"""Data-lake curation: Spadas as the data layer of the training framework.

    PYTHONPATH=src python examples/data_discovery.py

Given a lake of trajectory datasets and an exemplar, select the most
similar shards (top-k directed Hausdorff with batch pruning), drop
near-duplicates with the 2-eps approximate Hausdorff, and materialize a
resumable token pipeline — the deliverable the trainer consumes.
"""
import numpy as np

from repro.data import discovery, synthetic


def main():
    lake = synthetic.trajectory_repository(192, seed=0)
    # pollute the lake with near-duplicates to show dedup working
    rng = np.random.default_rng(1)
    for i in range(8):
        src = lake[i]
        dup = src + rng.normal(scale=1e-3, size=src.shape).astype(np.float32)
        lake.append(dup)

    exemplar = lake[0]
    selected, repo, info = discovery.curate(
        lake, exemplar, k=48, theta=6, metric="hausdorff")
    print(f"[discovery] lake={len(lake)} datasets; Hausdorff bound pass "
          f"pruned {info['search_stats']['pruned_fraction']:.0%} of exact "
          f"evaluations")
    print(f"[discovery] selected {len(selected)} shards, "
          f"deduped away {info['deduped_away']} near-duplicates")

    pipe = discovery.pipeline_from_selection(
        lake, selected, repo, theta=6, seq_len=128, batch=4)
    b = pipe.next_batch()
    print(f"[discovery] pipeline ready: batch tokens {b['tokens'].shape}, "
          f"vocab range [{b['tokens'].min()}, {b['tokens'].max()}]")
    print(f"[discovery] resumable state: {pipe.state.as_dict()}")


if __name__ == "__main__":
    main()
