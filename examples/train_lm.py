"""End-to-end driver: train the paper-native trajectory LM on data curated
by the Spadas index (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Defaults train the reduced config for a quick demonstration; pass --full
to train the full spadas-trajlm (~120M params) — the same driver, longer.
"""
import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    argv = [
        "--arch", "spadas_trajlm",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "256",
        "--lake-size", "256",
        "--ckpt-dir", "results/ckpt_example",
        "--ckpt-every", "100",
    ]
    if not args.full:
        argv.append("--reduced")
    losses = train_driver.main(argv)
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"[example] trained {args.steps} steps, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
