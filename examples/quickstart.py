"""Quickstart: the paper's Fig. 1 user journey in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the unified index over a synthetic repository, then runs every
search the paper supports: RangeS, top-k IA / GBO / ExactHaus / ApproHaus
(dataset granularity), RangeP and NNP (point granularity).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import point_search, search, zorder
from repro.core.build import build_query_index, build_repository
from repro.data import synthetic


def main():
    # a data lake of 120 spatial datasets (clustered POIs w/ GPS outliers)
    lake = synthetic.poi_repository(120, seed=0)
    repo, info = build_repository(lake, leaf_capacity=16, theta=5)
    print(f"unified index: {info['n_datasets']} datasets, bottom depth "
          f"{info['bottom_depth']}, upper depth {info['upper_depth']}, "
          f"outlier threshold r'={float(info['outlier_threshold']):.2f}")

    # the user's exemplar dataset
    Q = lake[7]
    q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=5)
    q_lo, q_hi = jnp.asarray(Q.min(0)), jnp.asarray(Q.max(0))

    # ---- coarse granularity: dataset search -------------------------------
    mask, stats = search.range_search(repo, q_lo, q_hi)
    print(f"RangeS: {int(mask.sum())} datasets overlap the query region "
          f"({stats.nodes_evaluated} node tests)")

    vals, ids = search.topk_ia(repo, q_lo, q_hi, 5)
    print(f"IA    top-5: {np.asarray(ids).tolist()}")

    vals, ids = search.topk_gbo(repo, q_sig, 5)
    print(f"GBO   top-5: {np.asarray(ids).tolist()} "
          f"(overlaps {np.asarray(vals).tolist()})")

    vals, ids, hstats = search.topk_hausdorff(repo, q_idx, 5)
    print(f"Haus  top-5: {np.asarray(ids).tolist()} "
          f"(exact evals: {hstats.exact_evaluations} of "
          f"{info['n_datasets']} — {hstats.pruned_fraction:.0%} pruned)")

    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
    avals, aids, (lq, ld, eps_eff) = search.topk_hausdorff_approx(
        repo, q_idx, 5, eps)
    print(f"ApproHaus top-5: {np.asarray(aids).tolist()} "
          f"(error <= {2 * eps_eff:.3f})")

    # ---- fine granularity: point search -----------------------------------
    best = int(ids[1])  # most similar dataset that isn't Q itself
    d_idx = jax.tree.map(lambda x: x[best], repo.ds_index)
    take, pstats = point_search.range_points(d_idx, q_lo, q_hi)
    print(f"RangeP: {int(take.sum())} points of dataset {best} in region "
          f"({pstats.pruned_fraction:.0%} of leaves pruned)")

    dist, idx, nstats = point_search.nnp_pruned(q_idx, d_idx)
    live = np.asarray(q_idx.valid)
    print(f"NNP: mean NN distance {float(np.asarray(dist)[live].mean()):.3f} "
          f"({nstats.pruned_fraction:.0%} of leaf pairs pruned)")


if __name__ == "__main__":
    main()
