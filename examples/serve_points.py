"""Serving scenario: a spatial point-query service + LM decode side-by-side.

    PYTHONPATH=src python examples/serve_points.py

Simulates the deployed system: a resident Spadas QueryEngine answers
declarative search requests through the unified `engine.search` API
(retrieval), while the trajectory LM serves batched decode steps
(generation) — the two workloads the production mesh hosts.  Requests are
frozen `Query` / `Pipeline` specs: one mixed batch covers point queries
(RangeP), dataset queries, and the paper's dataset->point pipeline (top-k
datasets, then search points inside the winners) in a single engine call;
the online path pushes the same specs through the SearchServer's
continuous micro-batching.
"""
import time

import numpy as np

from repro.core.build import build_repository
from repro.data import synthetic
from repro.engine import Pipeline, Query, QueryEngine
from repro.launch import serve as serve_driver
from repro.launch.serve_search import SearchServer


def main():
    # --- retrieval side ---
    lake = synthetic.trajectory_repository(64, seed=0)
    repo, info = build_repository(lake, leaf_capacity=16, theta=5)
    engine = QueryEngine(repo)

    rng = np.random.default_rng(0)
    n_requests = 16
    boxes = [rng.uniform(20, 80, 2).astype(np.float32)
             for _ in range(n_requests)]

    # one declarative mixed batch: RangeP rows for every box PLUS a
    # dataset->point pipeline (top-3 IA datasets, then RangeP inside the
    # winners — the id handoff never leaves the device)
    batch = [
        Query(op="range_points", ds_id=i % 64, r_lo=c - 2.0, r_hi=c + 2.0)
        for i, c in enumerate(boxes)
    ]
    c0 = boxes[0]
    batch.append(Pipeline(
        Query(op="topk_ia", r_lo=c0 - 10.0, r_hi=c0 + 10.0, k=3),
        Query(op="range_points", r_lo=c0 - 2.0, r_hi=c0 + 2.0)))

    engine.search(batch)               # warmup: compile the bucketed execs
    g0 = engine.stats.plan_groups
    t0 = time.time()
    results = engine.search(batch)
    hits = sum(int(np.asarray(r.mask).sum()) for r in results[:-1])
    pipe = results[-1]
    dt = time.time() - t0
    print(f"[retrieval] {n_requests} RangeP + 1 pipeline in {dt*1e3:.1f} ms "
          f"({hits} points returned; pipeline winners "
          f"{np.asarray(pipe.extras['ds_ids']).tolist()} -> "
          f"{int(np.asarray(pipe.mask).sum())} points, "
          f"{engine.stats.plan_groups - g0} dispatch groups planned)")

    # the same specs flow through the online server (continuous
    # micro-batching; submit() is a thin Query-constructing shim)
    server = SearchServer(engine, max_batch=32).start()
    Q = lake[1][:256]
    server.submit("nnp", ds_id=0, q=Q).result(timeout=600)  # warmup
    d0 = engine.stats.dispatches
    t0 = time.time()
    dist, idx = server.submit("nnp", ds_id=0, q=Q).result(timeout=600)
    print(f"[retrieval] NNP for {len(Q)} points in "
          f"{(time.time()-t0)*1e3:.1f} ms "
          f"({engine.stats.dispatches - d0} engine dispatches)")
    server.stop()

    # --- generation side ---
    serve_driver.main(["--arch", "spadas_trajlm", "--requests", "8",
                       "--prompt-len", "64", "--gen", "16"])


if __name__ == "__main__":
    main()
