"""Serving scenario: a spatial point-query service + LM decode side-by-side.

    PYTHONPATH=src python examples/serve_points.py

Simulates the deployed system: a resident Spadas index answers batched
RangeP/NNP requests (retrieval), while the trajectory LM serves batched
decode steps (generation) — the two workloads the production mesh hosts.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import point_search
from repro.core.build import build_query_index, build_repository
from repro.data import synthetic
from repro.launch import serve as serve_driver


def main():
    # --- retrieval side ---
    lake = synthetic.trajectory_repository(64, seed=0)
    repo, info = build_repository(lake, leaf_capacity=16, theta=5)
    d_idx = jax.tree.map(lambda x: x[0], repo.ds_index)

    rng = np.random.default_rng(0)
    n_requests = 16
    t0 = time.time()
    hits = 0
    for _ in range(n_requests):
        c = rng.uniform(20, 80, 2).astype(np.float32)
        lo, hi = jnp.asarray(c - 2.0), jnp.asarray(c + 2.0)
        take, _ = point_search.range_points(d_idx, lo, hi)
        hits += int(take.sum())
    dt = time.time() - t0
    print(f"[retrieval] {n_requests} RangeP requests in {dt*1e3:.1f} ms "
          f"({hits} points returned)")

    Q = lake[1][:256]
    q_idx, _ = build_query_index(Q)
    t0 = time.time()
    dist, idx, stats = point_search.nnp_pruned(q_idx, d_idx)
    print(f"[retrieval] NNP for {len(Q)} points in "
          f"{(time.time()-t0)*1e3:.1f} ms "
          f"({stats.pruned_fraction:.0%} leaf pairs pruned)")

    # --- generation side ---
    serve_driver.main(["--arch", "spadas_trajlm", "--requests", "8",
                       "--prompt-len", "64", "--gen", "16"])


if __name__ == "__main__":
    main()
