"""Serving scenario: a spatial point-query service + LM decode side-by-side.

    PYTHONPATH=src python examples/serve_points.py

Simulates the deployed system: a resident Spadas QueryEngine answers
micro-batched RangeP/NNP requests through the search serving front-end
(retrieval), while the trajectory LM serves batched decode steps
(generation) — the two workloads the production mesh hosts.  The old
per-request host loop is gone: every group of requests is one device
dispatch.
"""
import time

import numpy as np

from repro.core.build import build_repository
from repro.data import synthetic
from repro.engine import QueryEngine
from repro.launch import serve as serve_driver
from repro.launch.serve_search import SearchServer, ServerStats


def main():
    # --- retrieval side ---
    lake = synthetic.trajectory_repository(64, seed=0)
    repo, info = build_repository(lake, leaf_capacity=16, theta=5)
    engine = QueryEngine(repo)
    server = SearchServer(engine, max_batch=32).start()

    rng = np.random.default_rng(0)
    n_requests = 16
    boxes = [rng.uniform(20, 80, 2).astype(np.float32)
             for _ in range(n_requests)]

    # warmup burst (compile the bucketed executables once)
    warm = [server.submit("range_points", ds_id=i % 64, r_lo=c - 2.0,
                          r_hi=c + 2.0) for i, c in enumerate(boxes)]
    for f in warm:
        f.result(timeout=600)
    server.stats = ServerStats()       # report the measured window only

    t0 = time.time()
    futures = [
        server.submit("range_points", ds_id=i % 64, r_lo=c - 2.0,
                      r_hi=c + 2.0)
        for i, c in enumerate(boxes)
    ]
    hits = sum(int(np.asarray(f.result(timeout=600)).sum())
               for f in futures)
    dt = time.time() - t0
    print(f"[retrieval] {n_requests} RangeP requests in {dt*1e3:.1f} ms "
          f"({hits} points returned, "
          f"{server.stats.batches} device batches)")

    Q = lake[1][:256]
    server.submit("nnp", ds_id=0, q=Q).result(timeout=600)  # warmup
    d0 = engine.stats.dispatches
    t0 = time.time()
    dist, idx = server.submit("nnp", ds_id=0, q=Q).result(timeout=600)
    print(f"[retrieval] NNP for {len(Q)} points in "
          f"{(time.time()-t0)*1e3:.1f} ms "
          f"({engine.stats.dispatches - d0} engine dispatches)")
    server.stop()

    # --- generation side ---
    serve_driver.main(["--arch", "spadas_trajlm", "--requests", "8",
                       "--prompt-len", "64", "--gen", "16"])


if __name__ == "__main__":
    main()
