"""Roofline analysis (spec deliverable g) from the dry-run artifacts.

Hardware model (TPU v5e, per chip):
    peak bf16 compute  197e12 FLOP/s
    HBM bandwidth      819e9  B/s
    ICI per link       50e9   B/s

Terms per (arch x shape), single-pod mesh:
    compute_s    = HLO_FLOPs_per_device / 197e12
    memory_s     = HLO_bytes_per_device / 819e9
    collective_s = ring-model moved bytes per device / 50e9
                   (serialized upper bound; overlap noted per cell)

plus MODEL_FLOPS (6ND train / 2ND inference, N = active params) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs x chips).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def model_flops(rec: dict) -> float:
    n_active = rec["model_params_active"]
    tokens = rec["batch"] * rec["seq"]
    if rec["cell_kind"] == "train":
        return 6.0 * n_active * tokens
    if rec["cell_kind"] == "prefill":
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * rec["batch"]


def analyze(rec: dict, probe: dict | None = None) -> dict:
    nd = rec["n_devices"]
    flops = rec["flops_per_device"]
    bts = rec["bytes_per_device"]
    moved = rec.get("collective_moved_bytes_total",
                    rec.get("collective_bytes_total", 0))
    corrected = False
    if probe and probe.get("status") == "ok":
        # scan-trip correction: XLA counts the layer-scan body once.  The
        # unrolled R=1/R=2 probes give the true per-repeat marginal cost;
        # anchor on the SCANNED artifact (which fully counts everything
        # outside the scan, incl. SPMD-fallback copies) and add the
        # (R-1) missing repeats of the scan body.
        R = probe["n_repeats"]
        r1, r2 = probe["probe"]["r1"], probe["probe"]["r2"]
        flops += (R - 1) * max(r2["flops"] - r1["flops"], 0.0)
        bts += (R - 1) * max(r2["bytes"] - r1["bytes"], 0.0)
        moved += (R - 1) * max(r2["coll_moved"] - r1["coll_moved"], 0.0)
        corrected = True
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    collective_s = moved / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_total = flops * nd
    useful = mf / hlo_total if hlo_total > 0 else float("nan")
    # roofline fraction: useful model FLOPs over what the bottleneck term
    # would allow at peak (the score the perf loop drives up)
    step_s = max(terms.values())
    achievable_mfu = (mf / nd / PEAK_FLOPS) / step_s if step_s > 0 else 0.0
    return {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": achievable_mfu,
        "scan_corrected": corrected,
    }


def load_records(d: Path, mesh: str = "single", tag: str = "") -> list[dict]:
    recs = []
    for f in sorted(d.glob(f"*__{mesh}{'__' + tag if tag else ''}.json")):
        r = json.loads(f.read_text())
        if tag == "" and r.get("tag"):
            continue
        recs.append(r)
    return recs


def load_probes(d: Path, tag: str = "") -> dict:
    ptag = f"probe__{tag}" if tag else "probe"
    out = {}
    for f in sorted(d.glob(f"*__single__{ptag}.json")):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:8.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:8.2f}ms"
    return f"{x*1e6:8.2f}us"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    recs = load_records(Path(args.dir), args.mesh, args.tag)
    probes = load_probes(Path(args.dir), args.tag)
    rows = []
    print(f"{'arch':<22}{'shape':<13}{'compute':>11}{'memory':>11}"
          f"{'collective':>11}  {'bound':<11}{'useful':>8}{'roofline%':>10}")
    for r in recs:
        if r.get("status") == "skipped":
            print(f"{r['arch']:<22}{r['shape']:<13}"
                  f"{'-- skipped (full-attention @512k, see DESIGN.md) --'}")
            rows.append(r)
            continue
        if r.get("status") != "ok":
            print(f"{r['arch']:<22}{r['shape']:<13}  ERROR")
            rows.append(r)
            continue
        a = analyze(r, probes.get((r["arch"], r["shape"])))
        rows.append({**r, "roofline": a})
        print(f"{r['arch']:<22}{r['shape']:<13}"
              f"{fmt_s(a['compute_s']):>11}{fmt_s(a['memory_s']):>11}"
              f"{fmt_s(a['collective_s']):>11}  {a['bottleneck']:<11}"
              f"{a['useful_ratio']:>8.2f}{a['roofline_fraction']*100:>9.1f}%")
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=2))
        print(f"wrote {args.json_out}")
    return rows


if __name__ == "__main__":
    main()
