"""Engine QPS benchmark: batched multi-query dispatch vs per-query loop.

    PYTHONPATH=src python benchmarks/bench_engine.py [--out BENCH_engine.json]
    REPRO_HOST_DEVICES=8 PYTHONPATH=src \
        python benchmarks/bench_engine.py --sharded   # -> BENCH_engine_sharded.json

For each dataset-granularity op (RangeS, top-k IA, top-k GBO, ApproHaus)
and the point-granularity RangeP, measures queries-per-second of

  * the **per-query-loop baseline**: a Python loop over the seed
    single-query ops (the pre-engine serving shape — one host round trip
    per query), and
  * the **engine batched path** at batch sizes 1 -> 256 (one device
    dispatch per batch via the QueryEngine's cached executables).

With ``--sharded`` the engine is a :class:`ShardedQueryEngine` over a 1-D
``data`` mesh spanning all local devices (set ``REPRO_HOST_DEVICES=N`` to
force N host-platform devices on CPU) and the record lands in
``BENCH_engine_sharded.json``; the record also gains an ``exact_hausdorff``
section — single-query ExactHaus latency AND per-device resident
repository bytes at 1/3/8 shards, showing memory dropping ~1/N now that
the sharded branch-and-bound keeps no replicated repository copy.

Both modes also run the BATCHED ExactHaus sweep (`exact_hausdorff_batched`
section): batch 1..64 query-index batches answered in ONE branch-and-bound
dispatch (shared phase-2 work frontier) vs the per-query dispatch loop
(one engine dispatch per query — the pre-batching serving shape), on a
serving-shaped corpus of its own, AND the MIXED-OP sweep (`mixed_ops`
section): heterogeneous declarative batches — all seven ops plus a
dataset->point pipeline kind — answered with ONE `engine.search` call vs
the per-op grouped-dispatch loop over the same rows (hand grouping + one
engine call per (op, statics) group + host id handoff for pipelines, the
pre-redesign serving shape).  All engines run with the result cache
disabled so repeated timing iterations measure dispatch, not memoization.
``--max-batch`` trims every sweep (the CI bench-smoke step uses it).

Two more sections ride along in both modes: ``bound_phases`` — the fused
all-levels `ops.bound_grid` pass vs the per-level `vmap(frontier_bounds)`
composition it replaced in ExactHaus phases 0/1 (B in {1, 8, 32}) — and
``adaptive_serving`` — the serving front-end's queue-depth-driven batching
window vs the seed's static max-wait window (QPS + p50/p99 at low and
saturating load).

``--join-sweep`` runs the joinable-op mode on its own record
(``BENCH_engine_join.json``): batched ``topk_overlap`` / ``topk_coverage``
QPS at batch 1..32 vs the per-query dispatch loop, the bound-phase pruned
fraction per row, and a PRE-FILLED saturating serving segment mixing
joinable queries with dataset→dataset re-rank pipelines (see
``bench_join_sweep``).

``--replica-sweep`` runs a third mode on its own record
(``BENCH_engine_replica.json``): the ReplicatedQueryEngine over R x D
(replica x data) meshes at fixed D — saturated serving QPS plus the
measured per-replica-group critical path and its device-parallel QPS
projection at R = 1/2/4 (see ``bench_replica_scaling``).

Emits the JSON record with per-op QPS curves plus a summary of the
batch-64 speedup over the baseline and the batch-32 batched-ExactHaus
speedup.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

from repro import hostdev

# must happen before the first jax import: force N host-platform devices so
# the sharded mode has something to shard over on CPU-only machines
hostdev.apply()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import point_search, search, zorder
from repro.core.build import build_repository
from repro.data import synthetic
from repro.engine import QueryEngine, ShardedQueryEngine
from repro.engine.sharded import data_mesh, repo_device_bytes

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
EXACT_BATCHES = (1, 2, 4, 8, 16, 32, 64)
MIXED_BATCHES = (1, 2, 4, 8, 16, 32, 64)
EXACT_SHARD_COUNTS = (1, 3, 8)

# ExactHaus batched-QPS corpus: the online serving shape — many small-ish
# datasets, small exemplar queries (distinct from the main op corpus so the
# branch-and-bound sweep isn't dominated by one giant padded point axis)
EXACT_DATASETS = 128
EXACT_N_POINTS = (40, 100)
EXACT_Q_POINTS = 24
EXACT_K = 10
EXACT_CHUNK = 8


def bench_exacthaus_batched(engine_ctor, repeats, *, max_batch=None,
                            seed=1):
    """Batched ExactHaus QPS sweep: batch 1..64 in ONE dispatch each vs
    the per-query dispatch loop (the pre-batching serving shape: one
    engine dispatch per query, as serve_search used to issue).

    Builds its own serving-shaped corpus (EXACT_* constants), constructs
    an engine via `engine_ctor(repo)` (local or sharded; result cache off
    so repeats measure dispatch), and returns the op record with per-batch
    QPS and speedup-vs-loop.  The baseline loop for each row runs the
    SAME b queries as the batched dispatch (per-query branch-and-bound
    work varies across the pool, so a fixed baseline query set would bias
    the ratio — at batch 1 both sides run the identical single dispatch
    and the speedup is ~1 by construction)."""
    lake = synthetic.trajectory_repository(EXACT_DATASETS, seed=seed,
                                           n_points=EXACT_N_POINTS)
    repo, _ = build_repository(lake, leaf_capacity=16, theta=5,
                               remove_outliers=False)
    engine = engine_ctor(repo)
    n_pool = max(EXACT_BATCHES)
    q_sets = [lake[i % len(lake)][:EXACT_Q_POINTS] for i in range(n_pool)]
    q_batch_all = engine.build_queries(q_sets)
    k, chunk = EXACT_K, EXACT_CHUNK

    def q_at(i):
        return jax.tree.map(lambda x: x[i], q_batch_all)

    def q_slice(b):
        return jax.tree.map(lambda x: x[:b], q_batch_all)

    engine.topk_hausdorff(q_at(0), k, chunk=chunk)     # warm bucket 1

    batches = [b for b in EXACT_BATCHES
               if max_batch is None or b <= max_batch]
    rows = []
    for b in batches:
        def loop(b=b):                 # matched set: queries 0..b-1
            out = None
            for i in range(b):
                out = engine.topk_hausdorff(q_at(i), k, chunk=chunk)[0]
            return out

        t_loop = _time_best(loop, repeats=max(2, repeats // 2))
        tb = _time_best(lambda: engine.topk_hausdorff(q_slice(b), k,
                                                      chunk=chunk)[0],
                        repeats=repeats)
        rows.append({
            "batch": b,
            "seconds_per_batch": tb,
            "qps": b / tb,
            "loop_seconds": t_loop,
            "loop_qps": b / t_loop,
            "speedup_vs_loop": t_loop / tb,
        })
    return {
        "corpus": {
            "n_datasets": EXACT_DATASETS, "n_points": EXACT_N_POINTS,
            "query_points": EXACT_Q_POINTS, "k": k, "chunk": chunk,
            "ds_points_padded": int(repo.ds_index.points.shape[1]),
            "query_points_padded": int(q_batch_all.points.shape[1]),
        },
        "batches": rows,
    }


def _block_mixed(outs):
    """Block on every device leaf of a mixed result list (SearchResults
    and raw arrays alike)."""
    leaves = []
    for r in outs:
        if hasattr(r, "op"):
            for x in (r.vals, r.ids, r.mask):
                if x is not None:
                    leaves.append(x)
        else:
            leaves.append(r)
    jax.block_until_ready(leaves)
    return outs


def make_mixed_pool(repo, lake, n: int, k: int, eps, seed: int = 2):
    """A declarative query pool cycling all seven ops plus a pipeline kind
    (top-3 IA datasets -> RangeP inside the winners) — the heterogeneous
    traffic shape the unified search() API exists for."""
    from repro.core import zorder as zorder_lib
    from repro.engine.query import Pipeline, Query

    rng = np.random.default_rng(seed)
    n_ds = len(lake)
    sig_fn = jax.jit(lambda p, v: zorder_lib.signature(
        p, v, repo.space_lo, repo.space_hi, 5))
    pool = []
    for i in range(n):
        c = rng.uniform(10, 90, 2).astype(np.float32)
        lo, hi = c - 4.0, c + 4.0
        kind = i % 8
        if kind == 0:
            pool.append(Query(op="range_search", r_lo=lo, r_hi=hi))
        elif kind == 1:
            pool.append(Query(op="topk_ia", r_lo=lo, r_hi=hi, k=k))
        elif kind == 2:
            q = lake[int(rng.integers(n_ds))]
            sig = np.asarray(sig_fn(jnp.asarray(q),
                                    jnp.ones(len(q), bool)))
            pool.append(Query(op="topk_gbo", q_sig=sig, k=k))
        elif kind == 3:
            q = lake[int(rng.integers(n_ds))][:64]
            pool.append(Query(op="topk_hausdorff_approx", q=q, k=k,
                              eps=eps))
        elif kind == 4:
            q = lake[int(rng.integers(n_ds))][:24]
            pool.append(Query(op="topk_hausdorff", q=q, k=k, chunk=8))
        elif kind == 5:
            pool.append(Query(op="range_points",
                              ds_id=int(rng.integers(n_ds)),
                              r_lo=lo, r_hi=hi))
        elif kind == 6:
            q = lake[int(rng.integers(n_ds))][:64]
            pool.append(Query(op="nnp", ds_id=int(rng.integers(n_ds)),
                              q=q))
        else:
            pool.append(Pipeline(
                Query(op="topk_ia", r_lo=c - 10.0, r_hi=c + 10.0, k=3),
                Query(op="range_points", r_lo=lo, r_hi=hi)))
    return pool


def bench_mixed_ops(engine, repo, lake, k, eps, repeats, *,
                    max_batch=None):
    """Mixed-op QPS sweep: ONE declarative `engine.search` call for a
    heterogeneous batch vs the per-op grouped-dispatch loop (group the
    same rows by (op, statics) by hand, one engine call per group, with
    the HOST id handoff for pipelines — the pre-redesign serving shape).
    Both sides run the SAME query rows per batch size, on the same engine
    with the result cache off, so the ratio isolates the single-entry
    planning win (shared drains, no per-op Python passes, device-side
    pipeline handoff)."""
    from collections import OrderedDict

    from repro.engine.query import Pipeline

    batches = [b for b in MIXED_BATCHES
               if max_batch is None or b <= max_batch]
    pool = make_mixed_pool(repo, lake, max(batches), k, eps)

    def grouped(items):
        out = []
        groups: "OrderedDict[tuple, list]" = OrderedDict()
        for it in items:
            if isinstance(it, Pipeline):
                key = ("pipeline", it.dataset_stage.op,
                       it.dataset_stage.statics())
            else:
                key = (it.op,) + it.statics()
            groups.setdefault(key, []).append(it)
        for key, its in groups.items():
            if key[0] == "pipeline":
                # two-call host baseline: ids leave the device per request
                s1 = engine.search([it.dataset_stage for it in its])
                for it, r1 in zip(its, s1):
                    ids = np.asarray(r1.ids)
                    safe = np.where(ids >= 0, ids, 0)
                    kk = len(ids)
                    ps = it.point_stage
                    out.append(engine.range_points(
                        safe, np.broadcast_to(ps.r_lo, (kk, 2)),
                        np.broadcast_to(ps.r_hi, (kk, 2))))
            else:
                out.extend(engine.search(its))
        return out

    rows = []
    for b in batches:
        items = pool[:b]
        # 5 best-of trials: the mixed/grouped ratio is near 1 by
        # construction (same dispatch groups), so scheduler noise on small
        # shared CPUs — especially under an 8-forced-device host mesh —
        # needs more trials than the coarser sweeps to not flip the sign
        t_mixed = _time_best(lambda: _block_mixed(engine.search(items)),
                             repeats=repeats, trials=5)
        t_grouped = _time_best(lambda: _block_mixed(grouped(items)),
                               repeats=repeats, trials=5)
        rows.append({
            "batch": b,
            "seconds_per_batch": t_mixed,
            "qps": b / t_mixed,
            "grouped_seconds": t_grouped,
            "grouped_qps": b / t_grouped,
            "speedup_vs_grouped": t_grouped / t_mixed,
        })
    return {"kinds": 8, "pipeline_every": 8, "batches": rows}


BOUND_PHASE_BATCHES = (1, 8, 32)


def bench_bound_phases(repo, q_batch_all, repeats, *, max_batch=None):
    """Fused bound-phase microbenchmark: ONE `ops.bound_grid` dispatch for
    every tree level's (B, S) frontier bounds vs the pre-fusion
    composition — one jitted `vmap(frontier_bounds)` dispatch PER level
    (the exact pass ExactHaus phases 0/1 used to issue, kept here as the
    baseline).  The record also carries the composition hand-fused under
    one jit (`legacy_onejit_seconds`) so the dispatch-overhead share of
    the win stays visible.

    Outputs are asserted numerically equal first (rtol 1e-5; the residual
    is XLA's shape-dependent FMA contraction, ~1 ulp, and the row records
    the observed max relative deviation), then timed."""
    from repro.core.search import _frontier_bound_all_levels, frontier_bounds

    max_level = min(q_batch_all.depth, repo.ds_index.depth, 3)
    fused = jax.jit(
        lambda q: _frontier_bound_all_levels(q, repo.ds_index, max_level))
    per_level = jax.jit(
        jax.vmap(frontier_bounds, in_axes=(0, None, None, None)),
        static_argnums=(2, 3))

    def legacy(q):
        LBs, UBs = [], []
        for l in range(max_level + 1):
            LB, UB = per_level(q, repo.ds_index, l, l)
            LBs.append(LB)
            UBs.append(UB)
        return jnp.stack(LBs), jnp.stack(UBs)

    def legacy_onejit_fn(q):
        bounds = jax.vmap(frontier_bounds, in_axes=(0, None, None, None))
        LBs, UBs = [], []
        for l in range(max_level + 1):
            LB, UB = bounds(q, repo.ds_index, l, l)
            LBs.append(LB)
            UBs.append(UB)
        return jnp.stack(LBs), jnp.stack(UBs)

    legacy_onejit = jax.jit(legacy_onejit_fn)

    rows = []
    for b in BOUND_PHASE_BATCHES:
        if max_batch is not None and b > max_batch:
            continue
        q = jax.tree.map(lambda x: x[:b], q_batch_all)
        f = jax.block_until_ready(fused(q))
        g = jax.block_until_ready(legacy(q))
        max_rel = 0.0
        for a, c in zip(jax.tree.leaves(f), jax.tree.leaves(g)):
            a, c = np.asarray(a), np.asarray(c)
            np.testing.assert_allclose(a, c, rtol=1e-5)
            denom = np.maximum(np.abs(c), np.float32(1e-30))
            max_rel = max(max_rel, float(np.max(np.abs(a - c) / denom)))
        t_fused = _time_best(lambda: fused(q), repeats=repeats)
        t_legacy = _time_best(lambda: legacy(q), repeats=repeats)
        t_onejit = _time_best(lambda: legacy_onejit(q), repeats=repeats)
        rows.append({
            "batch": b,
            "fused_seconds": t_fused,
            "legacy_seconds": t_legacy,
            "legacy_onejit_seconds": t_onejit,
            "speedup_vs_legacy": t_legacy / t_fused,
            "speedup_vs_legacy_onejit": t_onejit / t_fused,
            "max_rel_deviation": max_rel,
        })
    return {
        "levels": max_level + 1,
        "n_slots": int(repo.ds_index.radii.shape[0]),
        "batches": rows,
    }


def bench_adaptive_serving(engine, repo, lake, k, eps, *,
                           max_batch=None, trials=3, seed=3):
    """Serving A/B: queue-depth-driven adaptive batching window vs the
    seed's fixed max-wait window, same engine, same mixed traffic.

    Two load points per mode: **low** (requests paced at 3x the static
    mode's measured per-request service time — the window policy IS the
    latency here) and
    **saturating** (the whole request pool sits in the queue BEFORE the
    dispatcher starts — batches must fill from queue depth alone; filling
    the queue first removes the submitter-vs-dispatcher thread race,
    which would otherwise measure Python thread scheduling instead of
    the batching policy).  Trials alternate static/adaptive servers so
    machine drift cancels out of the ratio; each (mode, load) keeps its
    best-QPS trial's record (QPS + p50/p99 ms from the server's
    per-request latency log).  Two untimed warm passes precede the trials
    so compile cost never lands in a row."""
    from repro.launch.serve_search import Request, SearchServer
    from repro.engine.query import Pipeline

    server_batch = 16 if max_batch is None else min(16, max_batch)
    n_requests = 6 * server_batch
    # saturating trials cycle the pool 4x: a longer timed window shrinks
    # the relative scheduler noise on what is otherwise a ~tie (under a
    # deep queue both policies fill every batch instantly)
    sat_rounds = 4
    pool = make_mixed_pool(repo, lake, n_requests, k, eps, seed=seed)

    def _row(server, dt, n):
        return {
            "qps": n / dt,
            "p50_ms": server.stats.p50_ms,
            "p99_ms": server.stats.p99_ms,
            "mean_batch": server.stats.mean_batch,
        }

    def run_paced(adaptive, gap_s):
        server = SearchServer(engine, max_batch=server_batch,
                              max_wait_ms=2.0, adaptive=adaptive).start()
        try:
            t0 = time.perf_counter()
            futures = []
            for i, q in enumerate(pool):
                # pace submissions against the trial clock (not sleep
                # accumulation) so the offered load stays what it claims
                lag = t0 + i * gap_s - time.perf_counter()
                if lag > 0:
                    time.sleep(lag)
                futures.append(server.submit_query(q))
            for f in futures:
                f.result(timeout=600)
            return _row(server, time.perf_counter() - t0, n_requests)
        finally:
            server.stop()

    def run_saturating(adaptive):
        # pre-fill the queue, THEN start the dispatcher: queue depth is
        # the whole trial's requests at t0, so every drain sees genuine
        # saturation
        server = SearchServer(engine, max_batch=server_batch,
                              max_wait_ms=2.0, adaptive=adaptive)
        reqs = []
        for q in pool * sat_rounds:
            op = "pipeline" if isinstance(q, Pipeline) else q.op
            req = Request(op, q)
            reqs.append(req)
            server._queue.put(req)
        t0 = time.perf_counter()
        server.start()
        try:
            for req in reqs:
                req.future.result(timeout=600)
            return _row(server, time.perf_counter() - t0, len(reqs))
        finally:
            server.stop()

    rec = {"n_requests": n_requests,
           "n_requests_saturating": n_requests * sat_rounds,
           "max_batch": server_batch, "loads": {}}
    # warm every dispatch group once off the measured path (shared engine:
    # both modes then time steady-state dispatch, not compilation), and
    # measure the per-request service time that paces the low-load trials
    # from the STATIC run — the seed policy defines the load scale, and
    # unlike the adaptive run its throughput doesn't include the
    # depth-scaled overfill win (pacing off the faster adaptive rate
    # would quietly turn "low" load into near-saturation)
    run_saturating(True)
    # best of two: the first static pass may still compile its own
    # (smaller) per-drain bucket shapes on the shared engine, and a
    # one-off slow pass here would mis-scale every low-load trial
    service_s = 1.0 / max(run_saturating(False)["qps"],
                          run_saturating(False)["qps"])
    # interleave the modes trial-by-trial (fresh server each, shared warm
    # engine) so machine drift lands on both sides of the ratio equally;
    # best-of-trials per (load, mode) like the other serving-shaped sweeps
    runs: dict = {}
    for _ in range(trials):
        for mode, adaptive in (("static", False), ("adaptive", True)):
            runs.setdefault(("saturating", mode), []).append(
                run_saturating(adaptive))
            # low-load trials are short and pacer-dominated, so the
            # policy signal is small against scheduler noise — sample
            # twice per round (best-of keeps the cleanest run per mode)
            for _ in range(2):
                runs.setdefault(("low", mode), []).append(
                    run_paced(adaptive, 3.0 * service_s))
    for (load, mode), rows in runs.items():
        rec["loads"].setdefault(load, {})[mode] = max(
            rows, key=lambda r: r["qps"])
    for load, row in rec["loads"].items():
        row["adaptive_qps_ratio"] = (row["adaptive"]["qps"]
                                     / row["static"]["qps"])
    return rec


def bench_replica_scaling(repo, lake, k, eps, *, repeats, max_batch=None,
                          data_shards=2, replica_counts=(1, 2, 4)):
    """Replica-parallel serving sweep at fixed repository bytes per device:
    R replica groups x D data shards, R in `replica_counts`, D fixed.

    Two throughput signals per R, both recorded:

      * ``qps_serving`` — honest end-to-end saturated serving QPS: the
        whole mixed pool sits in the server queue BEFORE the dispatcher
        starts (queue depth alone fills the batches), one
        ``engine.search`` per drain on the ReplicatedQueryEngine.  On a
        machine whose host "devices" time-slice fewer physical cores than
        R x D (CI, laptops — see ``host_cores``), replica groups serialize
        and this number DROPS with R; on real hardware each group owns its
        devices and it tracks the projection below.
      * ``qps_projected_parallel`` — B / t_group(R), where t_group(R) is
        MEASURED wall time of one replica group's program: a 1 x D
        sharded engine answering ``pool[:B//R]`` in one search() call.
        By the bit-identity construction that IS the program each group
        runs (the pool cycles its 8 kinds round-robin, so a 1/R prefix
        reproduces each group's per-dispatch row mix).  With groups on
        disjoint devices the slowest group bounds the batch -> QPS =
        B / t_group.  Monotonically increasing in R because t_group grows
        with rows (fixed per-dispatch overhead amortizes).

    The per-device repository bytes column is the point of fixing D: it
    stays constant across the sweep — replicas buy throughput, not memory.
    """
    from repro.engine import ReplicatedQueryEngine
    from repro.engine.query import Pipeline
    from repro.launch.serve_search import Request, SearchServer

    n_dev = jax.device_count()
    counts = [r for r in replica_counts if r * data_shards <= n_dev]
    server_batch = 16 if max_batch is None else min(16, max_batch)
    # B rows per measured group dispatch: divisible by every R and by the
    # pool's 8 kinds so each 1/R prefix keeps the full round-robin mix
    b_rows = 64 if max_batch is None else max(8, max_batch)
    sat_rounds = 4
    pool = make_mixed_pool(repo, lake, b_rows, k, eps, seed=3)

    def run_saturating(engine):
        server = SearchServer(engine, max_batch=server_batch,
                              max_wait_ms=2.0, adaptive=True)
        reqs = []
        for q in pool * sat_rounds:
            op = "pipeline" if isinstance(q, Pipeline) else q.op
            req = Request(op, q)
            reqs.append(req)
            server._queue.put(req)
        t0 = time.perf_counter()
        server.start()
        try:
            for req in reqs:
                req.future.result(timeout=600)
            dt = time.perf_counter() - t0
            return {"qps": len(reqs) / dt,
                    "p50_ms": server.stats.p50_ms,
                    "p99_ms": server.stats.p99_ms,
                    "mean_batch": server.stats.mean_batch}
        finally:
            server.stop()

    # one replica group's program: a 1 x D engine on a 1/R row prefix
    group_eng = ShardedQueryEngine(repo, mesh=data_mesh(data_shards),
                                   result_cache_size=0)
    ds_arrays = (group_eng.repo.ds_index, group_eng.repo.ds_sigs,
                 group_eng.repo.ds_valid)

    rows = []
    for r in counts:
        engine = ReplicatedQueryEngine(repo, n_replicas=r,
                                       n_data=data_shards,
                                       result_cache_size=0)
        run_saturating(engine)                       # warm every drain shape
        serving = max((run_saturating(engine) for _ in range(2)),
                      key=lambda x: x["qps"])
        g_rows = b_rows // r
        t_group = _time_best(
            lambda n=g_rows: _block_mixed(group_eng.search(pool[:n])),
            repeats=repeats)
        per_dev = repo_device_bytes(
            (engine.repo.ds_index, engine.repo.ds_sigs, engine.repo.ds_valid))
        rows.append({
            "replicas": r,
            "data_shards": data_shards,
            "devices": r * data_shards,
            "serving": serving,
            "group_rows": g_rows,
            "group_seconds_per_batch": t_group,
            "qps_projected_parallel": b_rows / t_group,
            "per_device_repo_bytes": max(per_dev.values()),
        })

    # idle-devices baseline: the 1 x D sharded engine serving the same
    # traffic with the other devices unused — what replicas improve on
    baseline_eng = ShardedQueryEngine(repo, mesh=data_mesh(data_shards),
                                      result_cache_size=0)
    run_saturating(baseline_eng)
    baseline = run_saturating(baseline_eng)

    proj = [row["qps_projected_parallel"] for row in rows]
    return {
        "method": ("qps_serving is the end-to-end pre-filled-queue drain on "
                   "the replicated engine (time-sliced on hosts with fewer "
                   "cores than devices); qps_projected_parallel = "
                   "batch_rows / measured wall time of one replica group's "
                   "program (a 1xD engine on the group's row share), the "
                   "device-parallel throughput bound"),
        "host_cores": os.cpu_count(),
        "batch_rows": b_rows,
        "n_requests_saturating": b_rows * sat_rounds,
        "baseline_1xD_idle_devices": baseline,
        "sweep": rows,
        "replica_qps_monotonic": all(a <= b for a, b in zip(proj, proj[1:])),
    }


def bench_mutation_sweep(lake, k, *, repeats, max_batch=None):
    """Live-repository serving under churn: closed-loop mixed-query QPS
    on a LiveRepository with NO mutations (baseline) vs the SAME load
    while a churn thread streams ingest / replace / delete BURSTS
    through the server's mutation lane (the two-stage pipeline: each
    burst's prepare overlaps the in-flight query segment and the whole
    burst publishes as ONE coalesced epoch at its stream position).

    Both phases use the same closed-loop feeder — a bounded in-flight
    window of queries, so drains stay saturated without pre-filling the
    whole phase (a pre-filled queue would push every mutation behind
    ALL queries and nothing would interleave).  Each phase runs on a
    FRESH server with fresh ``ServerStats``, so per-phase mean_batch
    actually shows the segment splits churn causes.

    The mutation stream keeps the safe id discipline: replaces rotate
    over original ids (always live), deletes only ever target slots the
    stream itself ingested (and only after their publish resolved) — so
    every point query in the pool stays valid no matter how the bursts
    interleave with the drains.

    Also records the mutation lane itself: per-publish latency
    percentiles, coalescing and prepare-overlap counters, bytes
    uploaded (placement accounting: single-dataset payloads only —
    never a full re-upload), epoch movement, and tier growth.
    """
    import threading
    from collections import deque

    from repro.engine import LiveRepository
    from repro.engine.query import Pipeline
    from repro.launch.serve_search import Request, SearchServer

    live = LiveRepository(lake, leaf_capacity=16, theta=5,
                          remove_outliers=False, result_cache_size=0)
    eps = float(zorder.default_epsilon(live.repo.space_lo,
                                       live.repo.space_hi, 5))
    # deeper drains than the query-only serving bench: under churn every
    # mutation run SPLITS its drain into separate engine calls, so the
    # per-call planning/dispatch overhead amortizes over the drain depth
    # — depth 32 keeps post-split segments as large as the query-only
    # bench's whole drains
    server_batch = 32 if max_batch is None else min(32, max_batch)
    b_rows = 64 if max_batch is None else max(8, max_batch)
    # 6 pool rounds per measured phase: long enough that one drain of
    # warm-up jitter can't move the phase QPS by more than a few percent
    sat_rounds = 6
    burst = 8
    window = 4 * server_batch
    pool = make_mixed_pool(live.repo, lake, b_rows, k, eps, seed=3)
    rng = np.random.default_rng(11)
    payloads = [(lake[int(rng.integers(len(lake)))]
                 + rng.normal(0, 0.5, 2).astype(np.float32))
                for _ in range(8)]
    counts = {"applied": 0, "payload": 0}
    own: list = []                          # slots the churn ingested

    def churn(server, stop):
        i = counts["applied"]
        while not stop.is_set():
            futs = []
            for _ in range(burst):          # one back-to-back burst
                kind = i % 3
                if kind == 1:
                    futs.append(server.submit_mutation(
                        "replace", ds_id=int(i // 3) % len(lake),
                        points=payloads[(i + 1) % len(payloads)]))
                    counts["payload"] += 1
                elif kind == 2 and own:
                    futs.append(server.submit_mutation(
                        "delete", ds_id=own.pop(0)))
                else:
                    futs.append(server.submit_mutation(
                        "ingest", points=payloads[i % len(payloads)]))
                    counts["payload"] += 1
                i += 1
            for f in futs:
                out = f.result(timeout=600)
                counts["applied"] += 1
                if isinstance(out, int) and out not in range(len(lake)):
                    own.append(out)         # a fresh ingest slot

    def run_phase(mutate: bool):
        server = SearchServer(live=live, max_batch=server_batch,
                              max_wait_ms=2.0, adaptive=True)
        n_total = len(pool) * sat_rounds
        server.start()
        stop = threading.Event()
        thread = None
        if mutate:
            thread = threading.Thread(target=churn, args=(server, stop),
                                      daemon=True)
        inflight: deque = deque()
        reqs = 0
        t0 = time.perf_counter()
        if thread is not None:
            thread.start()
        try:
            for n in range(n_total):
                q = pool[n % len(pool)]
                op = "pipeline" if isinstance(q, Pipeline) else q.op
                req = Request(op, q)
                server._queue.put(req)
                inflight.append(req)
                reqs += 1
                if len(inflight) >= window:
                    inflight.popleft().future.result(timeout=600)
            while inflight:
                inflight.popleft().future.result(timeout=600)
            dt = time.perf_counter() - t0
        finally:
            # join BEFORE stopping: the last burst's futures must still
            # be served, or its submitted-but-unapplied mutations would
            # skew the placement accounting
            stop.set()
            if thread is not None:
                thread.join(timeout=120)
            server.stop()
        return {"qps": reqs / dt,
                "p50_ms": server.stats.p50_ms,
                "p99_ms": server.stats.p99_ms,
                "mean_batch": server.stats.mean_batch,
                "mutations_in_phase": server.stats.mutations}

    # warm both lanes off the measured path: the query drains compile
    # their bucket shapes; one ingest/replace/delete probe compiles the
    # row-build stages, the group-of-1 updater, AND the tier growth
    # (128 datasets fill the initial ladder tier exactly, so the first
    # ingest doubles it here, not mid-measurement); coalesced groups of
    # {2, 4, 8} compile the batched publish buckets the bursts will hit
    run_phase(mutate=False)
    wid = live.ingest(payloads[0])
    live.replace(wid, payloads[1])
    live.delete(wid)
    for width in (2, 4, 8):
        group = live.prepare_group(
            [("ingest", None, payloads[i % len(payloads)])
             for i in range(width)])
        sids = live.publish_group(group)
        live.publish_group(live.prepare_group(
            [("delete", sid, None) for sid in sids]))
    live.bytes_uploaded = 0
    epoch0, layout0 = live.epoch, getattr(live.engine.dispatch,
                                          "repo_epoch", 0)
    estats = live.engine.stats
    pub0 = len(estats.publish_seconds)
    mc0 = estats.mutations_coalesced
    ov0 = estats.prepare_overlap_seconds

    baseline = max((run_phase(mutate=False) for _ in range(2)),
                   key=lambda r: r["qps"])
    under = max((run_phase(mutate=True) for _ in range(2)),
                key=lambda r: r["qps"])

    pub_ms = sorted(1e3 * x for x in estats.publish_seconds[pub0:])
    pct = lambda p: pub_ms[min(len(pub_ms) - 1,          # noqa: E731
                               int(p * (len(pub_ms) - 1)))] if pub_ms else 0.0
    geom = live.geometry
    per_mutation = geom.point_capacity * (4 * geom.dim + 1)
    return {
        "method": ("closed-loop mixed serving (bounded in-flight query "
                   "window) on a LiveRepository; 'under_mutation' repeats "
                   "the load while a churn thread submits back-to-back "
                   "8-mutation bursts through the server lane — each "
                   "burst prepares concurrently with the in-flight "
                   "segment and publishes as one coalesced epoch; "
                   "mutation latency is per-PUBLISH wall time"),
        "n_requests": b_rows * sat_rounds,
        "in_flight_window": window,
        "burst": burst,
        "baseline": baseline,
        "under_mutation": under,
        "qps_ratio_under_mutation": under["qps"] / baseline["qps"],
        "mutations_applied": counts["applied"],
        "mutations_coalesced": estats.mutations_coalesced - mc0,
        "publishes": len(pub_ms),
        "mutation_mean_ms": (sum(pub_ms) / len(pub_ms)) if pub_ms else 0.0,
        "mutation_p50_ms": pct(0.50),
        "mutation_p99_ms": pct(0.99),
        "prepare_overlap_seconds": estats.prepare_overlap_seconds - ov0,
        "epoch_delta": live.epoch - epoch0,
        "layout_epoch_delta": getattr(live.engine.dispatch, "repo_epoch", 0)
                              - layout0,
        "bytes_uploaded": live.bytes_uploaded,
        "bytes_per_payload_mutation": per_mutation,
        # placement accounting: every upload is ONE padded dataset row
        # (ingest/replace); deletes and growth upload nothing
        "no_full_reupload": live.bytes_uploaded
                            == counts["payload"] * per_mutation,
        "slots": live.n_slots,
        "live_datasets": len(live.live_ids),
    }


JOIN_BATCHES = (1, 2, 4, 8, 16, 32)
JOIN_Q_POINTS = 64
JOIN_CHUNK = 16


def bench_join_sweep(repo, lake, k, *, repeats, max_batch=None):
    """Joinable dataset search: batched QPS + bound-phase pruning.

    For each joinable op (``topk_overlap`` / ``topk_coverage``), batch
    1..32 query point sets answered as ONE `engine.search` call each
    (bound phase + shared-order chunked refine in a single dispatch),
    against the per-query dispatch loop baseline.  Every row also
    records the refine-loop work actually done: the mean bound-phase
    pruned fraction (1 - exact evaluations / valid slots) — the Eq.-4
    bound family earning its keep on the joinable ops.

    A serving segment rides along: a PRE-FILLED saturating queue (the
    whole burst visible to the first drain — in-flight feeding would
    measure the feeder) of joinable queries mixed with dataset→dataset
    pipeline requests (top-k IA winners re-ranked by overlap), drained
    through `SearchServer` / the single mixed `engine.search` path.
    """
    from repro.engine import Pipeline, Query
    from repro.launch.serve_search import Request, SearchServer, _to_query

    batches = [b for b in JOIN_BATCHES
               if max_batch is None or b <= max_batch]
    n_pool = max(batches)
    engine = QueryEngine(repo, result_cache_size=0,
                         default_chunk=JOIN_CHUNK)
    n_valid = int(np.asarray(repo.ds_valid).sum())
    qsets = [np.asarray(lake[i % len(lake)][:JOIN_Q_POINTS], np.float32)
             for i in range(n_pool)]

    rec = {
        "method": ("engine.search batches of B joinable queries (one "
                   "bound+refine dispatch) vs a per-query dispatch "
                   "loop; pruned fraction = 1 - exact evaluations / "
                   f"valid slots, refine chunk {JOIN_CHUNK}"),
        "k": k,
        "n_valid": n_valid,
        "chunk": JOIN_CHUNK,
        "ops": {},
    }
    for op in ("topk_overlap", "topk_coverage"):
        def one(i, op=op):
            return engine.search([Query(op=op, q=qsets[i % n_pool], k=k)])

        n_base = min(n_pool, 8)
        t = _time(lambda: [one(i) for i in range(n_base)],
                  repeats=max(2, repeats // 2))
        baseline_qps = n_base / t

        rows = []
        for b in batches:
            qs = [Query(op=op, q=qsets[i], k=k) for i in range(b)]
            res_box = {}

            def run(qs=qs, res_box=res_box):
                res_box["res"] = engine.search(qs)
                return res_box["res"][0].vals

            tb = _time_best(run, repeats=repeats)
            stats = [r.stats for r in res_box["res"]]
            pruned = sum(s.pruned_fraction for s in stats) / len(stats)
            rows.append({
                "batch": b,
                "seconds_per_batch": tb,
                "qps": b / tb,
                "speedup_vs_loop": (b / tb) / baseline_qps,
                "pruned_fraction": pruned,
                "evaluated_mean": (sum(s.exact_evaluations for s in stats)
                                   / len(stats)),
            })
        rec["ops"][op] = {
            "baseline_qps": baseline_qps,
            "baseline_loop_size": n_base,
            "batches": rows,
        }

    # serving segment: pre-filled saturating queue of joinable +
    # dataset→dataset pipeline requests through the mixed search() drain
    n_req = 4 * max(batches)
    reqs = []
    for i in range(n_req):
        q = qsets[i % n_pool]
        kind = i % 3
        if kind == 0:
            reqs.append(("topk_overlap", dict(q=q, k=k)))
        elif kind == 1:
            reqs.append(("topk_coverage", dict(q=q, k=k)))
        else:
            c = q.mean(axis=0)
            reqs.append(("pipeline", dict(
                dataset=dict(op="topk_ia", r_lo=c - 10.0, r_hi=c + 10.0,
                             k=min(8, n_valid)),
                point=dict(op="topk_overlap", q=q, k=min(3, k)))))
    serve_engine = QueryEngine(repo, result_cache_size=0,
                               default_chunk=JOIN_CHUNK)

    def serve_once():
        server = SearchServer(serve_engine, max_batch=max(batches),
                              max_wait_ms=2.0, adaptive=True)
        items = [Request(op, _to_query(op, p)) for op, p in reqs]
        for r in items:
            server._queue.put(r)
        t0 = time.perf_counter()
        server.start()
        try:
            for r in items:
                r.future.result(timeout=600)
            dt = time.perf_counter() - t0
        finally:
            server.stop()
        return {"qps": n_req / dt, "p50_ms": server.stats.p50_ms,
                "p99_ms": server.stats.p99_ms,
                "mean_batch": server.stats.mean_batch}

    serve_once()                             # warm the bucket ladder
    rec["serving"] = max((serve_once() for _ in range(2)),
                         key=lambda r: r["qps"])
    rec["serving"]["n_requests"] = n_req
    rec["serving"]["mix"] = ("1/3 topk_overlap, 1/3 topk_coverage, "
                             "1/3 IA->overlap rerank pipeline")
    return rec


def bench_exacthaus(repo, qi, k, repeats):
    """Sharded ExactHaus: single-query latency + per-device resident
    repository bytes at 1/3/8 shards (clipped to the available devices).

    The memory column is the point of the row: the dispatcher keeps NO
    replicated repository copy, so the per-device dataset bytes drop
    ~1/N with the shard count while the upper tree stays replicated.
    Includes the unsharded LocalDispatcher pipeline as the reference.
    """
    le = QueryEngine(repo, result_cache_size=0)
    t = _time(lambda: le.topk_hausdorff(qi, k)[0], repeats=repeats)
    rec = {
        "k": k,
        "local": {
            "seconds_per_query": t,
            "qps": 1.0 / t,
            "per_device_repo_bytes": max(repo_device_bytes(le.repo).values()),
        },
        "rows": [],
    }
    for s in EXACT_SHARD_COUNTS:
        if s > jax.device_count():
            print(f"[bench_engine] exacthaus: skipping {s} shards "
                  f"({jax.device_count()} devices available)")
            continue
        e = ShardedQueryEngine(repo, mesh=data_mesh(s),
                               result_cache_size=0)
        last = {}

        def run(e=e, last=last):
            vals, _, last["stats"] = e.topk_hausdorff(qi, k)
            return vals

        t = _time(run, repeats=repeats)
        stats = last["stats"]
        per_dev = repo_device_bytes(e.dispatch.repo)
        total = sum(x.nbytes for x in jax.tree.leaves(e.dispatch.repo))
        rec["rows"].append({
            "shards": s,
            "seconds_per_query": t,
            "qps": 1.0 / t,
            "per_device_repo_bytes": max(per_dev.values()),
            "total_repo_bytes": total,
            "exact_evaluations": stats.exact_evaluations,
        })
    return rec


def _time(fn, *, repeats: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def _time_best(fn, *, repeats: int, trials: int = 3) -> float:
    """Best-of-`trials` mean timing — robust to scheduler noise spikes on
    small shared CPUs (one descheduled trial can't poison a committed
    row)."""
    return min(_time(fn, repeats=repeats) for _ in range(trials))


def _query_pool(repo, datasets, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lo = rng.uniform(0, 80, (n, 2)).astype(np.float32)
    hi = lo + rng.uniform(2, 20, (n, 2)).astype(np.float32)
    sig_fn = jax.jit(lambda p, v: zorder.signature(
        p, v, repo.space_lo, repo.space_hi, 5))
    sigs = []
    for i in range(n):
        q = datasets[i % len(datasets)]
        sigs.append(np.asarray(sig_fn(jnp.asarray(q),
                                      jnp.ones(len(q), bool))))
    return lo, hi, np.stack(sigs)


def bench_op(name, baseline_one, engine_batch, pool_size, *, repeats=8):
    """QPS for per-query loop vs engine batches; returns the op's record."""
    # baseline: Python loop, one op call per query (seed serving shape)
    n_base = min(pool_size, 32)

    def loop():
        out = None
        for i in range(n_base):
            out = baseline_one(i)
        return out

    t = _time(loop, repeats=max(2, repeats // 2))
    baseline_qps = n_base / t

    rows = []
    for b in BATCHES:
        tb = _time(lambda: engine_batch(b), repeats=repeats)
        rows.append({
            "batch": b,
            "seconds_per_batch": tb,
            "qps": b / tb,
            "speedup_vs_loop": (b / tb) / baseline_qps,
        })
    return {
        "baseline_qps": baseline_qps,
        "baseline_loop_size": n_base,
        "batches": rows,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="output json (default BENCH_engine.json, or "
                         "BENCH_engine_sharded.json with --sharded)")
    ap.add_argument("--datasets", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="trim every batch sweep to <= this size (CI "
                         "bench-smoke uses a tiny cap so the scripts "
                         "stay cheap but can't rot)")
    ap.add_argument("--sharded", action="store_true",
                    help="benchmark the ShardedQueryEngine over a 1-D data "
                         "mesh spanning all local devices")
    ap.add_argument("--replica-sweep", action="store_true",
                    help="run ONLY the replica-parallel serving sweep "
                         "(ReplicatedQueryEngine at R x 2 for R in 1/2/4; "
                         "force 8 host devices with REPRO_HOST_DEVICES=8) "
                         "-> BENCH_engine_replica.json")
    ap.add_argument("--mutation-sweep", action="store_true",
                    help="run ONLY the live-repository churn benchmark "
                         "(saturated mixed serving with and without a "
                         "background ingest/replace/delete stream) "
                         "-> BENCH_engine_live.json")
    ap.add_argument("--join-sweep", action="store_true",
                    help="run ONLY the joinable-op benchmark (batched "
                         "overlap/coverage QPS + bound-phase pruned "
                         "fraction + a pre-filled mixed serving segment) "
                         "-> BENCH_engine_join.json")
    args = ap.parse_args(argv)
    if args.max_batch is not None:
        global BATCHES
        BATCHES = tuple(b for b in BATCHES if b <= args.max_batch)
    if args.out is None:
        args.out = ("BENCH_engine_live.json" if args.mutation_sweep
                    else "BENCH_engine_join.json" if args.join_sweep
                    else "BENCH_engine_replica.json" if args.replica_sweep
                    else "BENCH_engine_sharded.json" if args.sharded
                    else "BENCH_engine.json")

    lake = synthetic.trajectory_repository(args.datasets, seed=0,
                                           n_points=(100, 400))
    if args.mutation_sweep:
        rec = {
            "bench": "engine_live",
            "n_datasets": args.datasets,
            "n_devices": jax.device_count(),
            "mutation_sweep": bench_mutation_sweep(
                lake, 10, repeats=max(2, args.repeats // 2),
                max_batch=args.max_batch),
        }
        ms = rec["mutation_sweep"]
        summary = {
            "qps_baseline": round(ms["baseline"]["qps"], 1),
            "qps_under_mutation": round(ms["under_mutation"]["qps"], 1),
            "qps_ratio_under_mutation":
                round(ms["qps_ratio_under_mutation"], 3),
            "p99_ms_under_mutation": round(ms["under_mutation"]["p99_ms"], 1),
            "mutation_p50_ms": round(ms["mutation_p50_ms"], 1),
            "mutation_p99_ms": round(ms["mutation_p99_ms"], 1),
            "mutations_applied": ms["mutations_applied"],
            "mutations_coalesced": ms["mutations_coalesced"],
            "prepare_overlap_seconds":
                round(ms["prepare_overlap_seconds"], 3),
            "no_full_reupload": ms["no_full_reupload"],
        }
        rec["summary"] = summary
        Path(args.out).write_text(json.dumps(rec, indent=2))
        print(json.dumps(summary, indent=2))
        return rec
    repo, info = build_repository(lake, leaf_capacity=16, theta=5,
                                  remove_outliers=False)

    if args.join_sweep:
        rec = {
            "bench": "engine_join",
            "n_datasets": args.datasets,
            "n_devices": jax.device_count(),
            # k=5: the 10th-best join score of a 64-point trajectory probe
            # is typically 0 (few walks cross it), which pins tau at 0 and
            # disables pruning entirely; at k=5 tau is positive and the
            # bound phase actually earns its keep
            "join_sweep": bench_join_sweep(
                repo, lake, 5, repeats=max(2, args.repeats // 2),
                max_batch=args.max_batch),
        }
        js = rec["join_sweep"]
        top = {op: js["ops"][op]["batches"][-1] for op in js["ops"]}
        summary = {
            "n_valid": js["n_valid"],
            "qps_top_batch": {op: round(row["qps"], 1)
                              for op, row in top.items()},
            "speedup_top_batch": {op: round(row["speedup_vs_loop"], 2)
                                  for op, row in top.items()},
            "pruned_fraction": {op: round(row["pruned_fraction"], 3)
                                for op, row in top.items()},
            "serving_qps": round(js["serving"]["qps"], 1),
            "serving_mean_batch": round(js["serving"]["mean_batch"], 2),
        }
        rec["summary"] = summary
        Path(args.out).write_text(json.dumps(rec, indent=2))
        print(json.dumps(summary, indent=2))
        return rec

    if args.replica_sweep:
        eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
        rec = {
            "bench": "engine_replica",
            "n_datasets": args.datasets,
            "n_devices": jax.device_count(),
            "replica_scaling": bench_replica_scaling(
                repo, lake, 10, eps, repeats=max(2, args.repeats // 2),
                max_batch=args.max_batch),
        }
        summary = {
            "replica_qps_monotonic":
                rec["replica_scaling"]["replica_qps_monotonic"],
            "qps_projected": {
                str(row["replicas"]): round(row["qps_projected_parallel"], 1)
                for row in rec["replica_scaling"]["sweep"]},
            "qps_serving": {
                str(row["replicas"]): round(row["serving"]["qps"], 1)
                for row in rec["replica_scaling"]["sweep"]},
        }
        rec["summary"] = summary
        Path(args.out).write_text(json.dumps(rec, indent=2))
        print(json.dumps(summary, indent=2))
        return rec
    # result cache OFF: the sweeps repeat identical inputs to time
    # dispatch, which the result LRU would short-circuit
    if args.sharded:
        engine = ShardedQueryEngine(repo, result_cache_size=0)
        print(f"[bench_engine] sharded: {engine.dispatch.n_shards} shard(s) "
              f"x {engine.dispatch.shard_slots} dataset slots")
    else:
        engine = QueryEngine(repo, result_cache_size=0)
    n_pool = max(BATCHES)
    lo, hi, sigs = _query_pool(repo, lake, n_pool)
    lo_j, hi_j, sigs_j = jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(sigs)
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
    k = 10

    # small exemplar queries for ApproHaus (the serving shape: Q ~ 64 pts)
    q_sets = [lake[i % len(lake)][:64] for i in range(n_pool)]
    q_batch_all = engine.build_queries(q_sets)

    def q_slice(b):
        return jax.tree.map(lambda x: x[:b], q_batch_all)

    ds_ids = np.arange(n_pool, dtype=np.int32) % args.datasets

    ops = {}

    ops["range_search"] = bench_op(
        "range_search",
        lambda i: search.range_search(repo, lo_j[i], hi_j[i])[0],
        lambda b: engine.range_search(lo[:b], hi[:b]),
        n_pool, repeats=args.repeats,
    )
    ops["topk_ia"] = bench_op(
        "topk_ia",
        lambda i: search.topk_ia(repo, lo_j[i], hi_j[i], k)[0],
        lambda b: engine.topk_ia(lo[:b], hi[:b], k),
        n_pool, repeats=args.repeats,
    )
    ops["topk_gbo"] = bench_op(
        "topk_gbo",
        lambda i: search.topk_gbo(repo, sigs_j[i], k)[0],
        lambda b: engine.topk_gbo(sigs[:b], k),
        n_pool, repeats=args.repeats,
    )
    ops["topk_hausdorff_approx"] = bench_op(
        "topk_hausdorff_approx",
        lambda i: search.topk_hausdorff_approx(
            repo, jax.tree.map(lambda x: x[i], q_batch_all), k, eps)[0],
        lambda b: engine.topk_hausdorff_approx(q_slice(b), k, eps),
        n_pool, repeats=max(2, args.repeats // 2),
    )
    ops["range_points"] = bench_op(
        "range_points",
        lambda i: point_search.range_points(
            jax.tree.map(lambda x: x[int(ds_ids[i])], repo.ds_index),
            lo_j[i], hi_j[i])[0],
        lambda b: engine.range_points(ds_ids[:b], lo[:b], hi[:b]),
        n_pool, repeats=args.repeats,
    )

    exact = None
    if args.sharded:
        # single-query ExactHaus across shard counts: latency + per-device
        # resident repository memory (the scale-out win of the sharded
        # branch-and-bound; no replicated copy remains)
        qi = jax.tree.map(lambda x: x[0], q_batch_all)
        exact = bench_exacthaus(repo, qi, k, max(2, args.repeats // 2))

    # batched ExactHaus QPS sweep (both modes): one shared phase-2 work
    # frontier per dispatch vs the per-query dispatch loop
    if args.sharded:
        exact_ctor = lambda r: ShardedQueryEngine(r, result_cache_size=0)
    else:
        exact_ctor = lambda r: QueryEngine(r, result_cache_size=0)
    exact_batched = bench_exacthaus_batched(
        exact_ctor, max(2, args.repeats // 2), max_batch=args.max_batch)

    # mixed-op declarative batches through the unified search() entry
    # point vs the per-op grouped-dispatch loop, on the main corpus
    mixed = bench_mixed_ops(engine, repo, lake, k, eps,
                            max(2, args.repeats // 2),
                            max_batch=args.max_batch)

    # fused all-levels bound pass vs the per-level composition (the
    # ExactHaus phase-0/1 hot path), on the main corpus query batch
    bound_phases = bench_bound_phases(repo, q_batch_all, args.repeats,
                                      max_batch=args.max_batch)

    # serving A/B: adaptive queue-depth window vs the static max-wait
    # window, mixed traffic at low and saturating load
    serving = bench_adaptive_serving(engine, repo, lake, k, eps,
                                     max_batch=args.max_batch,
                                     trials=max(7, args.repeats // 2))

    def speedup_at(rec_op, b):
        """(actual_batch, speedup) for the largest swept batch <= b — the
        key is NAMED with the actual batch so a --max-batch smoke record
        can never be misread as a full-size speedup."""
        rows = [r for r in rec_op["batches"] if r["batch"] <= b]
        return (rows[-1]["batch"], rows[-1]["speedup_vs_loop"]) if rows \
            else (None, None)

    summary = {}
    for name, rec_op in ops.items():
        b, s = speedup_at(rec_op, 64)
        summary[f"{name}_speedup_at_{b}"] = s
    b, s = speedup_at(exact_batched, 32)
    summary[f"exact_hausdorff_batched_speedup_at_{b}"] = s
    mrows = [r for r in mixed["batches"] if r["batch"] <= 32]
    if mrows:
        summary[f"mixed_ops_speedup_at_{mrows[-1]['batch']}"] = \
            mrows[-1]["speedup_vs_grouped"]
    brows = [r for r in bound_phases["batches"] if r["batch"] <= 32]
    if brows:
        summary[f"bound_phases_speedup_at_{brows[-1]['batch']}"] = \
            brows[-1]["speedup_vs_legacy"]
    for load, row in serving["loads"].items():
        summary[f"adaptive_qps_ratio_{load}"] = row["adaptive_qps_ratio"]
    if exact is not None and exact["rows"]:
        base_bytes = exact["rows"][0]["per_device_repo_bytes"]
        summary["exacthaus_per_device_mem_ratio_max_shards"] = (
            exact["rows"][-1]["per_device_repo_bytes"] / base_bytes)
    rec = {
        "bench": "engine_qps_sharded" if args.sharded else "engine_qps",
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "sharded": bool(args.sharded),
        "mesh": (
            {"axis": engine.dispatch.axis,
             "n_shards": engine.dispatch.n_shards,
             "shard_slots": engine.dispatch.shard_slots}
            if args.sharded else None
        ),
        "n_datasets": args.datasets,
        "n_slots": info["n_slots"],
        "k": k,
        "ops": ops,
        "exact_hausdorff": exact,
        "exact_hausdorff_batched": exact_batched,
        "mixed_ops": mixed,
        "bound_phases": bound_phases,
        "adaptive_serving": serving,
        "summary": summary,
        "engine_stats": {
            "dispatches": engine.stats.dispatches,
            "cache_hits": engine.stats.cache_hits,
            "cache_misses": engine.stats.cache_misses,
        },
    }
    Path(args.out).write_text(json.dumps(rec, indent=2))
    print(json.dumps(summary, indent=2))
    print(f"wrote {args.out}")
    return rec


if __name__ == "__main__":
    main()
