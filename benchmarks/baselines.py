"""Baseline methods from the paper's comparison set (Section VII).

These are deliberately host-side (numpy, per-dataset loops) implementations
of the prior art Spadas is compared against:
  ScanGBO  [52]  — sequential scan computing grid overlap per dataset
  ScanHaus [47]  — MBR-corner bounds + branch-and-bound over a full scan
  IncHaus  [47]  — incremental R-tree-pair traversal (priority queue)
  BruteHaus      — 'Origin': exact quadratic Hausdorff, no index
  kNN      [59]  — per-query-point NN with early break
  INNE     [12]  — isolation-based NN-ensemble outlier scores
"""
from __future__ import annotations

import heapq

import numpy as np


def brute_hausdorff(q: np.ndarray, d: np.ndarray) -> float:
    dd = np.sqrt(((q[:, None, :] - d[None, :, :]) ** 2).sum(-1))
    return float(dd.min(axis=1).max())


def early_break_hausdorff(q: np.ndarray, d: np.ndarray) -> float:
    """Taha & Hanbury-style early-break scan [59]."""
    cmax = 0.0
    for p in q:
        cmin = np.inf
        for r in d:
            dist = float(np.sqrt(((p - r) ** 2).sum()))
            if dist < cmax:      # this q point cannot raise the max
                cmin = 0.0
                break
            cmin = min(cmin, dist)
        if cmin != np.inf:
            cmax = max(cmax, cmin)
    return cmax


def scan_gbo(q_cells: set, ds_cells: list[set], k: int):
    """ScanGBO [52]: python-set intersection per dataset, full scan."""
    scores = [(len(q_cells & c), i) for i, c in enumerate(ds_cells)]
    scores.sort(key=lambda t: (-t[0], t[1]))
    return scores[:k]


def _mbr(d: np.ndarray):
    return d.min(axis=0), d.max(axis=0)


def _mbr_haus_bounds(q_lo, q_hi, d_lo, d_hi):
    """Corner-enumeration bounds of [47]: 4^dim distance evaluations."""
    dim = q_lo.shape[0]
    corners_q = np.stack(np.meshgrid(
        *[(q_lo[i], q_hi[i]) for i in range(dim)], indexing="ij"),
        -1).reshape(-1, dim)
    corners_d = np.stack(np.meshgrid(
        *[(d_lo[i], d_hi[i]) for i in range(dim)], indexing="ij"),
        -1).reshape(-1, dim)
    dd = np.sqrt(((corners_q[:, None] - corners_d[None]) ** 2).sum(-1))
    # max over q corners of min over d corners upper-bounds H loosely
    ub = float(dd.max())
    lo = np.maximum(q_lo, d_lo)
    hi = np.minimum(q_hi, d_hi)
    gap = np.maximum(np.maximum(q_lo - d_hi, d_lo - q_hi), 0.0)
    lb = float(np.sqrt((gap ** 2).sum()))
    return lb, ub


def scan_haus_topk(q: np.ndarray, datasets: list[np.ndarray], k: int):
    """ScanHaus [47]: MBR bounds to order + prune a full exact scan."""
    q_lo, q_hi = _mbr(q)
    bounds = []
    for i, d in enumerate(datasets):
        d_lo, d_hi = _mbr(d)
        bounds.append((_mbr_haus_bounds(q_lo, q_hi, d_lo, d_hi), i))
    bounds.sort(key=lambda t: t[0][0])
    results: list[tuple[float, int]] = []
    tau = np.inf
    evals = 0
    for (lb, ub), i in bounds:
        if lb > tau and len(results) >= k:
            continue
        h = brute_hausdorff(q, datasets[i])
        evals += 1
        results.append((h, i))
        results.sort()
        if len(results) >= k:
            tau = results[k - 1][0]
    return results[:k], evals


class _KDNode:
    __slots__ = ("lo", "hi", "pts", "left", "right")

    def __init__(self, pts):
        self.pts = pts
        self.lo = pts.min(axis=0)
        self.hi = pts.max(axis=0)
        self.left = self.right = None


def build_kd(pts: np.ndarray, leaf: int = 16) -> _KDNode:
    node = _KDNode(pts)
    if len(pts) > leaf:
        dim = int(np.argmax(node.hi - node.lo))
        order = np.argsort(pts[:, dim])
        mid = len(pts) // 2
        node.left = build_kd(pts[order[:mid]], leaf)
        node.right = build_kd(pts[order[mid:]], leaf)
    return node


def kd_tree_size(node: _KDNode) -> int:
    """Rough index footprint in bytes (boxes + object overhead)."""
    if node is None:
        return 0
    own = node.lo.nbytes + node.hi.nbytes + 64
    return own + kd_tree_size(node.left) + kd_tree_size(node.right)


def _box_min_dist(p, lo, hi):
    g = np.maximum(np.maximum(lo - p, p - hi), 0.0)
    return float(np.sqrt((g * g).sum()))


def kd_nn(root: _KDNode, p: np.ndarray) -> float:
    """Best-first NN in a KD tree (the kNN [59] baseline primitive)."""
    best = np.inf
    heap = [(_box_min_dist(p, root.lo, root.hi), id(root), root)]
    while heap:
        d, _, node = heapq.heappop(heap)
        if d >= best:
            break
        if node.left is None:
            dd = np.sqrt(((node.pts - p) ** 2).sum(-1))
            best = min(best, float(dd.min()))
        else:
            for ch in (node.left, node.right):
                md = _box_min_dist(p, ch.lo, ch.hi)
                if md < best:
                    heapq.heappush(heap, (md, id(ch), ch))
    return best


def inc_haus(q_root: _KDNode, d_root: _KDNode) -> float:
    """IncHaus [47]: incremental pair traversal with per-q-node queues."""
    h = 0.0
    main: list = [(-np.inf, 0, q_root)]
    cnt = 1
    while main:
        neg_ub, _, qn = heapq.heappop(main)
        if qn.left is not None:
            for ch in (qn.left, qn.right):
                heapq.heappush(main, (neg_ub, cnt, ch))
                cnt += 1
            continue
        # leaf: exact max-min against the D tree via kd_nn
        for p in qn.pts:
            h = max(h, kd_nn(d_root, p))
    return h


def knn_scan(q: np.ndarray, d: np.ndarray) -> np.ndarray:
    """kNN [59] baseline for NNP: per-point early-break scan."""
    out = np.empty(len(q))
    for i, p in enumerate(q):
        best = np.inf
        for r in d:
            dd = ((p - r) ** 2).sum()
            if dd < best:
                best = dd
        out[i] = np.sqrt(best)
    return out


def inne_scores(pts: np.ndarray, *, n_ensembles: int = 8, psi: int = 16,
                seed: int = 0) -> np.ndarray:
    """INNE [12]: isolation scores via nearest-neighbor hyperspheres."""
    rng = np.random.default_rng(seed)
    n = len(pts)
    scores = np.zeros(n)
    for _ in range(n_ensembles):
        samp = pts[rng.choice(n, size=min(psi, n), replace=False)]
        dd = np.sqrt(((samp[:, None] - samp[None]) ** 2).sum(-1))
        np.fill_diagonal(dd, np.inf)
        radius = dd.min(axis=1)                      # NN radius per center
        d_to_c = np.sqrt(((pts[:, None] - samp[None]) ** 2).sum(-1))
        covered = d_to_c <= radius[None, :]
        ratio = np.where(
            covered, radius[np.argmin(d_to_c, axis=1)][:, None] /
            np.maximum(dd.min(axis=1)[None, :], 1e-12), 1.0)
        scores += np.where(covered.any(axis=1), 1 - ratio.min(axis=1), 1.0)
    return scores / n_ensembles
