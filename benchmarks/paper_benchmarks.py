"""One benchmark per paper table/figure (Section VII), Spadas vs baselines.

Each function returns a list of CSV rows ``(name, us_per_call, derived)``.
Sizes are scaled to this CPU container; the RATIOS (Spadas vs Scan*) are
the reproduction target, not absolute times.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import baselines as BL
from repro.core import point_search, search, zorder
from repro.core.build import build_query_index, build_repository
from repro.data import synthetic
from repro.kernels import ops


def _timeit(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
            else x, out)
    return (time.perf_counter() - t0) / repeat * 1e6, out


def _timeit_host(fn, *args, repeat=1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6, out


def _repo(name="multiopen", m=200, theta=5, f=16, outliers=True):
    datasets = synthetic.REPOSITORIES[name](m)
    repo, info = build_repository(datasets, leaf_capacity=f, theta=theta,
                                  remove_outliers=outliers)
    return datasets, repo, info


def _cells_of(datasets, repo, theta):
    """python-set z-order cells per dataset (for the ScanGBO baseline)."""
    out = []
    for d in datasets:
        ids = np.asarray(zorder.cell_ids(
            jnp.asarray(d), repo.space_lo, repo.space_hi, theta))
        out.append(set(ids.tolist()))
    return out


# ---------------------------------------------------------------------------
# Fig. 9 — seven main steps
# ---------------------------------------------------------------------------


def bench_fig9_overview(m=150):
    rows = []
    datasets = synthetic.REPOSITORIES["multiopen"](m)
    us, (repo_info) = _timeit_host(
        lambda: build_repository(datasets, leaf_capacity=16, theta=5), repeat=1)
    repo, info = repo_info
    rows.append(("fig9/index_construction", us, f"m={m}"))

    Q = datasets[3]
    q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=5)
    qlo, qhi = jnp.asarray(Q.min(0)[:2]), jnp.asarray(Q.max(0)[:2])

    us, _ = _timeit(search.range_search, repo, qlo, qhi)
    rows.append(("fig9/RangeS", us, ""))
    us, _ = _timeit(search.topk_ia, repo, qlo, qhi, 10)
    rows.append(("fig9/IA", us, "k=10"))
    us, _ = _timeit(search.topk_gbo, repo, q_sig, 10)
    rows.append(("fig9/GBO", us, "k=10"))
    us, _ = _timeit_host(search.topk_hausdorff, repo, q_idx, 10)
    rows.append(("fig9/ExactHaus", us, "k=10"))
    d_idx = jax.tree.map(lambda x: x[0], repo.ds_index)
    us, _ = _timeit(point_search.range_points, d_idx, qlo, qhi)
    rows.append(("fig9/RangeP", us, ""))
    us, _ = _timeit(point_search.nnp, q_idx, d_idx)
    rows.append(("fig9/NNP", us, ""))
    return rows


# ---------------------------------------------------------------------------
# Fig. 10 — index construction time/space vs m, unified vs dedicated
# ---------------------------------------------------------------------------


def bench_fig10_index_cost(ms=(50, 100, 200)):
    rows = []
    for m in ms:
        datasets = synthetic.REPOSITORIES["tdrive"](m)
        us, (repo, info) = _timeit_host(
            lambda: build_repository(datasets, leaf_capacity=16, theta=5),
            repeat=1)
        unified_bytes = sum(
            x.nbytes for x in jax.tree.leaves(repo)
            if hasattr(x, "nbytes"))
        rows.append((f"fig10/unified_build_m{m}", us, f"bytes={unified_bytes}"))

        t0 = time.perf_counter()
        trees = [BL.build_kd(d) for d in datasets]
        us_kd = (time.perf_counter() - t0) * 1e6
        kd_bytes = sum(BL.kd_tree_size(t) for t in trees) + sum(
            d.nbytes for d in datasets)
        rows.append((f"fig10/dedicated_build_m{m}", us_kd,
                     f"bytes={kd_bytes}"))
    return rows


# ---------------------------------------------------------------------------
# Figs. 11-13 — overlap-based top-k
# ---------------------------------------------------------------------------


def bench_fig11_overlap_topk(m=200, ks=(10, 30, 50)):
    rows = []
    datasets, repo, info = _repo(m=m)
    Q = datasets[3]
    q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=5)
    qlo, qhi = jnp.asarray(Q.min(0)[:2]), jnp.asarray(Q.max(0)[:2])
    cells = _cells_of(datasets, repo, 5)
    q_cells = set(np.asarray(zorder.cell_ids(
        jnp.asarray(Q), repo.space_lo, repo.space_hi, 5)).tolist())
    for k in ks:
        us, _ = _timeit(search.topk_ia, repo, qlo, qhi, k)
        rows.append((f"fig11/IA_k{k}", us, ""))
        us, _ = _timeit(search.topk_gbo, repo, q_sig, k)
        rows.append((f"fig11/GBO_k{k}", us, ""))
        us, _ = _timeit_host(BL.scan_gbo, q_cells, cells, k, repeat=3)
        rows.append((f"fig11/ScanGBO_k{k}", us, ""))
    return rows


def bench_fig12_leaf_capacity(m=150, fs=(10, 30, 50)):
    rows = []
    datasets = synthetic.REPOSITORIES["multiopen"](m)
    for f in fs:
        repo, info = build_repository(datasets, leaf_capacity=f, theta=5)
        Q = datasets[3]
        q_idx, q_sig = build_query_index(
            Q, leaf_capacity=f, space_lo=repo.space_lo,
            space_hi=repo.space_hi, theta=5)
        qlo, qhi = jnp.asarray(Q.min(0)[:2]), jnp.asarray(Q.max(0)[:2])
        us, _ = _timeit(search.topk_ia, repo, qlo, qhi, 10)
        rows.append((f"fig12/IA_f{f}", us, ""))
        us, _ = _timeit(search.topk_gbo, repo, q_sig, 10)
        rows.append((f"fig12/GBO_f{f}", us, ""))
    return rows


def bench_fig13_resolution(m=150, thetas=(3, 5, 7)):
    rows = []
    datasets = synthetic.REPOSITORIES["multiopen"](m)
    for th in thetas:
        repo, info = build_repository(datasets, leaf_capacity=16, theta=th)
        Q = datasets[3]
        _, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=th)
        us, _ = _timeit(search.topk_gbo, repo, q_sig, 10)
        rows.append((f"fig13/GBO_theta{th}", us,
                     f"sig_words={zorder.num_words(th)}"))
    return rows


# ---------------------------------------------------------------------------
# Figs. 14-15, 17 — Hausdorff top-k: exact, approximate, accuracy
# ---------------------------------------------------------------------------


def bench_fig14_exact_haus(m=100, ks=(10, 30, 50)):
    rows = []
    datasets, repo, info = _repo(name="tdrive", m=m)
    Q = datasets[3]
    q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=5)
    d_trees = None
    for k in ks:
        us, (vals, ids, stats) = _timeit_host(
            search.topk_hausdorff, repo, q_idx, k)
        rows.append((f"fig14/ExactHaus_k{k}", us,
                     f"exact_evals={stats.exact_evaluations}"))
        us_s, (res, evals) = _timeit_host(
            BL.scan_haus_topk, Q, datasets, k)
        rows.append((f"fig14/ScanHaus_k{k}", us_s, f"exact_evals={evals}"))
        if k == ks[0]:
            # IncHaus once (expensive): pairwise traversal over candidates
            if d_trees is None:
                q_tree = BL.build_kd(Q)
                d_trees = [BL.build_kd(d) for d in datasets[:m]]
            t0 = time.perf_counter()
            hs = [BL.inc_haus(q_tree, t) for t in d_trees]
            us_i = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig14/IncHaus_k{k}", us_i, "full_scan_traversal"))
            # correctness cross-check on top-1
            top1 = float(np.sort(np.asarray(vals))[0])
            rows.append((f"fig14/check_top1", 0.0,
                         f"spadas={top1:.4f},inchaus={min(hs):.4f}"))
    return rows


def bench_fig15_appro_haus(m=100, thetas=(3, 4, 5, 6)):
    rows = []
    datasets, repo, info = _repo(name="tdrive", m=m)
    Q = datasets[3]
    q_idx, _ = build_query_index(Q, space_lo=repo.space_lo,
                                 space_hi=repo.space_hi, theta=5)
    d_idx = jax.tree.map(lambda x: x[7], repo.ds_index)
    for th in thetas:
        eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, th))
        us, h = _timeit(search.hausdorff_pair_approx, q_idx, d_idx, eps)
        rows.append((f"fig15/pairApproHaus_theta{th}", us,
                     f"eps={eps:.3f}"))
        us, _ = _timeit_host(search.topk_hausdorff_approx, repo, q_idx, 10,
                             eps, repeat=3)
        rows.append((f"fig15/topkApproHaus_theta{th}", us, f"eps={eps:.3f}"))
    return rows


def bench_fig17_accuracy(m=100, k=10):
    rows = []
    datasets, repo, info = _repo(name="multiopen", m=m)
    Q = datasets[3]
    q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=5)
    vals_e, ids_e, _ = search.topk_hausdorff(repo, q_idx, k)
    truth = set(np.asarray(ids_e).tolist())
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))

    us_a, (vals_a, ids_a, _) = _timeit_host(
        search.topk_hausdorff_approx, repo, q_idx, k, eps, repeat=3)
    acc_a = len(truth & set(np.asarray(ids_a).tolist())) / k
    rows.append((f"fig17/ApproHaus", us_a, f"acc={acc_a:.2f}"))

    us_g, (vals_g, ids_g) = _timeit(search.topk_gbo, repo, q_sig, k)
    acc_g = len(truth & set(np.asarray(ids_g).tolist())) / k
    rows.append((f"fig17/GBO", us_g, f"acc={acc_g:.2f}"))

    us_e, _ = _timeit_host(search.topk_hausdorff, repo, q_idx, k, repeat=3)
    rows.append((f"fig17/ExactHaus", us_e, "acc=1.00"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 18 — outlier removal vs INNE
# ---------------------------------------------------------------------------


def bench_fig18_outliers(m=60):
    rows = []
    datasets = synthetic.poi_repository(m, seed=7, outlier_frac=0.02)
    t0 = time.perf_counter()
    repo, info = build_repository(datasets, leaf_capacity=16, theta=5,
                                  remove_outliers=True)
    us_ours = (time.perf_counter() - t0) * 1e6
    removed = int(np.asarray(repo.ds_valid[:m]).sum())
    n_before = sum(len(d) for d in datasets)
    n_after = int(np.asarray(repo.ds_index.valid).sum())
    rows.append(("fig18/spadas_outlier_removal", us_ours,
                 f"points_removed={n_before - n_after}"))

    t0 = time.perf_counter()
    inne_removed = 0
    for d in datasets[:8]:        # INNE is orders of magnitude slower
        scores = BL.inne_scores(d)
        inne_removed += int((scores > 0.9).sum())
    us_inne = (time.perf_counter() - t0) * 1e6 * (m / 8)
    rows.append(("fig18/INNE(extrapolated)", us_inne,
                 f"flagged_in_8={inne_removed}"))
    return rows


# ---------------------------------------------------------------------------
# Figs. 19-21 — pairwise Hausdorff vs f; dimensions
# ---------------------------------------------------------------------------


def bench_fig19_pairwise(fs=(10, 30, 50)):
    rows = []
    datasets = synthetic.REPOSITORIES["tdrive"](20)
    Q, D = datasets[0], datasets[1]
    for f in fs:
        q_idx, _ = build_query_index(Q, leaf_capacity=f)
        d_idx, _ = build_query_index(D, leaf_capacity=f)
        us, (h, pruned) = _timeit(search.hausdorff_pair_exact, q_idx, d_idx)
        rows.append((f"fig19/pairExact_f{f}", us,
                     f"pruned={float(pruned):.2f}"))
    us, h = _timeit_host(BL.brute_hausdorff, Q, D, repeat=3)
    rows.append(("fig19/Origin_brute", us, f"h={h:.4f}"))
    us, h = _timeit_host(BL.early_break_hausdorff, Q, D)
    rows.append(("fig19/EarlyBreak[59]", us, f"h={h:.4f}"))
    return rows


def bench_fig21_dimension(ds=(2, 5, 8, 11), m=60):
    rows = []
    for d in ds:
        datasets = synthetic.highdim_repository(m, d=max(d, 2), seed=4)
        datasets = [x[:, :d] for x in datasets]
        repo, info = build_repository(datasets, leaf_capacity=16, theta=5)
        Q = datasets[3]
        q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                         space_hi=repo.space_hi, theta=5)
        # range ops use the full d-dim MBR (IA itself is the 2-D area term)
        qlo, qhi = jnp.asarray(Q.min(0)), jnp.asarray(Q.max(0))
        us, _ = _timeit(search.topk_ia, repo, qlo, qhi, 10)
        rows.append((f"fig21/IA_d{d}", us, ""))
        us, _ = _timeit(search.topk_gbo, repo, q_sig, 10)
        rows.append((f"fig21/GBO_d{d}", us, ""))
        us, (v, i, stats) = _timeit_host(search.topk_hausdorff, repo, q_idx,
                                         10, repeat=1)
        rows.append((f"fig21/ExactHaus_d{d}", us,
                     f"pruned={stats.pruned_fraction:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# Figs. 22-23 — point search
# ---------------------------------------------------------------------------


def bench_fig22_rangep(scales=(1, 3, 5)):
    rows = []
    datasets = synthetic.REPOSITORIES["porto"](40)
    repo, info = build_repository(datasets, leaf_capacity=16, theta=5)
    d_idx = jax.tree.map(lambda x: x[0], repo.ds_index)
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
    c = np.asarray(d_idx.centers[0])
    for s in scales:
        lo = jnp.asarray(c - s * eps)
        hi = jnp.asarray(c + s * eps)
        us, (mask, stats) = _timeit(point_search.range_points, d_idx, lo, hi)
        rows.append((f"fig22/RangeP_R{s}eps", us,
                     f"hits={int(np.asarray(mask).sum())}"))
    return rows


def bench_fig23_nnp(ss=(1, 4, 16)):
    rows = []
    datasets = synthetic.REPOSITORIES["porto"](40)
    repo, info = build_repository(datasets, leaf_capacity=16, theta=5)
    d_idx = jax.tree.map(lambda x: x[0], repo.ds_index)
    D = datasets[0]
    for s in ss:
        Q = np.concatenate(datasets[1 : 1 + s])[:2048]
        q_idx, _ = build_query_index(Q)
        us, _ = _timeit(point_search.nnp, q_idx, d_idx)
        rows.append((f"fig23/NNP_s{s}", us, f"|Q|={len(Q)}"))
        us, _ = _timeit(point_search.nnp_pruned, q_idx, d_idx)
        rows.append((f"fig23/NNP_pruned_s{s}", us, ""))
        if s <= 4:
            us, _ = _timeit_host(BL.knn_scan, Q[:256], D)
            us = us * (len(Q) / 256)
            rows.append((f"fig23/kNN[59](extrap)_s{s}", us, ""))
    return rows


# ---------------------------------------------------------------------------
# online-demo companion metric: top-k EMD [67] (Sec. VII Implementation)
# ---------------------------------------------------------------------------


def bench_emd_topk(m=60, k=10):
    import numpy as np
    from repro.core import emd as emd_lib
    rows = []
    datasets, repo, info = _repo(name="multiopen", m=m)
    Q = jnp.asarray(datasets[3])
    qv = jnp.ones(len(datasets[3]), bool)
    us, (vals, ids) = _timeit(emd_lib.topk_emd, repo, Q, qv, k)
    rows.append(("emd/topk_full", us, f"top1={int(ids[0])}"))
    us, (vals_p, ids_p) = _timeit(
        lambda *a: emd_lib.topk_emd(*a, prefilter=max(16, 2 * k)),
        repo, Q, qv, k)
    agree = len(set(np.asarray(ids).tolist())
                & set(np.asarray(ids_p).tolist())) / k
    rows.append(("emd/topk_prefiltered", us, f"top_k_agree={agree:.2f}"))
    return rows


ALL_BENCHES = [
    bench_fig9_overview,
    bench_fig10_index_cost,
    bench_fig11_overlap_topk,
    bench_fig12_leaf_capacity,
    bench_fig13_resolution,
    bench_fig14_exact_haus,
    bench_fig15_appro_haus,
    bench_fig17_accuracy,
    bench_fig18_outliers,
    bench_fig19_pairwise,
    bench_fig21_dimension,
    bench_fig22_rangep,
    bench_fig23_nnp,
    bench_emd_topk,
]
