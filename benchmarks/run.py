"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Also appends the roofline
summary when dry-run artifacts are present (results/dryrun/).

    PYTHONPATH=src python -m benchmarks.run [--only fig14]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark fn names")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks import paper_benchmarks as pb

    print("name,us_per_call,derived")
    failures = 0
    for fn in pb.ALL_BENCHES:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception:
            traceback.print_exc()
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"# {fn.__name__} wall={time.time()-t0:.1f}s", flush=True)

    if not args.skip_roofline:
        from pathlib import Path
        if Path("results/dryrun").exists() and any(
                Path("results/dryrun").glob("*__single.json")):
            print("\n# === roofline (from dry-run artifacts) ===")
            from benchmarks import roofline
            roofline.main(["--dir", "results/dryrun", "--mesh", "single"])

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
