"""mamba2-780m [ssm] — SSD state-space duality [arXiv:2405.21060].

48L d_model=1536, attention-free (d_ff=0: pure Mamba-2 stack), vocab 50280,
ssm_state=128.  Runs the long_500k cell (O(1) decode state).
"""
import dataclasses
from repro.models.config import ModelConfig, MAMBA

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=24,          # unused (attention-free); kept for config parity
    n_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    block_pattern=(MAMBA,),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        vocab_size=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
        remat=False)
