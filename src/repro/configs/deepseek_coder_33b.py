"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196]."""
import dataclasses
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    block_pattern=(ATTN,),
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, remat=False, attn_q_chunk=64, attn_kv_chunk=64)
