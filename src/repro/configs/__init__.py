"""Assigned architecture configs (one module per arch) + registry.

Every config is from public literature; the ``[source]`` tag from the
assignment is recorded in each module.  ``get(name)`` returns the full
config; ``get_reduced(name)`` returns the same-family shrunken config used
by the CPU smoke tests (few layers/width/experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "mamba2_780m",
    "grok_1_314b",
    "arctic_480b",
    "internlm2_20b",
    "yi_9b",
    "llama3_8b",
    "deepseek_coder_33b",
    "musicgen_medium",
    "jamba_v0_1_52b",
    "llama3_2_vision_11b",
    "spadas_trajlm",          # paper-native: trajectory LM over spatial data
]


def normalize(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.CONFIG


def get_reduced(name: str):
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.reduced()


def all_configs():
    return {a: get(a) for a in ARCH_IDS}
