"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].  The EnCodec frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings (embed_input=False);
the backbone + 2048-way codebook head are real.
"""
import dataclasses
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,     # kv=24 -> MHA
    d_ff=6144,
    vocab_size=2048,
    block_pattern=(ATTN,),
    embed_input=False,
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=128, remat=False, attn_q_chunk=64, attn_kv_chunk=64)
