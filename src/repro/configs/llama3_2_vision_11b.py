"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].  The vision encoder is a STUB per the
assignment: input_specs() provides precomputed patch embeddings
(batch, vision_tokens, d_model); the text backbone with gated cross-attn
every 5th layer is real.
"""
import dataclasses
from repro.models.config import ModelConfig, ATTN, CROSS

_PATTERN = (ATTN, ATTN, ATTN, ATTN, CROSS)

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=_PATTERN,
    vision_tokens=1024,
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, vision_tokens=16, remat=False,
        attn_q_chunk=64, attn_kv_chunk=64)
