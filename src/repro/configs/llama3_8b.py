"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
import dataclasses
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=(ATTN,),
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, remat=False, attn_q_chunk=64, attn_kv_chunk=64)
