"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1]."""
import dataclasses
from repro.models.config import ModelConfig, ATTN_MOE

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    block_pattern=(ATTN_MOE,),
    n_experts=8,
    top_k_experts=2,
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, n_experts=4, top_k_experts=2, remat=False,
        attn_q_chunk=64, attn_kv_chunk=64)
