"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
import dataclasses
from repro.models.config import ModelConfig, ATTN_MOE_DENSE

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    block_pattern=(ATTN_MOE_DENSE,),
    n_experts=128,
    top_k_experts=2,
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab_size=512, n_experts=8, top_k_experts=2, remat=False,
        attn_q_chunk=64, attn_kv_chunk=64)
