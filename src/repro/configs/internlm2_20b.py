"""internlm2-20b [dense] — GQA [arXiv:2403.17297]."""
import dataclasses
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    block_pattern=(ATTN,),
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, remat=False, attn_q_chunk=64, attn_kv_chunk=64)
