"""spadas-trajlm — paper-native config: a small trajectory LM trained on
z-order-tokenized spatial data curated by the Spadas index (the end-to-end
driver of examples/train_lm.py).  Vocab = 4^theta Morton cells + specials.
"""
import dataclasses
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="spadas-trajlm",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=4160,   # 4^6 cells + 64 specials
    block_pattern=(ATTN,),
    tie_embeddings=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=1088, remat=False, attn_q_chunk=64, attn_kv_chunk=64)
