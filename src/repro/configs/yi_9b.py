"""yi-9b [dense] — llama-arch GQA [arXiv:2403.04652]."""
import dataclasses
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    block_pattern=(ATTN,),
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, remat=False, attn_q_chunk=64, attn_kv_chunk=64)
