"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].  Block pattern of 8 layers: attention at position 4,
Mamba elsewhere; MoE on every other layer (odd positions).  Runs the
long_500k cell (KV cache only at 4/32 layers).
"""
import dataclasses
from repro.models.config import (ModelConfig, ATTN_MOE, MAMBA, MAMBA_MOE)

_PATTERN = (
    MAMBA, MAMBA_MOE, MAMBA, MAMBA_MOE,
    ATTN_MOE, MAMBA_MOE, MAMBA, MAMBA_MOE,
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=_PATTERN,
    n_experts=16,
    top_k_experts=2,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, n_experts=4, top_k_experts=2, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=32, remat=False,
        attn_q_chunk=64, attn_kv_chunk=64)
