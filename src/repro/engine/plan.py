"""Planner for `QueryEngine.search`: mixed declarative batches -> dispatches.

`execute` compiles a mixed ``list[Query | Pipeline]`` into per-(op,
static-params, query-shape) :class:`DispatchGroup`\\ s and runs each group
through the engine's per-op executor (``engine._exec_<op>``) — so every
group rides the existing bucket ladder, executable cache, and result cache
(cache hits short-circuit per row inside the executor, exactly as they do
for the legacy batch methods).  Results are scattered back into INPUT
order; the number of device dispatches is one per group (plus one per
grouped query-index build), never one per query.

Pipelines run in two stages:

  * **stage 1** — each pipeline's ``dataset_stage`` is planned as an
    ordinary row of its op's dispatch group, so pipeline stage-1 queries
    and standalone queries of the same (op, statics) share ONE dispatch;
  * **stage 2** — the winning dataset ids feed ``range_points`` / ``nnp``
    with the id handoff staying ON DEVICE (the planner slices the ids out
    of the stage-1 dispatch output BEFORE any host materialization; ``-1``
    sentinel winners are clamped to slot 0 for the gather and masked out
    of the result).  Stage-2 rows group across pipelines by (point op,
    statics, built query capacity), so P pipelines with compatible point
    stages cost one dispatch of ``sum(k_p)`` rows.  A joinable stage-2
    (``topk_overlap`` / ``topk_coverage`` — the dataset→dataset pipeline)
    takes the same handoff: winner slots are gathered by id on device and
    exactly re-scored against the stage's query set in one grouped
    dispatch, then re-ranked host-side to the stage's top-k (descending
    score, ties keeping stage-1 rank; sentinel winners score ``-1`` and
    stay sentinels).

Grouping keys are host-side only (op tags, static scalars, array shapes) —
planning never syncs device values.  Per-row payload marshalling is
host-side too: group payloads are stacked in NUMPY and uploaded as ONE
array per operand, and dispatch outputs are materialized once per group
and split into free numpy row views — per-query Python cost stays in the
microseconds instead of paying a device-op round trip per row (jax eager
dispatch overhead is ~100us/op on CPU, which would dwarf small-op
dispatches at batch 64+).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.engine.query import (DATASET_RERANK_OPS, Pipeline, Query,
                                SearchResult)


@dataclass
class DispatchGroup:
    """Rows of one batched dispatch: same op, same statics, same query
    shape signature.  ``rows`` are positions in the caller's input list."""

    op: str
    statics: tuple
    shape_sig: tuple
    rows: list = field(default_factory=list)
    queries: list = field(default_factory=list)


def plan(items, leaf_capacity: int = 16) -> list[DispatchGroup]:
    """Group a mixed batch into stage-1 dispatch groups (first-seen order;
    a Pipeline contributes its ``dataset_stage`` here)."""
    groups: "OrderedDict[tuple, DispatchGroup]" = OrderedDict()
    for pos, item in enumerate(items):
        q = item.dataset_stage if isinstance(item, Pipeline) else item
        key = (q.op, q.statics(), q.query_shape_sig(leaf_capacity))
        g = groups.get(key)
        if g is None:
            g = groups[key] = DispatchGroup(q.op, key[1], key[2])
        g.rows.append(pos)
        g.queries.append(q)
    return list(groups.values())


def count_groups(items, leaf_capacity: int = 16) -> int:
    """Number of dispatch groups `execute` would compile for a batch:
    stage-1 op groups + distinct pipeline stage-2 groups.  Host-side
    only — lets observers (the serving front-end) book group counts
    without racing on the engine's shared counters."""
    s2 = {_stage2_key(it.point_stage, leaf_capacity)
          for it in items if isinstance(it, Pipeline)}
    return len(plan(items, leaf_capacity)) + len(s2)


def execute(engine, items) -> list:
    """Run a mixed batch through the engine; one SearchResult per input."""
    items = list(items)
    for it in items:
        if not isinstance(it, (Query, Pipeline)):
            raise TypeError(
                f"search() takes Query/Pipeline items, got {type(it)!r}")
        # a STANDALONE point query must name its dataset; only a
        # Pipeline's point stage may leave ds_id None (filled from the
        # stage-1 winners) — catch it here with a clear message instead
        # of an opaque asarray failure inside the group marshalling
        if (isinstance(it, Query) and it.op in ("range_points", "nnp")
                and it.ds_id is None):
            raise ValueError(
                f"Query(op={it.op!r}) requires ds_id outside a Pipeline "
                f"point stage")
    results: list = [None] * len(items)
    stage1: dict = {}          # input pos -> stage-1 SearchResult
    handoffs: dict = {}        # input pos -> device (k,) winner-id row
    for g in plan(items, engine.leaf_capacity):
        # subgroups: replica row-blocks this group's rows span (1 unless
        # the dispatcher splits rows across replica groups)
        engine.stats.count_group(g.op, engine._plan_subgroups(len(g.rows)))
        t0 = time.perf_counter()
        rows, ids_dev = _run_group(engine, g)
        engine.stats.record_latency(g.op, time.perf_counter() - t0)
        for j, (pos, res) in enumerate(zip(g.rows, rows)):
            if isinstance(items[pos], Pipeline):
                stage1[pos] = res
                handoffs[pos] = ids_dev[j]      # device slice: the handoff
            else:
                results[pos] = res
    if stage1:
        engine.stats.pipeline_stage1 += len(stage1)
        _run_stage2(engine, items, stage1, handoffs, results)
    return results


# ---------------------------------------------------------------------------
# stage 1 / plain groups
# ---------------------------------------------------------------------------


def _stack_boxes(queries, attr):
    """(B, d) operand from per-query host rows: ONE numpy stack, no
    per-row device ops (the executor does the single upload)."""
    return np.stack([np.asarray(getattr(q, attr), np.float32)
                     for q in queries])


def _split(x) -> list:
    """Materialize a dispatch output once and split it into free numpy
    row views."""
    a = np.asarray(x)
    return [a[i] for i in range(a.shape[0])]


def _group_q_batch(engine, queries):
    """The group's (B, ...) query-index batch: pre-built rows are stacked
    shape-exactly on the host (the group key guarantees equal
    capacity/depth; one upload per leaf at dispatch), raw point sets go
    through ONE grouped `build_queries` (padded to the group's common
    capacity, exactly like the serving front-end built grouped
    requests)."""
    if queries[0].q_index is not None:
        return jax.tree.map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]),
            *[q.q_index for q in queries])
    return engine.build_queries([np.asarray(q.q) for q in queries])


def _run_group(engine, g: DispatchGroup):
    """Run one dispatch group; returns (per-row SearchResults, device
    top-k id batch or None).  The device id batch is kept UNSPLIT so a
    pipeline's stage-2 handoff can slice it without the ids ever visiting
    the host."""
    op, qs = g.op, g.queries
    n = len(qs)
    if op == "range_search":
        masks = engine._exec_range_search(
            _stack_boxes(qs, "r_lo"), _stack_boxes(qs, "r_hi"))
        return [SearchResult(op=op, mask=m) for m in _split(masks)], None
    if op == "topk_ia":
        vals, ids = engine._exec_topk_ia(
            _stack_boxes(qs, "r_lo"), _stack_boxes(qs, "r_hi"), qs[0].k)
        return [SearchResult(op=op, vals=v, ids=i)
                for v, i in zip(_split(vals), _split(ids))], ids
    if op == "topk_gbo":
        sigs = np.stack([np.asarray(q.q_sig) for q in qs])
        vals, ids = engine._exec_topk_gbo(sigs, qs[0].k)
        return [SearchResult(op=op, vals=v, ids=i)
                for v, i in zip(_split(vals), _split(ids))], ids
    if op == "topk_hausdorff_approx":
        q_batch = _group_q_batch(engine, qs)
        vals, ids, eps_eff = engine._exec_topk_hausdorff_approx(
            q_batch, qs[0].k, qs[0].eps)
        return [SearchResult(op=op, vals=v, ids=i, extras={"eps_eff": e})
                for v, i, e in zip(_split(vals), _split(ids),
                                   _split(eps_eff))], ids
    if op == "topk_hausdorff":
        q_batch = _group_q_batch(engine, qs)
        vals, ids, stats = engine._exec_topk_hausdorff(
            q_batch, qs[0].k, qs[0].refine_levels, qs[0].chunk)
        return [SearchResult(op=op, vals=v, ids=i, stats=s)
                for v, i, s in zip(_split(vals), _split(ids),
                                   stats)], ids
    if op == "range_points":
        ds = np.asarray([q.ds_id for q in qs], np.int32)
        take, stats = engine._exec_range_points(
            ds, _stack_boxes(qs, "r_lo"), _stack_boxes(qs, "r_hi"))
        return [SearchResult(op=op, mask=m, stats=s)
                for m, s in zip(_split(take), stats)], None
    if op == "nnp":
        ds = np.asarray([q.ds_id for q in qs], np.int32)
        q_batch = _group_q_batch(engine, qs)
        dists, idxs, stats = engine._exec_nnp(ds, q_batch)
        valid = _split(q_batch.valid)
        return [SearchResult(op=op, vals=d, ids=i, mask=m, stats=s)
                for d, i, m, s in zip(_split(dists), _split(idxs),
                                      valid, stats)], None
    if op in DATASET_RERANK_OPS:
        pts, val = _stack_pointsets(
            [q.q for q in qs],
            max(q.built_capacity(engine.leaf_capacity) for q in qs))
        vals, ids, stats = engine._exec_topk_join(op, pts, val, qs[0].k)
        return [SearchResult(op=op, vals=v, ids=i, stats=s)
                for v, i, s in zip(_split(vals), _split(ids),
                                   stats)], ids
    raise ValueError(f"unplannable op {op!r}")  # pragma: no cover


def _stack_pointsets(pointsets, cap: int):
    """(B, cap, d) points + (B, cap) validity from raw per-query sets —
    the joinable ops score on the shared grid, so no tree build: ONE
    numpy pad/stack, one upload at dispatch.  Padding rows are invalid
    and park in the grid's overflow cell, so any two groupings of the
    same query produce bit-identical scores."""
    sets = [np.asarray(ps, np.float32) for ps in pointsets]
    pts = np.zeros((len(sets), cap, sets[0].shape[-1]), np.float32)
    val = np.zeros((len(sets), cap), bool)
    for i, s in enumerate(sets):
        pts[i, :s.shape[0]] = s
        val[i, :s.shape[0]] = True
    return pts, val


# ---------------------------------------------------------------------------
# stage 2: pipeline point queries over the stage-1 winners
# ---------------------------------------------------------------------------


def _stage2_key(ps: Query, leaf_capacity: int) -> tuple:
    """Grouping key for a pipeline's point stage — host-side shape math
    only, so multiple pipelines share one stage-2 dispatch whenever their
    built query trees are shape-compatible."""
    if ps.op == "nnp":
        cap = ps.built_capacity(leaf_capacity)
        if ps.q_index is not None:
            depth = ps.q_index.depth
        else:
            depth = index_lib.depth_for(cap, leaf_capacity)
        return (ps.op, ps.statics(), cap, depth)
    if ps.op in DATASET_RERANK_OPS:
        # joinable re-rank rows stack raw padded point sets: the key pins
        # the padded capacity so the group's stack is shape-exact
        return (ps.op, ps.statics(), ps.built_capacity(leaf_capacity))
    return (ps.op, ps.statics())


def _run_stage2(engine, items, stage1, handoffs, results) -> None:
    groups: "OrderedDict[tuple, list[int]]" = OrderedDict()
    for pos in stage1:
        groups.setdefault(
            _stage2_key(items[pos].point_stage, engine.leaf_capacity),
            []).append(pos)
    for key, poss in groups.items():
        pop = key[0]
        ks = [items[pos].dataset_stage.k for pos in poss]
        total = int(sum(ks))
        engine.stats.count_group(pop, engine._plan_subgroups(total))
        t0 = time.perf_counter()
        # winner ids, handed off ON DEVICE (sliced from the stage-1
        # dispatch output): -1 sentinels (k past the valid dataset count)
        # are clamped to slot 0 for the gather and masked out below.
        # One concatenate + one compare + one where for the WHOLE group —
        # per-pipeline eager device ops would cost more than the dispatch
        w_flat = jnp.concatenate([handoffs[pos] for pos in poss])
        valid_flat = w_flat >= 0
        ds_flat = jnp.where(valid_flat, w_flat, 0).astype(jnp.int32)
        offs = np.concatenate([[0], np.cumsum(ks)])
        valid_np = np.asarray(valid_flat)
        valid_rows = [valid_np[offs[i]:offs[i + 1]]
                      for i in range(len(poss))]
        if pop == "range_points":
            def _tile_box(pos, k, attr):
                b = np.asarray(getattr(items[pos].point_stage, attr),
                               np.float32)
                return np.broadcast_to(b[None], (k,) + b.shape)

            lo = np.concatenate([_tile_box(pos, k, "r_lo")
                                 for pos, k in zip(poss, ks)])
            hi = np.concatenate([_tile_box(pos, k, "r_hi")
                                 for pos, k in zip(poss, ks)])
            take, stats = engine._exec_range_points(ds_flat, lo, hi)
            take_np = np.asarray(take)
            off = 0
            for pos, k, v in zip(poss, ks, valid_rows):
                results[pos] = SearchResult(
                    op="pipeline",
                    mask=take_np[off:off + k] & v[:, None],
                    stats=stats[off:off + k],
                    extras={"stage1": stage1[pos],
                            "ds_ids": stage1[pos].ids, "valid": v})
                off += k
        elif pop in DATASET_RERANK_OPS:
            # dataset→dataset: exact join score of each winner slot vs the
            # pipeline's query set (one grouped dispatch, ids on device),
            # then a host-side re-rank to the stage's top-k.  Sentinel
            # winners were clamped to slot 0 above; their rows are forced
            # to score -1 here, so a pipeline with ZERO surviving winners
            # degrades to all-sentinel output instead of ranking slot 0
            pts, val = _stack_pointsets(
                [items[pos].point_stage.q for pos in poss], key[2])
            reps = np.asarray(ks, np.int32)
            pts_rep = jnp.repeat(jnp.asarray(pts), reps, axis=0,
                                 total_repeat_length=total)
            val_rep = jnp.repeat(jnp.asarray(val), reps, axis=0,
                                 total_repeat_length=total)
            scores = engine._exec_join_rerank(pop, ds_flat, pts_rep, val_rep)
            s_np = np.asarray(scores)
            off = 0
            for pos, k, v in zip(poss, ks, valid_rows):
                k2 = items[pos].point_stage.k
                seg = np.where(v, s_np[off:off + k], -1).astype(np.int32)
                win = np.asarray(stage1[pos].ids, np.int32)[:k]
                # descending score; stable sort keeps stage-1 rank on ties
                order = np.argsort(-seg, kind="stable")[:k2]
                vals2 = np.full((k2,), -1, np.int32)
                ids2 = np.full((k2,), -1, np.int32)
                vals2[:len(order)] = seg[order]
                ids2[:len(order)] = np.where(vals2[:len(order)] < 0, -1,
                                             win[order])
                results[pos] = SearchResult(
                    op="pipeline", vals=vals2, ids=ids2, mask=vals2 >= 0,
                    extras={"stage1": stage1[pos],
                            "ds_ids": stage1[pos].ids, "valid": v})
                off += k
        else:  # nnp
            rows = _stage2_nnp_rows(engine, items, poss)
            reps = np.asarray(ks, np.int32)
            q_flat = jax.tree.map(
                lambda x: jnp.repeat(x, reps, axis=0,
                                     total_repeat_length=total), rows)
            dists, idxs, stats = engine._exec_nnp(ds_flat, q_flat)
            d_np, i_np = np.asarray(dists), np.asarray(idxs)
            qv_np = np.asarray(q_flat.valid)
            off = 0
            for pos, k, v in zip(poss, ks, valid_rows):
                results[pos] = SearchResult(
                    op="pipeline",
                    vals=d_np[off:off + k],
                    ids=i_np[off:off + k],
                    mask=v[:, None] & qv_np[off:off + k],
                    stats=stats[off:off + k],
                    extras={"stage1": stage1[pos],
                            "ds_ids": stage1[pos].ids, "valid": v})
                off += k
        engine.stats.record_latency(pop, time.perf_counter() - t0)
        engine.stats.pipeline_stage2 += len(poss)


def _stage2_nnp_rows(engine, items, poss):
    """One query-index row per pipeline in the group, as a (P, ...) tree.

    Raw point sets are built in ONE grouped `build_queries` call; the
    group key pins the built capacity to what a solo build would produce,
    so each row is bit-identical to the two-call host baseline's build.
    Pre-built rows are stacked directly."""
    raw = [pos for pos in poss if items[pos].point_stage.q_index is None]
    built = None
    if raw:
        built = engine.build_queries(
            [np.asarray(items[pos].point_stage.q) for pos in raw])
    raw_row = {pos: i for i, pos in enumerate(raw)}
    rows = []
    for pos in poss:
        ps = items[pos].point_stage
        if ps.q_index is None:
            rows.append(jax.tree.map(
                lambda x, i=raw_row[pos]: x[i], built))
        else:
            rows.append(jax.tree.map(jnp.asarray, ps.q_index))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
