"""Replica-parallel serving: dispatch over a 2-D (replica x data) mesh.

The ``data`` mesh axis scales MEMORY: sharding dataset slots across it
shrinks per-device repository bytes, but once the repository fits in D
devices the remaining devices of a larger machine idle.  This module adds
the THROUGHPUT axis: :func:`replica_mesh` arranges R x D devices as a 2-D
mesh with a leading ``replica`` axis, :func:`~repro.engine.sharded.
shard_repository` over that mesh places the slot arrays with
``P("data")`` — sharded over data, and therefore automatically REPLICATED
across the replica axis by the NamedSharding — and
:class:`ReplicatedDispatcher` partitions each batch's query rows over the
replica axis (``row_axis = "replica"``), so every replica group of D
devices runs the complete per-shard pipeline on its own row slice.

Bit-identity with :class:`~repro.engine.engine.LocalDispatcher` holds by
construction, for every replica count and row split:

  * every collective inside the per-shard ops — the O(k) ``all_gather``
    top-k merges, the ApproHaus ``pmin``/``pmax`` scalar reductions, the
    owner-exclusive ``psum`` merges, ExactHaus's batched tau
    ``global_kth_smallest`` all-reduce, and the joinable refine loop's
    integer τ all-reduce + psum'd continue flag — names the ``data`` axis
    only, so inside one replica group the program IS the PR-2/3/4 1-D
    sharded pipeline, unchanged (asserted per op in
    tests/test_engine_replicated.py and by the property suites);
  * per-row computations are independent: a replica group's answers
    depend only on its own rows (ExactHaus's shared phase-2 frontier is
    per-query lockstep — co-resident rows never perturb a row's
    trajectory), so splitting rows across groups, padding the row count
    to a multiple of R by replicating row 0, and concatenating the
    per-group outputs in replica order reproduces the unsplit batch
    exactly;
  * ExactHaus's ``while_loop`` continue flag is psum-reduced over
    ``data`` only, so it is uniform INSIDE each replica group (the
    collectives in the loop body stay deadlock-free) while groups retire
    their rows independently — a group with cheap rows simply exits its
    loop earlier.

The engine stack above is untouched: the same bucket ladder, executable
cache, result cache (which short-circuits BEFORE rows are split), and
planner serve every dispatcher; :class:`~repro.engine.engine.QueryEngine`
selects this dispatcher automatically when the mesh carries a replica
axis.  The planner books how many replica row-blocks each dispatch group
actually spanned through :meth:`ReplicatedDispatcher.row_subgroups`
(``EngineStats.group_counts`` / ``replica_subgroups``).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.repo_index import Repository
from repro.engine.engine import (DEFAULT_BUCKETS, DEFAULT_RESULT_CACHE,
                                 QueryEngine)
from repro.engine.sharded import ShardedDispatcher


def replica_mesh(
    n_replicas: int,
    n_data: int | None = None,
    *,
    replica_axis: str = "replica",
    data_axis: str = "data",
) -> Mesh:
    """A 2-D (replica x data) mesh over the first R x D local devices.

    ``n_data=None`` spreads the non-replica factor over the remaining
    devices (``len(devices) // n_replicas``).  An explicit request larger
    than the platform provides is an error, never a silent smaller mesh —
    same contract as :func:`~repro.engine.sharded.data_mesh`.
    """
    devs = jax.devices()
    if n_replicas < 1:
        raise ValueError(f"replica_mesh: n_replicas must be >= 1, "
                         f"got {n_replicas}")
    if n_data is None:
        n_data = max(1, len(devs) // n_replicas)
    need = n_replicas * n_data
    if need > len(devs):
        raise ValueError(
            f"replica_mesh: {n_replicas} x {n_data} devices requested but "
            f"only {len(devs)} available (on CPU, force more with "
            f"REPRO_HOST_DEVICES / --xla_force_host_platform_device_count "
            f"before jax initializes)")
    grid = np.asarray(devs[:need]).reshape(n_replicas, n_data)
    return Mesh(grid, (replica_axis, data_axis))


class ReplicatedDispatcher(ShardedDispatcher):
    """Sharded dispatch with query rows partitioned over a replica axis.

    Everything op-specific is inherited: the per-shard ``local`` functions
    and their ``data``-scoped collectives are byte-for-byte the 1-D
    sharded ones.  What changes is placement only — ``row_axis`` routes
    each replica group its own row slice (with the base class's generic
    row pad/slice in ``_smap``), and `shard_repository` over the 2-D mesh
    replicates the slot shards across replica groups for free via
    ``P("data")``.
    """

    name = "replicated"

    def __init__(self, repo: Repository, mesh: Mesh, axis: str = "data",
                 replica_axis: str = "replica"):
        if replica_axis not in mesh.axis_names:
            raise ValueError(
                f"ReplicatedDispatcher: mesh has no {replica_axis!r} axis "
                f"(axes: {mesh.axis_names}); build one with replica_mesh()")
        self.row_axis = replica_axis
        super().__init__(repo, mesh, axis=axis)
        self.n_replicas = int(mesh.shape[replica_axis])

    def row_subgroups(self, batch: int, bucket: int) -> int:
        """Replica row-blocks a `batch`-row dispatch at `bucket` rows
        spans: the padded bucket splits into ``n_replicas`` equal blocks,
        and the first ceil(batch / block) of them carry real rows.  The
        planner books this through ``EngineStats.count_group`` so
        ``group_counts`` accounts for replica sub-groups."""
        n_rep = self.n_replicas
        block = ((bucket + n_rep - 1) // n_rep * n_rep) // n_rep
        return min(n_rep, -(-batch // block))


class ReplicatedQueryEngine(QueryEngine):
    """QueryEngine serving from R replica groups of D data shards each.

    Same bucket ladder, executable cache, result cache, query
    construction, planner, and :class:`~repro.engine.engine.EngineStats`
    as every other engine; only dispatch differs.  With no ``mesh``
    given, builds ``replica_mesh(n_replicas, n_data)`` (``n_data=None``
    -> all remaining local devices).  ``n_replicas=1`` degenerates to the
    1-D sharded layout, so the class is safe to use unconditionally.
    """

    def __init__(
        self,
        repo: Repository,
        *,
        n_replicas: int = 1,
        n_data: int | None = None,
        mesh: Mesh | None = None,
        replica_spec: str = "replica",
        shard_spec: str = "data",
        buckets=DEFAULT_BUCKETS,
        leaf_capacity: int = 16,
        result_cache_size: int = DEFAULT_RESULT_CACHE,
    ):
        if mesh is None:
            mesh = replica_mesh(n_replicas, n_data,
                                replica_axis=replica_spec,
                                data_axis=shard_spec)
        super().__init__(repo, buckets=buckets, leaf_capacity=leaf_capacity,
                         mesh=mesh, shard_spec=shard_spec,
                         replica_spec=replica_spec,
                         result_cache_size=result_cache_size)
