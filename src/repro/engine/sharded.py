"""ShardedQueryEngine: the batched engine over a repository sharded on the
``data`` mesh axis.

The paper's "pruning in batch" bound pass is embarrassingly parallel across
dataset slots, so the scale-out unit is the SLOT: `shard_repository` pads
the resident :class:`Repository`'s dataset-axis arrays (`ds_index`,
`ds_sigs`, `ds_valid`) to a multiple of the shard count and places them
with a `NamedSharding` over the chosen mesh axis — each device owns a
contiguous slice of dataset slots; the upper repository tree and the space
bounds are tiny and stay replicated.  Every op then runs the same batched
score pass per shard inside `shard_map` and merges on device:

  * ``topk_ia`` / ``topk_gbo`` / ``topk_hausdorff_approx`` — local top-k
    per shard, then the O(k) all-gather merge from
    :mod:`repro.engine.merge` (network cost independent of repository
    size);
  * ``range_search`` — per-shard mask over the local slots; the global
    mask is the disjoint union (concatenation) of the shard masks, so no
    collective is needed at all;
  * ``range_points`` / ``nnp`` — every shard evaluates the batch against
    its local gather of the requested dataset rows and masks rows it does
    not own; the owner-exclusive contributions are combined with a `psum`
    (adding zeros is exact, so this is the running-min merge with the
    minimum taken over exactly one finite contribution).

Bit-identity with the unsharded :class:`~repro.engine.engine.QueryEngine`
(asserted per-op in tests/test_engine_sharded.py) follows from three facts:

  1. every per-slot score is computed by the same arithmetic on the same
     rows (slicing the slot axis changes no values);
  2. `jax.lax.top_k` breaks ties toward the smallest index, and per-shard
     lists concatenated in shard order enumerate equal values in ascending
     global id — the same order the global top_k uses (see merge.py);
  3. for ``range_search``, the upper-tree traversal can never reject a
     dataset whose own MBR overlaps the query box (every ancestor box
     contains each descendant's MBR and box overlap is monotone under
     containment, and ancestors of a valid slot have counts > 0), so the
     traversal mask equals the per-slot root test `hit & valid` that the
     shards evaluate.

ApproHaus needs two scalars that the seed op derives from the WHOLE
repository — the Lemma 1 dataset-side stopping level and the effective
epsilon's dataset radius term — so the shard pass reduces them with
`pmin`/`pmax` collectives before scoring (boolean AND of the per-shard
level checks, max of the per-shard frontier radii; both are exact).

ExactHaus (`topk_hausdorff`) is genuinely sharded end to end — no
replicated repository copy, so resident repository bytes per device are
~1/N:

  * phases 0/1 (Eq. 4 bound passes) run per shard on the local slot slice
    for the WHOLE (B, ...) query batch in one vmapped pass; each query's
    batch-prune threshold tau (kth-smallest upper bound) is the one
    repository-global quantity and is reduced with the O(k)
    `global_kth_smallest` gather (`core/distributed.py`, batched over the
    query axis), the same collective pattern as `sharded_topk_bounds`;
  * phase 2 runs ONE `lax.while_loop` per shard for the whole batch, over
    each query's OWN ascending-lower-bound candidate order on that
    shard's slots (a shared (query, candidate-chunk) work frontier);
    after every chunk of exact `directed_hausdorff_grid` evaluations the
    per-query taus are all-reduced again (k smallest finite exacts per
    shard -> gather -> kth), so every shard prunes with each query's
    global threshold while it scans.  The loop's per-query continue flags
    (any shard still has work for that query) are psum-reduced into the
    carry so the while cond stays collective-free and replicated;
  * the final top-k is the same O(k) all-gather merge as IA/GBO, batched
    over queries.

Tie-order contract (documented in `search._phase2_exact_loop`, asserted
against the host oracle in tests): per-shard chunking changes WHICH
extra candidates beyond the kth Hausdorff value get exact-evaluated (the
`evaluated` stat), but never the returned set — tau always upper-bounds
the true kth value, so a chunk skipped under either schedule lies
strictly outside the top-k, ties included; values and ids are
bit-identical to `topk_hausdorff_host`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import geometry, join_search, point_search, search
from repro.core.distributed import _shard_map
from repro.core.repo_index import Repository
from repro.engine import batched_ops, merge
from repro.engine.engine import (DEFAULT_BUCKETS, DEFAULT_RESULT_CACHE,
                                 QueryEngine)
from repro.kernels import ops as kernel_ops

Array = jax.Array
BIG = search.BIG


def data_mesh(n_devices: int | None = None, axis: str = "data") -> Mesh:
    """A 1-D mesh over the first `n_devices` local devices (all by default)
    with a single repository-sharding axis.  An explicit request larger
    than the platform provides is an error, never a silent smaller mesh."""
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"data_mesh: {n_devices} devices requested but only "
                f"{len(devs)} available (on CPU, force more with "
                f"REPRO_HOST_DEVICES / --xla_force_host_platform_"
                f"device_count before jax initializes)")
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (axis,))


def shard_repository(
    repo: Repository, mesh: Mesh, axis: str = "data"
) -> tuple[Repository, Repository, int]:
    """Place a Repository's dataset-slot axis across a mesh axis.

    Pads the slot axis to a multiple of the shard count with empty slots
    (zeros: counts == 0 and valid == False, so they are masked exactly like
    the builder's own padding) and device_puts each dataset-axis array with
    `NamedSharding(mesh, P(axis))`; the upper tree and space bounds are
    replicated.  Returns (sharded repository, matching PartitionSpec pytree
    for shard_map in_specs, padded slot count).
    """
    n_shards = int(mesh.shape[axis])
    n_slots = repo.n_slots
    n_padded = ((n_slots + n_shards - 1) // n_shards) * n_shards

    def pad_slots(x):
        if n_padded == n_slots:
            return x
        pad = jnp.zeros((n_padded - n_slots,) + x.shape[1:], x.dtype)
        return jnp.concatenate([x, pad], axis=0)

    def place(x, spec):
        sharding = NamedSharding(mesh, spec)
        if jax.process_count() > 1:
            # multi-host groundwork: assemble the global array from
            # process-local buffers so no single host ever has to device_put
            # the whole repository (each process here still holds the full
            # builder output, the documented fully-replicated input case of
            # make_array_from_process_local_data; a true multi-host loader
            # would hand each process only its slot slice)
            return jax.make_array_from_process_local_data(
                sharding, np.asarray(x), x.shape)
        return jax.device_put(x, sharding)

    sharded = Repository(
        ds_index=jax.tree.map(lambda x: place(pad_slots(x), P(axis)),
                              repo.ds_index),
        ds_sigs=place(pad_slots(repo.ds_sigs), P(axis)),
        ds_valid=place(pad_slots(repo.ds_valid), P(axis)),
        repo=jax.tree.map(lambda x: place(x, P()), repo.repo),
        space_lo=place(repo.space_lo, P()),
        space_hi=place(repo.space_hi, P()),
    )
    specs = Repository(
        ds_index=jax.tree.map(lambda _: P(axis), repo.ds_index),
        ds_sigs=P(axis),
        ds_valid=P(axis),
        repo=jax.tree.map(lambda _: P(), repo.repo),
        space_lo=P(),
        space_hi=P(),
    )
    return sharded, specs, n_padded


def repo_device_bytes(repo: Repository) -> dict:
    """Resident repository bytes per device, from the placed buffers.

    Sums `addressable_shards[*].data.nbytes` over every array leaf, so
    sharded leaves contribute 1/N per device while replicated leaves (the
    upper tree, space bounds) count fully on each — the number a device's
    memory actually pays.  Works on sharded and single-device repositories
    alike (the regression tests and `bench_engine --sharded` use it to
    prove ExactHaus no longer needs a replicated copy).
    """
    out: dict = {}
    for leaf in jax.tree.leaves(repo):
        for sh in leaf.addressable_shards:
            out[sh.device] = out.get(sh.device, 0) + sh.data.nbytes
    return out


class ShardedDispatcher:
    """Builds the sharded device callables the QueryEngine caches.

    Same call contracts as :class:`~repro.engine.engine.LocalDispatcher`:
    each ``build_*`` returns a callable over the query-side operands with
    the (sharded) repository bound as the leading jit argument.

    The QUERY-ROW placement is parameterized by ``row_axis``: every
    query-side operand and per-row output uses the spec ``P(row_axis,
    ...)``.  The base class keeps ``row_axis = None`` (rows replicated on
    every shard — the 1-D data mesh), while
    :class:`~repro.engine.replicated.ReplicatedDispatcher` sets it to the
    ``replica`` axis of a 2-D mesh so each replica group serves its own
    row slice.  When rows are split, :meth:`_smap` pads the leading row
    axis to a multiple of the replica count by replicating row 0 (the same
    trick as the engine's bucket padding — per-row computations are
    independent, so pad rows change nothing and are sliced off) and cuts
    the row-spec'd outputs back.
    """

    name = "sharded"
    #: mesh axis the query-row (leading batch) axis is partitioned over in
    #: every spec; None keeps rows replicated (the base 1-D behavior)
    row_axis: str | None = None
    #: layout epoch — bumped by a live repository when the slot-array
    #: shapes change (tier growth); part of every executable-cache key.
    #: The sharded builds additionally close over `n_slots`/`shard_slots`
    #: constants, so retiring them on growth is REQUIRED, not just tidy.
    repo_epoch = 0

    def __init__(self, repo: Repository, mesh: Mesh, axis: str = "data"):
        if not isinstance(axis, str):      # accept a PartitionSpec-ish spec
            axis = tuple(axis)[0]
        self.mesh = mesh
        self.axis = axis
        self.n_shards = int(mesh.shape[axis])
        self.n_slots = repo.n_slots
        # the sharded placement is the ONLY repository copy this dispatcher
        # retains — every op (ExactHaus included) runs on the shard slices,
        # so per-device resident bytes are ~total/N (asserted in tests)
        self.repo, self.specs, self.n_slots_sharded = shard_repository(
            repo, mesh, axis)
        self.shard_slots = self.n_slots_sharded // self.n_shards

    # -- helpers -----------------------------------------------------------

    @property
    def _rows(self):
        """Spec of a query-side operand / per-row output: partitioned on
        the row axis when one is configured (P(None) == replicated)."""
        return P(self.row_axis)

    def _smap(self, fn, in_specs, out_specs):
        sm = _shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                        out_specs=out_specs, check_vma=False)
        if self.row_axis is None:
            return sm
        n_rep = int(self.mesh.shape[self.row_axis])

        def row_split(spec):
            return len(spec) > 0 and spec[0] == self.row_axis

        def pad(x):
            # pad rows to a multiple of n_rep by repeating row 0 (rows are
            # independent, so pad rows never perturb real ones).  A single
            # gather, NOT concatenate: under jit, XLA's partitioner
            # mis-reshards a concat whose per-replica block is one operand
            # (each shard comes out psum-reduced over the other mesh axis).
            m = -x.shape[0] % n_rep
            if not m:
                return x
            idx = np.concatenate([np.arange(x.shape[0]), np.zeros(m, np.int64)])
            return jnp.take(x, jnp.asarray(idx), axis=0)

        # NOTE: PartitionSpec subclasses tuple — a bare P(...) out_specs is
        # ONE output, not a tuple of per-output specs
        single = isinstance(out_specs, P) or not isinstance(out_specs, tuple)
        o_specs = (out_specs,) if single else out_specs

        def wrapped(repo_s, *args):
            rows = None
            ins = []
            for a, spec in zip(args, in_specs[1:]):
                if row_split(spec):
                    if rows is None:
                        rows = jax.tree.leaves(a)[0].shape[0]
                    a = jax.tree.map(pad, a)
                ins.append(a)
            out = sm(repo_s, *ins)
            if rows is None:
                return out
            outs = (out,) if single else out
            cut = tuple(
                jax.tree.map(lambda x: x[:rows], o) if row_split(spec)
                else o
                for o, spec in zip(outs, o_specs))
            return cut[0] if single else cut

        return wrapped

    def _bind(self, impl):
        """jit with the sharded repository as the LATE-BOUND leading
        operand (an operand, not a closed-over constant, so XLA never
        inlines it; read from ``self.repo`` at call time, so a live
        mutation's atomic placed-repository swap takes effect on the next
        dispatch without recompiling — same shapes + same shardings hit
        the same executable)."""
        jitted = jax.jit(impl)

        def call(*args, **kw):
            return jitted(self.repo, *args, **kw)

        return call

    def _owner_select(self, repo_loc, ds_ids):
        """Per-request (owner mask, local gather of the requested dataset
        rows).  Non-owner shards gather a clamped row and compute masked-out
        garbage; only the owner's result survives the psum merge."""
        shard = repo_loc.ds_valid.shape[0]
        me = jax.lax.axis_index(self.axis)
        mine = (ds_ids // shard) == me
        lid = jnp.clip(ds_ids - me * shard, 0, shard - 1)
        d_sel = jax.tree.map(lambda x: x[lid], repo_loc.ds_index)
        return mine, d_sel

    # -- dataset granularity ----------------------------------------------

    def build_range_search(self):
        axis, n = self.axis, self.n_slots

        def local(repo_loc, r_lo, r_hi):
            # per-slot root test == the upper-tree traversal mask (ancestor
            # boxes contain descendant MBRs; see module docstring)
            _, _, lo, hi = repo_loc.roots()
            hit = geometry.box_overlaps(
                lo[None, :, :], hi[None, :, :],
                r_lo[:, None, :], r_hi[:, None, :])
            return hit & repo_loc.ds_valid[None, :]

        sm = self._smap(local, in_specs=(self.specs, self._rows, self._rows),
                        out_specs=P(self.row_axis, axis))

        def impl(repo_s, r_lo, r_hi):
            masks = sm(repo_s, r_lo, r_hi)
            return masks[:, :n], None

        return self._bind(impl)

    def build_topk_ia(self, k: int):
        axis = self.axis

        def local(repo_loc, q_lo, q_hi):
            _, _, lo, hi = repo_loc.roots()
            ia = geometry.intersect_area(
                lo[None, :, :], hi[None, :, :],
                q_lo[:, None, :], q_hi[:, None, :])
            ia = jnp.where(repo_loc.ds_valid[None, :], ia, -1.0)
            return merge.shard_topk(ia, k, axis)

        sm = self._smap(local, in_specs=(self.specs, self._rows, self._rows),
                        out_specs=(self._rows, self._rows))

        def impl(repo_s, q_lo, q_hi):
            vals, ids = sm(repo_s, q_lo, q_hi)
            return vals, merge.sentinel_ids(vals, ids)

        return self._bind(impl)

    def build_topk_gbo(self, k: int):
        axis = self.axis

        def local(repo_loc, q_sigs):
            counts = kernel_ops.set_intersect_counts(q_sigs, repo_loc.ds_sigs)
            counts = jnp.where(repo_loc.ds_valid[None, :], counts, -1)
            return merge.shard_topk(counts, k, axis)

        sm = self._smap(local, in_specs=(self.specs, self._rows),
                        out_specs=(self._rows, self._rows))

        def impl(repo_s, q_sigs):
            vals, ids = sm(repo_s, q_sigs)
            return vals, merge.sentinel_ids(vals, ids)

        return self._bind(impl)

    def build_topk_hausdorff_approx(self, k: int):
        axis = self.axis

        def local(repo_loc, q_batch, eps):
            dq = q_batch.depth
            dd = repo_loc.ds_index.depth
            n_lq = 1 << dq
            n_ld = 1 << dd

            # Lemma 1 dataset-side stopping level from the WHOLE repository:
            # AND the per-shard level-ok bits (padded slots have counts == 0
            # and drop out of the check exactly like builder padding)
            oks = batched_ops._levels_ok(
                repo_loc.ds_index.radii, repo_loc.ds_index.counts, dd, eps)
            oks = jax.lax.pmin(oks.astype(jnp.int32), axis).astype(bool)
            ld = jnp.where(jnp.any(oks), jnp.argmax(oks), dd)
            ld = ld.astype(jnp.int32)

            od, rd, cd, dmask = batched_ops._gather_frontier(
                repo_loc.ds_index.centers, repo_loc.ds_index.radii,
                repo_loc.ds_index.counts, ld, n_ld)
            d_ok = (cd > 0) & dmask[None, :]
            # global eps_eff radius term: max of the per-shard maxima (exact)
            r_d = jax.lax.pmax(jnp.max(jnp.where(d_ok, rd, 0.0)), axis)
            base = jax.lax.axis_index(axis) * repo_loc.ds_valid.shape[0]

            def per_query(q_centers, q_radii, q_counts):
                lq = batched_ops._level_for_eps(q_radii, q_counts, dq, eps)
                oq, rq, cq, qmask = batched_ops._gather_frontier(
                    q_centers, q_radii, q_counts, lq, n_lq)
                q_ok = (cq > 0) & qmask

                def one(od_i, ok_i):
                    cdm = geometry.pairwise_dist_exact(oq, od_i)
                    cdm = jnp.where(ok_i[None, :], cdm, BIG)
                    row = jnp.min(cdm, axis=1)
                    return jnp.max(jnp.where(q_ok, row, -BIG))

                vals = jax.vmap(one)(od, d_ok)
                vals = jnp.where(repo_loc.ds_valid, vals, BIG)
                neg, gids = merge.local_topk(-vals, k, base)
                r_q = jnp.max(jnp.where(q_ok, rq, 0.0))
                eps_eff = jnp.maximum(jnp.asarray(eps, r_q.dtype),
                                      jnp.maximum(r_q, r_d))
                return neg, gids, eps_eff

            neg, gids, eps_eff = jax.vmap(per_query)(
                q_batch.centers, q_batch.radii, q_batch.counts)
            neg, ids = merge.all_gather_topk(neg, gids, k, axis)
            return -neg, ids, eps_eff

        # eps is a replicated SCALAR (rank 0): its spec must stay P()
        sm = self._smap(local, in_specs=(self.specs, self._rows, P()),
                        out_specs=(self._rows, self._rows, self._rows))

        def impl(repo_s, q_batch, eps):
            return sm(repo_s, q_batch, eps)

        return self._bind(impl)

    def build_topk_hausdorff(self, k: int, refine_levels: int, chunk: int):
        """Sharded BATCHED ExactHaus: per-shard bound phases and ONE
        per-shard phase-2 while_loop for the whole (B, ...) query batch,
        with each query's tau all-reduced after every chunk (the schedule
        from the module docstring, batched over queries), then the O(k)
        all-gather top-k merge per query.  Per-query values and ids are
        bit-identical to the single-device pipeline and the host oracle;
        only the `evaluated` stat is schedule-dependent."""
        axis = self.axis
        n_total = self.n_slots
        shard = self.shard_slots

        def local(repo_loc, q_batch):
            LB, tau, cand, nodes, cand_after = search._hausdorff_bound_phases(
                repo_loc, q_batch, k, refine_levels, axis=axis,
                n_slots_total=n_total)
            exact_vals, evaluated = search._phase2_exact_loop(
                LB, cand, tau, q_batch, repo_loc.ds_index, k, chunk,
                axis=axis)
            vals = jnp.where(repo_loc.ds_valid[None, :], exact_vals, BIG)
            # shard-padded slots carry BIG like invalid ones and lose every
            # smallest-index tie, so k <= n_slots never surfaces a pad id
            base = jax.lax.axis_index(axis) * shard
            neg, gids = merge.local_topk(-vals, k, base)
            neg, ids = merge.all_gather_topk(neg, gids, k, axis)
            return -neg, ids, nodes, cand_after, evaluated

        sm = self._smap(local, in_specs=(self.specs, self._rows),
                        out_specs=(self._rows,) * 5)

        def impl(repo_s, q_batch):
            return sm(repo_s, q_batch)

        return self._bind(impl)

    def _build_topk_join(self, k: int, mode: str, chunk: int):
        """Sharded joinable top-k: per-shard bound phase over the local
        slot slice, the shared-order chunked refine with each query's
        integer τ all-reduced after every chunk (collective cond, so all
        shards iterate together), then the O(k) all-gather top-k merge.
        Scores are exact ints, so values/ids are bit-identical to the
        local dispatcher and the host oracle under ANY shard count; only
        the `evaluated` stat is schedule-dependent (the ExactHaus
        contract).  Shard-padded slots are invalid (ds_valid False), carry
        UB -1, and are never evaluated."""
        axis = self.axis
        n_total = self.n_slots
        shard = self.shard_slots

        def local(repo_loc, q_pts, q_val):
            exact, nodes, cand_after, evaluated = join_search.topk_join_scores(
                repo_loc, q_pts, q_val, k, mode, chunk, axis=axis,
                n_slots_total=n_total)
            base = jax.lax.axis_index(axis) * shard
            vals, gids = merge.local_topk(exact, k, base)
            vals, ids = merge.all_gather_topk(vals, gids, k, axis)
            return (vals, merge.sentinel_ids(vals, ids), nodes, cand_after,
                    evaluated)

        sm = self._smap(local, in_specs=(self.specs, self._rows, self._rows),
                        out_specs=(self._rows,) * 5)

        def impl(repo_s, q_pts, q_val):
            return sm(repo_s, q_pts, q_val)

        return self._bind(impl)

    def build_topk_overlap(self, k: int, chunk: int):
        return self._build_topk_join(k, "overlap", chunk)

    def build_topk_coverage(self, k: int, chunk: int):
        return self._build_topk_join(k, "coverage", chunk)

    # -- point granularity -------------------------------------------------

    def build_range_points(self):
        axis = self.axis

        def local(repo_loc, ds_ids, r_lo, r_hi):
            mine, d_sel = self._owner_select(repo_loc, ds_ids)
            take, scanned = jax.vmap(point_search.range_points_core)(
                d_sel, r_lo, r_hi)
            take = (take & mine[:, None]).astype(jnp.int32)
            scanned = (scanned & mine[:, None]).astype(jnp.int32)
            take = jax.lax.psum(take, axis).astype(bool)
            scanned = jax.lax.psum(scanned, axis).astype(bool)
            return take, scanned

        sm = self._smap(local,
                        in_specs=(self.specs, self._rows, self._rows,
                                  self._rows),
                        out_specs=(self._rows, self._rows))

        def impl(repo_s, ds_ids, r_lo, r_hi):
            return sm(repo_s, ds_ids, r_lo, r_hi)

        return self._bind(impl)

    def build_nnp(self):
        axis = self.axis

        def local(repo_loc, ds_ids, q_batch):
            mine, d_sel = self._owner_select(repo_loc, ds_ids)
            dists, idxs, pair_live = jax.vmap(point_search.nnp_pruned_core)(
                q_batch, d_sel)
            # owner-exclusive merge: + 0.0 and + 0 are exact, so the psum
            # reproduces the owner's values bit-for-bit; the Eq. 4
            # pair_live prune mask rides along the same way so the engine
            # can book the pruned fraction (PointStats)
            dists = jax.lax.psum(jnp.where(mine[:, None], dists, 0.0), axis)
            idxs = jax.lax.psum(jnp.where(mine[:, None], idxs, 0), axis)
            pair_live = jax.lax.psum(
                jnp.where(mine[:, None, None], pair_live, 0
                          ).astype(jnp.int32), axis).astype(bool)
            return dists, idxs, pair_live

        sm = self._smap(local, in_specs=(self.specs, self._rows, self._rows),
                        out_specs=(self._rows, self._rows, self._rows))

        def impl(repo_s, ds_ids, q_batch):
            return sm(repo_s, ds_ids, q_batch)

        return self._bind(impl)

    def build_join_rerank(self, mode: str):
        """Dataset→dataset pipeline stage 2, sharded: each winner slot's
        points live on exactly one shard, so the row-wise exact join score
        merges owner-exclusively (+0 is exact for ints, same pattern as
        NNP/RangeP)."""
        axis = self.axis

        def local(repo_loc, ds_ids, q_pts, q_val):
            mine, d_sel = self._owner_select(repo_loc, ds_ids)
            sc = join_search.pair_scores(repo_loc, d_sel.points, d_sel.valid,
                                         q_pts, q_val, mode)
            return jax.lax.psum(jnp.where(mine, sc, 0), axis)

        sm = self._smap(local, in_specs=(self.specs, self._rows, self._rows,
                                         self._rows),
                        out_specs=self._rows)

        def impl(repo_s, ds_ids, q_pts, q_val):
            return sm(repo_s, ds_ids, q_pts, q_val)

        return self._bind(impl)


class ShardedQueryEngine(QueryEngine):
    """QueryEngine whose resident repository is sharded over a mesh axis.

    Same bucket ladder, executable cache, query construction, and
    :class:`~repro.engine.engine.EngineStats`; only dispatch differs.  With
    no ``mesh`` given, shards over ALL local devices on a 1-D ``data``
    mesh (a 1-device mesh degenerates to the local layout, so the class is
    safe to use unconditionally).
    """

    def __init__(
        self,
        repo: Repository,
        *,
        mesh: Mesh | None = None,
        shard_spec: str = "data",
        buckets=DEFAULT_BUCKETS,
        leaf_capacity: int = 16,
        result_cache_size: int = DEFAULT_RESULT_CACHE,
    ):
        if mesh is None:
            mesh = data_mesh(axis=shard_spec)
        super().__init__(repo, buckets=buckets, leaf_capacity=leaf_capacity,
                         mesh=mesh, shard_spec=shard_spec,
                         result_cache_size=result_cache_size)
