"""LiveRepository: online ingest / delete / replace under serving traffic.

Every engine path so far serves a FROZEN :class:`Repository` built once at
startup.  This module makes the repository a live catalog:

  * ``ingest(points) -> ds_id`` — build the new dataset's bottom tree and
    z-order signature ON DEVICE under the pinned cold-build geometry
    (:mod:`repro.core.repo_mutate`), scatter it into a free slot, and
    rebuild the tiny upper tree — one jitted executable reused for every
    mutation, no full rebuild, no repository re-upload (only the new
    dataset's padded points cross the host->device boundary);
  * ``delete(ds_id)`` — zero the slot (bit-identical to a never-filled
    slot) and return it to the free list; ``replace(ds_id, points)`` is
    an in-place ingest into the same slot;
  * slot capacity is TIERED like the engine's bucket ladder: when ingest
    outruns the free list, the slot count doubles (zeros appended on
    device, shard-aligned) and the dispatcher's layout epoch retires the
    executables whose builds closed over the old slot count.

Versioning is EPOCH-BASED, two levels:

  * the engine's DATA epoch bumps on every published mutation and is part
    of every dataset-op result-cache key, so a query cached at epoch N is
    never served at epoch N+1 (the purged entries are booked in
    ``stats.epoch_invalidations``, and the identical re-query books a
    result-cache MISS — the hits+misses==dispatches invariant is
    untouched);
  * per-slot epochs version point-granularity results: a RangeP/NNP
    entry keyed on dataset j survives mutations of every OTHER dataset;
  * the dispatcher's LAYOUT epoch (executable-cache keys) bumps only on
    tier growth — data mutations swap ``dispatcher.repo`` atomically and
    keep every compiled executable (same shapes, same shardings).

The correctness bar is BIT-IDENTITY: after any mutation sequence, the
resident repository — and every op's results — must equal a cold engine
built by :func:`repro.core.repo_mutate.build_frozen` from the current
slot contents (``frozen_repository()``; asserted op-by-op in
tests/test_live_repository.py and for random interleavings in
tests/test_mutation_properties.py, on local, sharded, and replicated
dispatchers).

Mutations never tear in-flight queries: the slot update is a functional
(non-donating) device computation, so a dispatch that already read the
old repository keeps consistent old buffers, and the publish step is a
single Python attribute swap.  Mutation calls themselves are serialized
by a lock; queries never take it.

Every mutation runs as a TWO-STAGE pipeline:

  * **prepare** (:meth:`LiveRepository.prepare_group`) — validation, slot
    reservation, and the host-side jitted row-stage builds + padded
    payload upload.  Prepare touches nothing a query can observe, so a
    serving front-end may run it CONCURRENTLY with an in-flight query
    segment against the immutable pre-mutation snapshot (late-bound
    dispatchers make this safe).  A prepare that fails mid-group aborts
    cleanly: its reserved slot returns to the free list, the other items
    stay publishable (:meth:`abort_group` abandons a whole group).
  * **publish** (:meth:`LiveRepository.publish_group`) — the cheap
    install: ONE batched owner-write dispatch + ONE upper-tree rebuild
    for the whole group (:func:`repro.core.repo_mutate.update_slots`),
    then the atomic repo swap.  A run of N consecutive mutations with no
    intervening queries COALESCES into one publish and bumps the data
    epoch ONCE — semantics-preserving because every query is still
    answered at the epoch of its stream position (no query can observe
    the intermediate states a serial apply would have materialized).

``ingest``/``delete``/``replace`` are the group-of-1 form of the same
pipeline — one mutation, one publish, one epoch bump, exactly the
pre-pipeline semantics.

The joinable ops (``topk_overlap`` / ``topk_coverage``) need nothing
special here: their result-cache keys carry the data epoch like every
other dataset op, their coarse bounds read the same upper tree the
publish step rebuilds, and their exact refine gathers slot points through
``repo.ds_index`` — so a joinable query after any mutation sequence is
bit-identical to the cold frozen build (asserted at every epoch in
tests/test_join_search.py).
"""
from __future__ import annotations

import heapq
import threading
import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import repo_mutate
from repro.core.repo_index import Repository
from repro.engine.engine import QueryEngine

__all__ = ["LiveRepository", "PreparedGroup", "PreparedMutation"]


@dataclass
class PreparedMutation:
    """One mutation after its prepare stage: the target slot (reserved
    for ingest), the built batch-of-1 row + signature (zero row for
    delete), or the error its prepare raised (in which case every
    reservation was already returned — never half-reserved)."""
    op: str
    slot: int | None = None
    points: np.ndarray | None = None    # host copy (slot-data ground truth)
    row: object | None = None           # batch-of-1 DatasetIndex
    sig: object | None = None           # (1, W) signature words
    valid: bool = False
    error: Exception | None = None


@dataclass
class PreparedGroup:
    """An ordered run of prepared mutations awaiting one coalesced
    publish (or :meth:`LiveRepository.abort_group`)."""
    items: list = field(default_factory=list)
    published: bool = False
    aborted: bool = False


class LiveRepository:
    """A mutable, versioned repository serving through a QueryEngine.

    ``mesh=None`` serves locally; a 1-D mesh selects sharded dispatch and
    a (replica x data) mesh replica-parallel dispatch — mutation works
    identically on all three (the slot updater's outputs are pinned to
    the dispatcher's placement, so only TOUCHED state moves between
    devices).

    ``point_capacity`` reserves bottom-tree headroom for datasets larger
    than any initial one (the bottom depth is pinned; an oversize ingest
    raises).  ``slot_headroom`` pre-doubles slot capacity that many
    times.  ``clock`` injects the timebase for publish-latency
    accounting (tests drive it with virtual time).  Remaining engine
    knobs (buckets, result_cache_size, ...) pass through to
    :class:`~repro.engine.engine.QueryEngine`.
    """

    def __init__(
        self,
        datasets: Sequence[np.ndarray],
        *,
        mesh=None,
        leaf_capacity: int = 16,
        repo_leaf_capacity: int | None = None,
        theta: int = 5,
        remove_outliers: bool = True,
        point_capacity: int | None = None,
        slot_headroom: int = 0,
        clock=time.perf_counter,
        **engine_kwargs,
    ):
        self._clock = clock
        repo, geom = repo_mutate.init_live(
            datasets,
            leaf_capacity=leaf_capacity,
            repo_leaf_capacity=repo_leaf_capacity,
            theta=theta,
            remove_outliers=remove_outliers,
            point_capacity=point_capacity,
            slot_headroom=slot_headroom,
        )
        self.geometry = geom
        self.engine = QueryEngine(repo, leaf_capacity=leaf_capacity,
                                  mesh=mesh, **engine_kwargs)
        B = len(datasets)
        #: DATA epoch of the published repository (monotone, starts at 0)
        self.epoch = 0
        #: per-slot epoch: the data epoch at which the slot last changed
        self.slot_epochs = np.zeros(geom.n_slots, np.int64)
        #: host->device bytes moved by mutations (ingest/replace payloads
        #: only — delete and tier growth upload NOTHING; the acceptance
        #: check that single-dataset mutations never re-upload the
        #: repository reads this)
        self.bytes_uploaded = 0
        self.mutations = 0
        self._live: set = set(range(B))
        self._free: list = list(range(B, geom.n_slots))
        heapq.heapify(self._free)
        # host copies of current slot contents — the ground truth the
        # frozen oracle rebuilds from (and the source for `replace`-style
        # serving tools); one small np array per live dataset
        self._slot_data = {j: np.asarray(ds, np.float32)
                           for j, ds in enumerate(datasets)}
        self._lock = threading.Lock()
        # direct ingest/delete/replace serialize through this OUTER lock
        # (each is a group-of-1 prepare+publish, preserving the exact
        # pre-pipeline semantics); the inner ``_lock`` guards free-list /
        # live-set / publish internals so a serving front-end can overlap
        # prepare_group with an in-flight query segment
        self._api_lock = threading.Lock()
        zr, zs = repo_mutate.zero_slot_row(geom)
        # batch-of-1 zero row: deletes coalesce into the same batched
        # scatter as ingests/replaces
        self._zero_row1 = (jax.tree.map(lambda x: x[None], zr), zs[None])
        #: tiers reserved VIRTUALLY by prepare (free list extended past
        #: the current slot count) and not yet materialized by a publish
        self._grows_pending = 0
        # batched slot-write executables keyed by padded group size;
        # cleared on tier growth (they close over the slot count)
        self._updaters: dict = {}
        self.engine.set_repo_epoch(0, self.slot_epochs)

    # -- views -------------------------------------------------------------

    @property
    def repo(self) -> Repository:
        """The currently published (placed) repository."""
        return self.engine.dispatch.repo

    @property
    def stats(self):
        return self.engine.stats

    @property
    def live_ids(self) -> set:
        return set(self._live)

    @property
    def n_slots(self) -> int:
        return self.geometry.n_slots

    def search(self, queries):
        """Serve a declarative batch against the current epoch (see
        :meth:`QueryEngine.search`)."""
        return self.engine.search(queries)

    def slot_datasets(self) -> list:
        """Current slot contents, ``None`` for holes — exactly the input
        :func:`~repro.core.repo_mutate.build_frozen` expects."""
        return [self._slot_data.get(j) for j in range(self.geometry.n_slots)]

    def frozen_repository(self) -> Repository:
        """The cold-built oracle equivalent to the current live state —
        bit-identical to :attr:`repo` (modulo shard padding/placement) by
        construction; tests assert it."""
        return repo_mutate.build_frozen(self.slot_datasets(), self.geometry)

    # -- mutations ---------------------------------------------------------

    #: rows per device dispatch inside one publish — larger groups chunk
    #: (bounds the executable-variant count; padded buckets are powers
    #: of two, so the updater cache holds at most log2(MAX_GROUP)+1
    #: entries per tier)
    MAX_GROUP = 16

    def ingest(self, points) -> int:
        """Add a dataset; returns its slot id (stable until deleted).
        Grows the slot tier first if the free list is empty."""
        return self._apply_one("ingest", None, points)

    def delete(self, ds_id: int) -> None:
        """Remove a dataset: its slot is zeroed (bit-identical to a
        never-filled slot) and returned to the free list."""
        self._apply_one("delete", int(ds_id), None)

    def replace(self, ds_id: int, points) -> None:
        """Swap a live dataset's contents in place — a new VERSION under
        the same id: the slot keeps its id, its per-slot epoch bumps, and
        every cached result that touched it is retired."""
        self._apply_one("replace", int(ds_id), points)

    def _apply_one(self, op, ds_id, points):
        with self._api_lock:
            group = self.prepare_group([(op, ds_id, points)])
            item = group.items[0]
            if item.error is not None:
                group.published = True      # nothing reserved to return
                raise item.error
            return self.publish_group(group)[0]

    # -- prepare stage -----------------------------------------------------

    def prepare_group(self, specs) -> PreparedGroup:
        """Prepare a run of mutations ``[(op, ds_id, points), ...]`` —
        validation, slot reservation, and the jitted row builds + padded
        payload uploads — WITHOUT publishing anything.  Queries served
        while this runs still see the pre-mutation snapshot unchanged.

        Items validate against a group-local view of the live set
        (pending ingests visible, pending deletes excluded), so the
        outcome of each item matches a sequential apply of the group.  A
        failing item records its error (its reservation returned
        immediately) and does NOT poison the rest of the group; the
        caller sees the error in :meth:`publish_group`'s outcomes."""
        items = []
        with self._lock:
            view_live = set(self._live)
        for op, ds_id, points in specs:
            try:
                if op == "ingest":
                    items.append(self._prepare_ingest(points, view_live))
                elif op == "replace":
                    items.append(
                        self._prepare_replace(int(ds_id), points, view_live))
                elif op == "delete":
                    items.append(self._prepare_delete(int(ds_id), view_live))
                else:
                    raise ValueError(f"unknown mutation op {op!r}")
            except Exception as e:  # noqa: BLE001 — recorded per item
                items.append(PreparedMutation(op, error=e))
        return PreparedGroup(items)

    def _prepare_ingest(self, points, view_live):
        # reserve FIRST so concurrent prepares in the same group never
        # collide, then validate/build; ANY failure past the reservation
        # runs the abort path (slot back on the free list — never
        # half-reserved, tested by the abort-path suite)
        with self._lock:
            slot = self._reserve_slot()
        try:
            pts = self._check_points(points)
            row, sig = self._build_payload(pts)
        except Exception:
            with self._lock:
                heapq.heappush(self._free, slot)
            raise
        view_live.add(slot)
        return PreparedMutation("ingest", slot=slot, points=pts,
                                row=row, sig=sig, valid=True)

    def _prepare_replace(self, ds_id, points, view_live):
        if ds_id not in view_live:
            raise KeyError(f"dataset id {ds_id} is not live")
        pts = self._check_points(points)
        row, sig = self._build_payload(pts)
        return PreparedMutation("replace", slot=ds_id, points=pts,
                                row=row, sig=sig, valid=True)

    def _prepare_delete(self, ds_id, view_live):
        if ds_id not in view_live:
            raise KeyError(f"dataset id {ds_id} is not live")
        view_live.discard(ds_id)
        row, sig = self._zero_row1
        return PreparedMutation("delete", slot=ds_id,
                                row=row, sig=sig, valid=False)

    def _build_payload(self, pts):
        geom = self.geometry
        # the canonical batch-of-1 row pipeline — the same shared
        # executables the frozen oracle uses (bit-identity by
        # construction, see core/repo_mutate); the ONLY host->device
        # traffic a mutation pays is this one padded payload
        rows, sigs = repo_mutate.build_row(pts, geom)
        with self._lock:
            self.bytes_uploaded += geom.point_capacity * (4 * geom.dim + 1)
        return rows, sigs

    def _reserve_slot(self) -> int:
        """Pop a free slot (caller holds ``_lock``).  An empty free list
        extends VIRTUALLY into the next tier — ids past the current slot
        count — deferring the actual growth (its device work, layout
        epoch, and data epoch) to the publish stage."""
        if not self._free:
            base = self.geometry.n_slots << self._grows_pending
            self._grows_pending += 1
            for s in range(base, 2 * base):
                heapq.heappush(self._free, s)
        return heapq.heappop(self._free)

    def abort_group(self, group: PreparedGroup) -> None:
        """Abandon a prepared, unpublished group: every ingest
        reservation returns to the free list (subsequent ingests reuse
        the slots) and the group is marked consumed."""
        if group.published or group.aborted:
            raise RuntimeError("group already consumed")
        group.aborted = True
        with self._lock:
            for p in group.items:
                if p.error is None and p.op == "ingest":
                    heapq.heappush(self._free, p.slot)
                    p.error = RuntimeError("prepare aborted")

    # -- publish stage -----------------------------------------------------

    def publish_group(self, group: PreparedGroup):
        """Install a prepared group as ONE coalesced publish: one batched
        owner-write dispatch + one upper-tree rebuild for the whole run
        (chunked at :attr:`MAX_GROUP`), the data epoch bumped once per
        chunk.  Returns per-item outcomes in stream order: the slot id
        for ingest, the dataset id for replace, ``None`` for delete, or
        the item's prepare-stage exception."""
        if group.published or group.aborted:
            raise RuntimeError("group already consumed")
        group.published = True
        outcomes: list = [p.error for p in group.items]
        applied = [(i, p) for i, p in enumerate(group.items)
                   if p.error is None]
        with self._lock:
            for lo in range(0, len(applied), self.MAX_GROUP):
                self._publish_chunk(
                    [p for _, p in applied[lo:lo + self.MAX_GROUP]])
        for i, p in applied:
            outcomes[i] = None if p.op == "delete" else p.slot
        return outcomes

    def _publish_chunk(self, chunk) -> None:
        """One coalesced install (caller holds ``_lock``): materialize
        any tier growth the prepare stage reserved virtually, dedup the
        chunk's writes by slot (last write wins — stream order), pad to
        the power-of-two bucket by REPEATING the last write (duplicate
        scatter indices with identical payloads are deterministic), run
        the one batched updater, then apply host bookkeeping in stream
        order and publish the successor epoch."""
        t0 = self._clock()
        top = max(p.slot for p in chunk)
        while top >= self.geometry.n_slots:
            self._grow(push_free=False)
        last: dict = {}
        for p in chunk:                      # dict preserves insertion,
            last[p.slot] = p                 # value is the LAST write
        writes = list(last.values())
        bucket = 1
        while bucket < len(writes):
            bucket *= 2
        writes = writes + [writes[-1]] * (bucket - len(writes))
        slots = jnp.asarray([p.slot for p in writes], jnp.int32)
        rows = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                            *[p.row for p in writes])
        sigs = jnp.concatenate([p.sig for p in writes], axis=0)
        valids = jnp.asarray([p.valid for p in writes], bool)
        new_repo = self._updater_for(bucket)(self.repo, slots, rows,
                                             sigs, valids)
        for p in chunk:
            if p.op == "delete":
                self._live.discard(p.slot)
                self._slot_data.pop(p.slot, None)
                heapq.heappush(self._free, p.slot)
            else:
                self._live.add(p.slot)
                self._slot_data[p.slot] = p.points
        self.mutations += len(chunk)
        self._publish(new_repo, touched=tuple(last))
        self.engine.stats.record_publish(self._clock() - t0,
                                         coalesced=len(chunk) - 1)

    # -- internals ---------------------------------------------------------

    def _check_points(self, points) -> np.ndarray:
        points = np.asarray(points, np.float32)
        geom = self.geometry
        if points.ndim != 2 or points.shape[1] != geom.dim:
            raise ValueError(f"expected (n, {geom.dim}) points, got "
                             f"{points.shape}")
        if points.shape[0] == 0:
            raise ValueError("cannot ingest an empty dataset")
        if points.shape[0] > geom.point_capacity:
            raise ValueError(
                f"dataset with {points.shape[0]} points exceeds the pinned "
                f"point capacity {geom.point_capacity}; rebuild the live "
                f"repository with point_capacity >= {points.shape[0]}")
        return points

    def _check_live(self, ds_id: int) -> None:
        if ds_id not in self._live:
            raise KeyError(f"dataset id {ds_id} is not live")

    def _rep_sharding(self):
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.engine.dispatch.mesh, PartitionSpec())

    def _finish(self, repo, ds_index, ds_sigs, ds_valid, roots, geom):
        """Second mutation stage, shared by every dispatcher: the upper
        tree from single-device root summaries through the ONE cached
        stage executable the frozen oracle also calls — bit-identity with
        the cold build by construction (the same compiled program on
        bitwise-equal inputs), where re-deriving the tree inside the
        fused/shard_map stage can drift a node radius by one ulp at some
        slot counts (reduction codegen is shape- and context-dependent).
        Roots are O(n_slots) summaries — the hop to the default device
        and the replicated placement of the finished tree move no slot
        bodies."""
        dev0 = jax.devices()[0]
        tree = repo_mutate._stage_upper(geom.upper_depth)(
            *(jax.device_put(r, dev0) for r in roots))
        if getattr(self.engine.dispatch, "specs", None) is not None:
            tree = jax.device_put(tree, self._rep_sharding())
        return Repository(ds_index=ds_index, ds_sigs=ds_sigs,
                          ds_valid=ds_valid, repo=tree,
                          space_lo=repo.space_lo, space_hi=repo.space_hi)

    def _updater_for(self, bucket: int):
        fn = self._updaters.get(bucket)
        if fn is None:
            fn = self._make_updater(bucket)
            self._updaters[bucket] = fn
        return fn

    def _make_updater(self, bucket: int):
        """The batched slot-write executable for the CURRENT tier and one
        padded group size: ``bucket`` (slot, row, sig, valid) writes land
        in ONE dispatch — dynamic slot + validity operands, so any mix of
        ingest/delete/replace on any slots reuses it.  Inputs are NOT
        donated (in-flight queries keep the old buffers).  It returns the
        updated slot arrays plus the per-slot ROOT summaries; `_finish`
        turns those into the upper tree.

        Local dispatch is a plain jitted scatter (slots are pre-deduped,
        so the batched scatter is bitwise equal to ``bucket`` sequential
        single-row scatters — pure data movement).  On a mesh the writes
        run inside an EXPLICIT shard_map as a STATIC unroll of owner
        writes — the owner shard folds each (replicated) row into its
        local slice, later writes winning, and the roots are all-gathered
        once (tiny: one summary row per slot, not the slot bodies), so
        only the touched shards' slices change and nothing moves through
        the host.  shard_map rather than the SPMD partitioner is
        load-bearing: jit-of-scatter on a (replica x data) mesh lets the
        partitioner psum the replicated row operand over the replica
        axis, silently DOUBLING every slot (the same hazard
        `ShardedDispatcher._smap` documents for concat)."""
        geom = self.geometry
        disp = self.engine.dispatch
        specs = getattr(disp, "specs", None)
        B_pad = geom.n_slots

        def roots_of(ds_index, ds_sigs, ds_valid):
            return (ds_index.centers[:B_pad, 0, :],
                    ds_index.radii[:B_pad, 0],
                    ds_index.box_lo[:B_pad, 0, :],
                    ds_index.box_hi[:B_pad, 0, :],
                    ds_sigs[:B_pad], ds_valid[:B_pad])

        if specs is None:
            def scatter(repo, slots, rows, sigs, valids):
                ds_index, ds_sigs, ds_valid = repo_mutate.scatter_slots(
                    repo, slots, rows, sigs, valids)
                return (ds_index, ds_sigs, ds_valid,
                        roots_of(ds_index, ds_sigs, ds_valid))
            stage = jax.jit(scatter)
        else:
            from jax.sharding import PartitionSpec as P
            from repro.core.distributed import _shard_map
            axis = disp.axis

            def local(repo_s, slots, rows, sigs, valids):
                shard = repo_s.ds_valid.shape[0]
                me = jax.lax.axis_index(axis)
                ds_index = repo_s.ds_index
                ds_sigs = repo_s.ds_sigs
                ds_valid = repo_s.ds_valid
                for i in range(bucket):
                    lid = slots[i] - me * shard
                    owns = (lid >= 0) & (lid < shard)
                    lidc = jnp.clip(lid, 0, shard - 1)

                    def wr(a, r):
                        return a.at[lidc].set(jnp.where(owns, r, a[lidc]))

                    ds_index = jax.tree.map(
                        wr, ds_index, jax.tree.map(lambda x: x[i], rows))
                    ds_sigs = wr(ds_sigs, sigs[i])
                    ds_valid = wr(ds_valid, valids[i])

                def gat(x):
                    # physical slot order == shard-major order, so the
                    # tiled gather reassembles global slot order; [:B_pad]
                    # trims the shard-alignment padding
                    return jax.lax.all_gather(x, axis, tiled=True)[:B_pad]

                roots = (gat(ds_index.centers[:, 0, :]),
                         gat(ds_index.radii[:, 0]),
                         gat(ds_index.box_lo[:, 0, :]),
                         gat(ds_index.box_hi[:, 0, :]),
                         gat(ds_sigs), gat(ds_valid))
                return ds_index, ds_sigs, ds_valid, roots

            stage = jax.jit(_shard_map(
                local, mesh=disp.mesh,
                in_specs=(specs, P(), P(), P(), P()),
                out_specs=(specs.ds_index, specs.ds_sigs, specs.ds_valid,
                           (P(), P(), P(), P(), P(), P())),
                check_vma=False))

        def fn(repo, slots, rows, sigs, valids):
            ds_index, ds_sigs, ds_valid, roots = stage(repo, slots, rows,
                                                       sigs, valids)
            return self._finish(repo, ds_index, ds_sigs, ds_valid, roots,
                                geom)

        return fn

    def _grow(self, push_free: bool = True) -> None:
        """Double the slot tier: zeros appended ON DEVICE (shard-aligned,
        no host upload), dispatcher layout constants refreshed, layout
        epoch bumped (executables closing over the old slot count are
        retired), and the grown state published as its own data epoch —
        dataset-op result rows change width with the slot axis, so they
        must retire too (per-slot point-op entries survive: no slot's
        contents changed).  ``push_free=False`` materializes a tier the
        prepare stage already reserved virtually (its ids are on the
        free list or held by prepared ingests)."""
        old_n = self.geometry.n_slots
        geom = self.geometry.grown()
        disp = self.engine.dispatch
        n_shards = int(getattr(disp, "n_shards", 1))
        n_phys = -(-geom.n_slots // n_shards) * n_shards
        if getattr(disp, "specs", None) is None:
            ds_index, ds_sigs, ds_valid = jax.jit(
                lambda repo: repo_mutate.pad_slots(repo, n_phys))(self.repo)
            B_pad = geom.n_slots
            roots = (ds_index.centers[:B_pad, 0, :],
                     ds_index.radii[:B_pad, 0],
                     ds_index.box_lo[:B_pad, 0, :],
                     ds_index.box_hi[:B_pad, 0, :],
                     ds_sigs[:B_pad], ds_valid[:B_pad])
            grown = self._finish(self.repo, ds_index, ds_sigs, ds_valid,
                                 roots, geom)
        else:
            grown = self._grow_sharded(geom, n_phys)
        self.geometry = geom
        self.slot_epochs = np.concatenate(
            [self.slot_epochs, np.zeros(geom.n_slots - old_n, np.int64)])
        if push_free:
            for s in range(old_n, geom.n_slots):
                heapq.heappush(self._free, s)
        else:
            self._grows_pending = max(0, self._grows_pending - 1)
        disp.n_slots = geom.n_slots
        if hasattr(disp, "shard_slots"):
            disp.n_slots_sharded = n_phys
            disp.shard_slots = n_phys // n_shards
        disp.repo_epoch = getattr(disp, "repo_epoch", 0) + 1
        self._updaters = {}
        self._publish(grown, touched=())

    def _grow_sharded(self, geom, n_phys: int) -> Repository:
        """Tier growth on a mesh, as an explicit shard_map (the
        jit-of-concat partitioner path psum-doubles replicated state on a
        (replica x data) mesh — see `_make_updater`).  Growth must keep
        the GLOBAL slot order (logical slot j at physical row j), so
        per-shard local zero-padding is wrong — each shard all-gathers
        the old slot arrays, appends the zero tier, and slices out its
        own re-balanced chunk.  Device-to-device only; nothing crosses
        the host boundary."""
        disp = self.engine.dispatch
        specs = disp.specs
        axis = disp.axis
        shard_new = n_phys // int(disp.n_shards)
        B_pad = geom.n_slots

        from jax.sharding import PartitionSpec as P
        from repro.core.distributed import _shard_map

        def local(repo_s):
            me = jax.lax.axis_index(axis)

            def full(x):
                f = jax.lax.all_gather(x, axis, tiled=True)
                z = jnp.zeros((n_phys - f.shape[0],) + f.shape[1:], f.dtype)
                return jnp.concatenate([f, z], axis=0)

            def loc(x):
                return jax.lax.dynamic_slice_in_dim(
                    x, me * shard_new, shard_new, 0)

            fi = jax.tree.map(full, repo_s.ds_index)
            fs = full(repo_s.ds_sigs)
            fv = full(repo_s.ds_valid)
            roots = (fi.centers[:B_pad, 0, :], fi.radii[:B_pad, 0],
                     fi.box_lo[:B_pad, 0, :], fi.box_hi[:B_pad, 0, :],
                     fs[:B_pad], fv[:B_pad])
            return jax.tree.map(loc, fi), loc(fs), loc(fv), roots

        sm = jax.jit(_shard_map(
            local, mesh=disp.mesh, in_specs=(specs,),
            out_specs=(specs.ds_index, specs.ds_sigs, specs.ds_valid,
                       (P(), P(), P(), P(), P(), P())),
            check_vma=False))

        ds_index, ds_sigs, ds_valid, roots = sm(self.repo)
        return self._finish(self.repo, ds_index, ds_sigs, ds_valid, roots,
                            geom)

    def _publish(self, new_repo: Repository, touched) -> None:
        """Atomically install the successor repository and its epoch.

        The dispatcher attribute swap is the linearization point: every
        later dispatch reads the new repository (late-bound executables),
        every in-flight one keeps the old buffers.  Then the engine's
        epoch install purges retired result rows (booked as
        ``epoch_invalidations``) so no future lookup can hit them."""
        disp = self.engine.dispatch
        disp.repo = new_repo
        self.engine.repo = new_repo
        self.engine._n_valid = len(self._live)
        self.epoch += 1
        for s in touched:
            self.slot_epochs[s] = self.epoch
        # `touched` makes the sweep precise: point-op entries for
        # untouched slots survive the publish (one sweep per coalesced
        # group, not per mutation)
        self.engine.set_repo_epoch(self.epoch, self.slot_epochs,
                                   touched=touched)
