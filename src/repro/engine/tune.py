"""One-time measured autotuning of the engine's dispatch constants.

`tune_engine` replaces the seed's hard-coded routing constants with
measurements taken at THIS engine's repository shapes:

* **kernel routing** — for each tuned op (the fused ``bound_grid`` at the
  engine's query-batch buckets, the per-pair ``directed_hausdorff`` and
  the pair-grid ``hausdorff_grid`` at the repository's point capacity),
  candidate :class:`~repro.kernels.autotune.KernelConfig`\\ s are timed
  through :func:`repro.kernels.autotune.ensure_tuned` and the winner is
  installed in the process-global table.  Tuned entries carry
  ``min_q = min_d = 1``, so a verdict applies to its whole
  ``(backend, op, shape bucket)`` — this is how measurement LOWERS the
  seed thresholds when the kernel wins below them.

* **bit-identity gate** — a kernel candidate is only allowed into the
  sweep if its output at the probe shape is BITWISE identical to the
  untuned default route's output.  XLA:CPU's FMA-contraction decisions
  are shape-dependent, so per-shape bitwise equality is an empirical
  property, not a given; gating on it makes "tuned constants never shift
  a result" operationally true — the tuner can only ever change speed.
  The default-route candidate always stays in the pool, so the sweep is
  never empty.

* **ExactHaus chunk** — the refinement chunk size is swept through REAL
  ``engine.search`` dispatches (result cache disabled for the sweep) and
  the per-op wall-clock booked in :class:`EngineStats.op_seconds` picks
  the winner, installed as ``engine.default_chunk``.  Chunk only tiles
  the refinement sweep — vals/ids are bit-identical under any chunk —
  so retuning it between calls is always safe.

The sweep costs a few compilations per candidate and is cached: repeated
``engine.tune()`` calls in one process short-circuit per (op, bucket)
unless ``force=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops
from repro.engine.query import Query

__all__ = ["tune_engine"]


def _bitwise_equal(a, b) -> bool:
    """Exact bitwise equality across a pytree pair (NaN-safe: identical
    bit patterns compare equal via the void view)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        if xa.shape != ya.shape or xa.dtype != ya.dtype:
            return False
        if xa.tobytes() != ya.tobytes():
            return False
    return True


def _gate(run, candidates, default_cfg):
    """Keep only candidates whose output bitwise matches the untuned
    default route's output at the probe shape; the default route itself
    always survives.  Returns (allowed, n_rejected)."""
    reference = jax.tree.map(np.asarray, run(default_cfg))
    allowed, rejected = [], 0
    for cfg in candidates:
        if _bitwise_equal(run(cfg), reference):
            allowed.append(cfg)
        else:
            rejected += 1
    if default_cfg not in allowed:
        allowed.insert(0, default_cfg)
    return allowed, rejected


def _sweep(op, shape, run, candidates, *, repeats, force):
    """Gate + ensure_tuned for one (op, probe shape); returns a report
    row whether the decision was fresh or cached."""
    default_cfg = autotune.DEFAULTS[op]
    # pin the default verdict for THIS shape: resolve applies the seed
    # threshold rule, and the reference output must be what an untuned
    # process produces at exactly this shape
    resolved = autotune.resolve(op, shape)
    default_pinned = autotune.KernelConfig(
        resolved.use_kernel, default_cfg.tq, default_cfg.td,
        tile=default_cfg.tile, min_q=1, min_d=1)
    allowed, rejected = _gate(run, candidates, default_pinned)

    def runner(cfg):
        jax.block_until_ready(run(cfg))

    chosen, info = autotune.ensure_tuned(
        op, shape, runner, allowed, repeats=repeats, force=force)
    return {
        "shape": tuple(int(s) for s in shape),
        "key": list(autotune.table_key(op, shape)),
        "use_kernel": chosen.use_kernel,
        "tq": chosen.tq, "td": chosen.td, "tile": chosen.tile,
        "candidates_rejected_bitwise": rejected,
        "timings_s": None if info is None else info["timings_s"],
        "cached": info is None,
    }


def _probe_sets(repo, n: int):
    """n valid point sets cycled from the repository (host arrays)."""
    pts = np.asarray(repo.ds_index.points)
    val = np.asarray(repo.ds_index.valid)
    live = [i for i in range(pts.shape[0]) if val[i].any()]
    return [pts[live[i % len(live)]][val[live[i % len(live)]]]
            for i in range(n)]


def tune_engine(
    engine,
    *,
    batches=(8, 32),
    chunks=(16, 32, 64),
    chunk_batch: int = 8,
    repeats: int = 3,
    force: bool = False,
) -> dict:
    """Measure-and-install the dispatch constants for ``engine``'s
    repository (see module docstring).  Returns a report dict; the tuned
    kernel verdicts land in the process-global autotune table (bumping
    its epoch, which re-keys the engine's executable cache) and the
    winning chunk lands in ``engine.default_chunk``."""
    repo = engine.repo
    ds = repo.ds_index
    report: dict = {"backend": jax.default_backend()}

    # -- fused bound grid: one probe per query-batch bucket ---------------
    S = int(ds.radii.shape[0])
    max_level = min(ds.depth, 3)
    n_nodes = ds.level_slice(max_level).stop
    levels = tuple((ds.level_slice(l).start, ds.level_slice(l).stop)
                   for l in range(max_level + 1))
    od = ds.centers[:, :n_nodes, :]
    rd = ds.radii[:, :n_nodes]
    dok = ds.counts[:, :n_nodes] > 0
    bg_cands = [
        autotune.KernelConfig(True, 8, 128, min_q=1, min_d=1),
        autotune.KernelConfig(True, 8, 64, min_q=1, min_d=1),
        autotune.KernelConfig(False, 8, 128, min_q=1, min_d=1),
    ]
    report["bound_grid"] = {}
    for b in batches:
        B = engine.bucket_for(int(b))
        sel = jnp.arange(B) % S
        oq = jnp.take(od, sel, axis=0)
        rq = jnp.take(rd, sel, axis=0)
        qok = jnp.take(dok, sel, axis=0)

        def run_bg(cfg, oq=oq, rq=rq, qok=qok):
            return ops.bound_grid(oq, rq, qok, od, rd, dok, levels=levels,
                                  tb=cfg.tq, ts=cfg.td,
                                  use_kernel=cfg.use_kernel)

        report["bound_grid"][str(B)] = _sweep(
            "bound_grid", (B, S), run_bg, bg_cands,
            repeats=repeats, force=force)

    # -- per-pair + pair-grid Hausdorff at the repo's point capacity ------
    n_pad = int(ds.points.shape[-2])
    sel = jnp.arange(2) % S
    q2 = jnp.take(ds.points, sel, axis=0)
    v2 = jnp.take(ds.valid, sel, axis=0)

    def run_haus(cfg):
        return ops.directed_hausdorff(q2[0], q2[1], v2[0], v2[1],
                                      tq=cfg.tq, td=cfg.td,
                                      use_kernel=cfg.use_kernel)

    report["directed_hausdorff"] = _sweep(
        "directed_hausdorff", (n_pad, n_pad), run_haus,
        [autotune.KernelConfig(True, 256, 512, min_q=1, min_d=1),
         autotune.KernelConfig(True, 128, 512, min_q=1, min_d=1),
         autotune.KernelConfig(False, 256, 512, min_q=1, min_d=1)],
        repeats=repeats, force=force)

    ds_grid = jnp.stack([q2, q2], axis=1)        # (2, C=2, n_pad, dim)
    dv_grid = jnp.stack([v2, v2], axis=1)

    def run_grid(cfg):
        return ops.directed_hausdorff_grid(
            q2, ds_grid, v2, dv_grid,
            tile=cfg.tile, tq=cfg.tq, td=cfg.td,
            use_kernel=cfg.use_kernel)

    report["hausdorff_grid"] = _sweep(
        "hausdorff_grid", (n_pad, n_pad), run_grid,
        [autotune.KernelConfig(True, 256, 512, tile=128, min_q=1, min_d=1),
         autotune.KernelConfig(False, 256, 512, tile=128, min_q=1, min_d=1),
         autotune.KernelConfig(False, 256, 512, tile=64, min_q=1, min_d=1)],
        repeats=repeats, force=force)

    # -- ExactHaus refinement chunk, timed through EngineStats ------------
    k = max(1, min(4, engine._n_valid))
    rows = engine._host_tree_rows(
        engine.build_queries(_probe_sets(repo, chunk_batch)))
    saved_cache = engine.result_cache_size
    engine.result_cache_size = 0      # repeats must dispatch, not memoize
    try:
        timings = []
        for chunk in chunks:
            queries = [Query(op="topk_hausdorff", q_index=row, k=k,
                             chunk=int(chunk)) for row in rows]
            engine.search(queries)                # warmup / compile
            before = engine.stats.op_seconds.get("topk_hausdorff", 0.0)
            for _ in range(repeats):
                engine.search(queries)
            after = engine.stats.op_seconds.get("topk_hausdorff", 0.0)
            timings.append((after - before) / repeats)
    finally:
        engine.result_cache_size = saved_cache
    best = int(np.argmin(timings))
    engine.default_chunk = int(chunks[best])
    report["chunk"] = {
        "candidates": [int(c) for c in chunks],
        "timings_s": timings,
        "chosen": engine.default_chunk,
    }
    report["table"] = autotune.report()
    return report
