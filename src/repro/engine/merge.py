"""O(k) result-merge helpers for the sharded engine (kernels-adjacent).

When the repository's dataset slots are sharded over a mesh axis, every
dataset-granularity top-k op runs its score pass per shard and must merge
per-shard candidate lists into the global top-k.  The merge is O(k) per
shard (gather S*k candidates, one final top_k) instead of O(B_pad)
(gathering every score), which is what makes the sharded engine's network
cost independent of the repository size.

Exactness contract (property-tested in tests/test_merge_properties.py):
per-shard lists produced by `jax.lax.top_k` over CONTIGUOUS ascending
global-id ranges, concatenated in shard order, merge bit-identically to a
single global `jax.lax.top_k` over the concatenated scores — including
duplicate scores, because top_k breaks ties toward the smallest index and
the (shard, local-rank) concatenation order coincides with ascending
global id for equal values.

`merge_topk` / `local_topk` are pure (no collectives) so they can be
property-tested on one device; `shard_topk` / `all_gather_topk` wrap them
with the mesh collectives and must run inside `shard_map`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# pure forms (no collectives; property-tested)
# ---------------------------------------------------------------------------


def merge_topk(vals: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """Global top-k over concatenated per-shard top-k lists.

    vals/ids: (..., M) with M >= k — per-shard descending lists laid out in
    shard order along the last axis.  Returns (vals (..., k), ids (..., k)),
    bit-identical to `jax.lax.top_k` over the unsharded scores (see module
    docstring for why ties resolve identically).
    """
    top, pos = jax.lax.top_k(vals, k)
    return top, jnp.take_along_axis(ids, pos, axis=-1)


def local_topk(scores: Array, k: int, base: Array | int) -> tuple[Array, Array]:
    """One shard's candidate list: local top-min(k, shard) + global ids.

    scores: (..., shard_slots) local scores; `base` is the shard's first
    global slot id.  min(k, shard) candidates per shard always suffice for
    a global top-k with k <= total slots.
    """
    k_loc = min(k, scores.shape[-1])
    vals, ids = jax.lax.top_k(scores, k_loc)
    return vals, ids + base


def sentinel_ids(vals: Array, ids: Array, sentinel: int = -1) -> Array:
    """Mask ids of negative-scored (padded/invalid) slots with `sentinel`.

    Commutes with the merge: applying it to per-shard lists before merging
    or to the merged list afterwards yields the same ids, because the
    sentinel only depends on the value riding along with each id.
    """
    return jnp.where(vals < 0, sentinel, ids)


# ---------------------------------------------------------------------------
# collective forms (inside shard_map only)
# ---------------------------------------------------------------------------


def all_gather_topk(
    vals: Array, gids: Array, k: int, axis: str
) -> tuple[Array, Array]:
    """O(k) merge of per-shard lists across `axis`: all-gather the (..., k')
    lists along the last axis (shard order == ascending global id) and run
    the final top_k.  Output is replicated."""
    cat_v = jax.lax.all_gather(vals, axis, axis=vals.ndim - 1, tiled=True)
    cat_i = jax.lax.all_gather(gids, axis, axis=gids.ndim - 1, tiled=True)
    return merge_topk(cat_v, cat_i, k)


def shard_topk(scores: Array, k: int, axis: str) -> tuple[Array, Array]:
    """Sharded top-k over the last (slot) axis: local top-k, O(k) all-gather
    merge.  `scores` is the local (..., shard_slots) score slice."""
    base = jax.lax.axis_index(axis) * scores.shape[-1]
    vals, gids = local_topk(scores, k, base)
    return all_gather_topk(vals, gids, k, axis)
