"""Batched (multi-query) forms of every search op (engine hot paths).

The seed search layer answers one query per call; each of these functions
answers B queries in ONE device dispatch, either by vmapping the seed op's
pure-jax core over a leading query axis or — where the batched form is
itself the natural kernel shape (GBO popcount matrix, IA box algebra) — by
evaluating the whole (B, B_pad) interaction directly.  Results are
elementwise identical to a per-query Python loop over the seed ops
(asserted in tests/test_engine.py); none of them sync to the host.

Query batches arrive pre-padded to a shape bucket by the QueryEngine; rows
past the caller's true batch are padding and are sliced off by the engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import geometry, join_search, point_search, search
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository
from repro.kernels import ops

Array = jax.Array
BIG = search.BIG


# ---------------------------------------------------------------------------
# dataset granularity
# ---------------------------------------------------------------------------


def range_search_batched(repo: Repository, r_lo: Array, r_hi: Array):
    """RangeS for B query boxes: (masks (B, B_pad), live_nodes (B,))."""
    masks, live, _ = jax.vmap(
        search._range_search_core, in_axes=(None, 0, 0)
    )(repo, r_lo, r_hi)
    return masks, live


def topk_ia_batched(repo: Repository, q_lo: Array, q_hi: Array, k: int):
    """Top-k IA for B query boxes: (vals (B, k), ids (B, k)).

    IA is O(1) per (query, dataset) pair, so the batch is one dense
    (B, B_pad) box-algebra pass + a row-wise top_k.
    """
    _, _, lo, hi = repo.roots()
    ia = geometry.intersect_area(
        lo[None, :, :], hi[None, :, :], q_lo[:, None, :], q_hi[:, None, :]
    )
    ia = jnp.where(repo.ds_valid[None, :], ia, -1.0)
    vals, ids = jax.lax.top_k(ia, k)
    ids = jnp.where(vals < 0, -1, ids)
    return vals, ids


def topk_gbo_batched(repo: Repository, q_sigs: Array, k: int):
    """Top-k GBO for B query signatures — ONE popcount(AND) matrix kernel."""
    counts = ops.set_intersect_counts(q_sigs, repo.ds_sigs)   # (B, B_pad)
    counts = jnp.where(repo.ds_valid[None, :], counts, -1)
    vals, ids = jax.lax.top_k(counts, k)
    ids = jnp.where(vals < 0, -1, ids)
    return vals, ids


def topk_join_batched(repo: Repository, q_pts: Array, q_val: Array, k: int,
                      mode: str, chunk: int):
    """Joinable top-k (grid overlap / coverage) for B raw query point sets:
    coarse-signature bound phase, then the shared-order chunked exact
    refine (see :mod:`repro.core.join_search`).  Returns
    (vals (B, k), ids (B, k), nodes (B,), cand_after (B,), evaluated (B,))
    with -1 sentinels past the valid / unpruned supply."""
    exact, nodes, cand, evaluated = join_search.topk_join_scores(
        repo, q_pts, q_val, k, mode, chunk)
    vals, ids = jax.lax.top_k(exact, k)
    ids = jnp.where(vals < 0, -1, ids)
    return vals, ids, nodes, cand, evaluated


# ---------------------------------------------------------------------------
# ApproHaus, batched with per-query stopping levels
# ---------------------------------------------------------------------------


def _levels_ok(radii: Array, counts: Array, depth: int, eps) -> Array:
    """(depth+1,) bool: does level l satisfy the Lemma 1 stopping rule
    (every live node radius < eps)?  Reduces over ALL leading dims, matching
    `search.approx_level` on both single and batched indexes."""
    oks = []
    for level in range(depth + 1):
        sl = slice((1 << level) - 1, (1 << (level + 1)) - 1)
        ok = jnp.all(
            jnp.where(counts[..., sl] > 0, radii[..., sl], 0.0) < eps
        )
        oks.append(ok)
    return jnp.stack(oks)


def _level_for_eps(radii: Array, counts: Array, depth: int, eps) -> Array:
    """Device-side `search.approx_level`: first satisfying level, else the
    leaf level.  Traced — per-query levels cost no host sync."""
    oks = _levels_ok(radii, counts, depth, eps)
    return jnp.where(jnp.any(oks), jnp.argmax(oks), depth).astype(jnp.int32)


def _gather_frontier(centers, radii, counts, level, n_leaves: int):
    """The level-`level` node frontier, gathered into a fixed (n_leaves,)
    buffer (+ in-frontier mask) so a traced per-query level keeps static
    shapes.  Node (l, j) lives at flat slot 2^l - 1 + j."""
    start = jnp.left_shift(jnp.int32(1), level) - 1
    j = jnp.arange(n_leaves, dtype=jnp.int32)
    node = jnp.minimum(start + j, centers.shape[-2] - 1)
    mask = j < jnp.left_shift(jnp.int32(1), level)
    return (
        jnp.take(centers, node, axis=-2),
        jnp.take(radii, node, axis=-1),
        jnp.take(counts, node, axis=-1),
        mask,
    )


def topk_hausdorff_approx_batched(
    repo: Repository, q_batch: DatasetIndex, k: int, eps
):
    """ApproHaus (Lemma 1) for a (B, ...) batch of query indexes.

    Each query descends to ITS OWN stopping level (chosen on device), so
    results match the seed per-query op exactly; the whole batch is one
    dispatch.  Returns (vals (B, k), ids (B, k), eps_eff (B,)).
    """
    dq = q_batch.depth
    dd = repo.ds_index.depth
    n_lq = 1 << dq
    n_ld = 1 << dd

    # dataset-side level: shared by every query (matches the seed, which
    # picks it from the whole batched ds_index)
    ld = _level_for_eps(repo.ds_index.radii, repo.ds_index.counts, dd, eps)
    od, rd, cd, dmask = _gather_frontier(
        repo.ds_index.centers, repo.ds_index.radii, repo.ds_index.counts,
        ld, n_ld,
    )                                    # (B_pad, n_ld, d), ..., (n_ld,)
    d_ok = (cd > 0) & dmask[None, :]     # (B_pad, n_ld)
    r_d = jnp.max(jnp.where(d_ok, rd, 0.0))

    def per_query(q_centers, q_radii, q_counts):
        lq = _level_for_eps(q_radii, q_counts, dq, eps)
        oq, rq, cq, qmask = _gather_frontier(q_centers, q_radii, q_counts,
                                             lq, n_lq)
        q_ok = (cq > 0) & qmask

        def one(od_i, ok_i):
            cdm = geometry.pairwise_dist_exact(oq, od_i)
            cdm = jnp.where(ok_i[None, :], cdm, BIG)
            row = jnp.min(cdm, axis=1)
            return jnp.max(jnp.where(q_ok, row, -BIG))

        vals = jax.vmap(one)(od, d_ok)
        vals = jnp.where(repo.ds_valid, vals, BIG)
        top_vals, top_ids = jax.lax.top_k(-vals, k)
        r_q = jnp.max(jnp.where(q_ok, rq, 0.0))
        eps_eff = jnp.maximum(jnp.asarray(eps, r_q.dtype),
                              jnp.maximum(r_q, r_d))
        return -top_vals, top_ids, eps_eff

    return jax.vmap(per_query)(
        q_batch.centers, q_batch.radii, q_batch.counts
    )


# ---------------------------------------------------------------------------
# ExactHaus, batched branch-and-bound
# ---------------------------------------------------------------------------


def topk_hausdorff_batched(
    repo: Repository, q_batch: DatasetIndex, k: int,
    refine_levels: int = 3, chunk: int = 32,
):
    """ExactHaus for a (B, ...) batch of query indexes, ONE dispatch.

    Phases 0/1 compute the Eq. 4 bound matrices for all B queries in one
    vmapped pass; phase 2 is a single `lax.while_loop` over the shared
    (query, candidate-chunk) work frontier with per-query tau tightening
    (`search._topk_hausdorff_device_batched`).  Per-query (vals, ids) are
    bit-identical to the solo pipeline and the seed host loop
    `topk_hausdorff_host`; with the same ``chunk`` the per-query
    `evaluated` counters match the solo loop too (each query's trajectory
    is its solo loop run in lockstep).

    Returns (vals (B, k), ids (B, k), nodes (B,), cand_after (B,),
    evaluated (B,)).
    """
    return search._topk_hausdorff_device_batched(
        repo, q_batch, k=k, refine_levels=refine_levels, chunk=chunk
    )


# ---------------------------------------------------------------------------
# point granularity
# ---------------------------------------------------------------------------


def _select_datasets(repo: Repository, ds_ids: Array) -> DatasetIndex:
    """Gather the per-request dataset trees: one bottom-level index row per
    request (requests in a batch may target different datasets)."""
    return jax.tree.map(lambda x: x[ds_ids], repo.ds_index)


def range_points_batched(
    repo: Repository, ds_ids: Array, r_lo: Array, r_hi: Array
):
    """RangeP for B (dataset id, box) requests: (take (B, n_pad), scanned)."""
    d_sel = _select_datasets(repo, ds_ids)
    return jax.vmap(point_search.range_points_core)(d_sel, r_lo, r_hi)


def nnp_pruned_batched(
    repo: Repository, ds_ids: Array, q_batch: DatasetIndex
):
    """Tree-pruned NNP for B (query index, dataset id) requests.

    Returns (dists (B, nq), idx (B, nq), pair_live (B, qleaf, dleaf))."""
    d_sel = _select_datasets(repo, ds_ids)
    return jax.vmap(point_search.nnp_pruned_core)(q_batch, d_sel)
