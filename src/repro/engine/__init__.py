"""Batched multi-query execution engine over the unified Spadas index.

`QueryEngine` buckets incoming query batches into fixed shapes, caches one
jitted executable per (op, shape-bucket, k), and answers B queries with a
single device dispatch per op; `batched_ops` holds the pure-jax batched
forms of every dataset- and point-granularity search operation.  Dispatch
is pluggable: `ShardedQueryEngine` shards the resident repository's
dataset slots over the ``data`` mesh axis and merges per-shard results on
device (`merge` holds the O(k) top-k merge helpers), bit-identical to the
single-device engine.

The DECLARATIVE front door is `QueryEngine.search(list[Query | Pipeline])
-> list[SearchResult]` (`query` holds the frozen specs, `plan` the
mixed-batch planner); the per-op batch methods survive as deprecated
shims over it.

`LiveRepository` (`live`) makes the resident repository MUTABLE: online
ingest / delete / replace under a pinned cold-build geometry
(`core/repo_mutate`), epoch-versioned result and executable caches, and
bit-identity with a cold build of the equivalent frozen repository after
any mutation sequence — on all three dispatchers.

The JOINABLE op family (`core/join_search`) adds dataset->dataset search
over the same resident repository: ``topk_overlap`` / ``topk_coverage``
score every slot's grid-cell overlap (resp. point coverage) against a raw
query point set, with a coarse-signature bound phase pruning slots before
the exact fine-grid refine, and `Pipeline` accepts a joinable second
stage that re-ranks stage-1 dataset winners by joinability.
"""
from repro.engine.batched_ops import (  # noqa: F401
    nnp_pruned_batched,
    range_points_batched,
    range_search_batched,
    topk_gbo_batched,
    topk_hausdorff_approx_batched,
    topk_ia_batched,
    topk_join_batched,
)
from repro.engine.engine import (  # noqa: F401
    DEFAULT_BUCKETS,
    EngineStats,
    LocalDispatcher,
    QueryEngine,
)
from repro.engine.live import (  # noqa: F401
    LiveRepository,
)
from repro.engine.query import (  # noqa: F401
    DATASET_RERANK_OPS,
    DATASET_TOPK_OPS,
    OPS,
    POINT_OPS,
    Pipeline,
    Query,
    SearchResult,
)
from repro.engine.replicated import (  # noqa: F401
    ReplicatedDispatcher,
    ReplicatedQueryEngine,
    replica_mesh,
)
from repro.engine.sharded import (  # noqa: F401
    ShardedDispatcher,
    ShardedQueryEngine,
    data_mesh,
    repo_device_bytes,
    shard_repository,
)
