"""QueryEngine: the batched multi-query execution engine.

The engine owns a resident :class:`Repository` and turns ragged streams of
incoming queries into fixed-shape device work:

  * **shape bucketing** — a batch of B queries is padded (by replicating the
    first row) up to the smallest configured bucket >= B, so the number of
    distinct compiled shapes is bounded by the bucket ladder, not by the
    traffic;
  * **executable cache** — one jitted executable per (op, bucket, k) key,
    built lazily on first use and reused for every later batch that lands
    in the same bucket (every dispatch records a hit or a miss, so
    `stats.cache_hits + stats.cache_misses == stats.dispatches`);
  * **single dispatch** — every op lowers to exactly one device computation
    per batch; no per-query Python loop, no per-chunk host sync.

Dispatch is **pluggable**: the engine delegates the construction of every
device callable to a dispatcher object.  :class:`LocalDispatcher` (the
default) closes each executable over the single-device repository and the
vmapped forms in :mod:`repro.engine.batched_ops`;
:class:`repro.engine.sharded.ShardedDispatcher` (selected by passing
``mesh=``) places the repository's dataset slots across a mesh axis and
merges per-shard results on device.  Bucketing, the executable cache,
query construction, and :class:`EngineStats` are shared between the two —
sharded and unsharded engines differ ONLY in the callables they cache.

Query point sets are themselves bucketed: `build_queries` pads a ragged
list of point sets to a power-of-two point capacity and builds all their
ball-tree indexes in one vmapped build.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core import search
from repro.core.build import pad_batch
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository
from repro.engine import batched_ops

Array = jax.Array

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@dataclass
class EngineStats:
    """Cumulative engine observability counters.

    Every dispatch is recorded through :meth:`count`, which also books the
    executable-cache outcome — the invariant
    ``cache_hits + cache_misses == dispatches`` holds at all times and is
    asserted in tests.  ``per_op`` keeps the same breakdown per op name.
    """
    queries: int = 0                 # client queries ANSWERED (ops only)
    dispatches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    padded_queries: int = 0          # bucket padding overhead actually paid
    per_op: dict = field(default_factory=dict)

    def count(self, op: str, batch: int, bucket: int, *,
              cached: bool, internal: bool = False) -> None:
        """Record ONE dispatch.  ``internal=True`` (build_queries) books the
        dispatch and its cache outcome but keeps `queries`/`padded_queries`
        counting only answered client queries — a query that flows through
        build_queries AND an op must not be double-counted.  The per-op
        breakdown still records the batch under the internal op's name."""
        if not internal:
            self.queries += batch
            self.padded_queries += bucket - batch
        self.dispatches += 1
        if cached:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        per = self.per_op.setdefault(
            op, {"queries": 0, "dispatches": 0, "hits": 0, "misses": 0})
        per["queries"] += batch
        per["dispatches"] += 1
        per["hits" if cached else "misses"] += 1

    def record_search(self, op: str, stats) -> None:
        """Fold one dispatch's :class:`~repro.core.search.SearchStats` into
        the per-op breakdown: cumulative node/candidate/exact-evaluation
        counters plus the latest pruned fraction.  ExactHaus books these on
        every call (the engine no longer discards its SearchStats)."""
        per = self.per_op.setdefault(
            op, {"queries": 0, "dispatches": 0, "hits": 0, "misses": 0})
        per["nodes_evaluated"] = (
            per.get("nodes_evaluated", 0) + stats.nodes_evaluated)
        per["candidates_after_bounds"] = (
            per.get("candidates_after_bounds", 0)
            + stats.candidates_after_bounds)
        per["exact_evaluations"] = (
            per.get("exact_evaluations", 0) + stats.exact_evaluations)
        per["pruned_fraction"] = stats.pruned_fraction


class LocalDispatcher:
    """Single-device dispatch: one jitted executable per op over the
    resident repository.

    Each ``build_*`` returns a callable taking only the query-side operands;
    the repository rides along as a bound leading argument (not a closed-over
    constant, so XLA never bakes the arrays into the executable).
    """

    name = "local"

    def __init__(self, repo: Repository):
        self.repo = repo
        self.n_slots = repo.n_slots

    def build_range_search(self):
        return partial(jax.jit(batched_ops.range_search_batched), self.repo)

    def build_topk_ia(self, k: int):
        return partial(
            jax.jit(partial(batched_ops.topk_ia_batched, k=k)), self.repo)

    def build_topk_gbo(self, k: int):
        return partial(
            jax.jit(partial(batched_ops.topk_gbo_batched, k=k)), self.repo)

    def build_topk_hausdorff_approx(self, k: int):
        return partial(
            jax.jit(partial(batched_ops.topk_hausdorff_approx_batched, k=k)),
            self.repo)

    def build_topk_hausdorff(self, k: int, refine_levels: int, chunk: int):
        return partial(search._topk_hausdorff_device, self.repo, k=k,
                       refine_levels=refine_levels, chunk=chunk)

    def build_range_points(self):
        return partial(jax.jit(batched_ops.range_points_batched), self.repo)

    def build_nnp(self):
        return partial(jax.jit(batched_ops.nnp_pruned_batched), self.repo)


class QueryEngine:
    """Batched search over a resident repository (see module docstring).

    Passing ``mesh=`` (a `jax.sharding.Mesh`) selects the sharded dispatch
    path: dataset slots are placed across ``shard_spec`` (a mesh axis name,
    default ``"data"``) and per-shard results are merged on device —
    bit-identical to the local path (asserted in
    tests/test_engine_sharded.py).
    """

    def __init__(
        self,
        repo: Repository,
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        leaf_capacity: int = 16,
        mesh=None,
        shard_spec: str = "data",
        dispatcher=None,
    ):
        self.buckets = tuple(sorted(buckets))
        self.leaf_capacity = leaf_capacity
        self.stats = EngineStats()
        self._executables: dict = {}
        self._n_valid = int(repo.ds_valid.sum())
        if dispatcher is None:
            if mesh is not None:
                from repro.engine.sharded import ShardedDispatcher
                dispatcher = ShardedDispatcher(repo, mesh, axis=shard_spec)
            else:
                dispatcher = LocalDispatcher(repo)
        self.dispatch = dispatcher
        # hold the dispatcher's PLACED repository (the sharded copy under a
        # ShardedDispatcher) rather than the builder's, so the engine never
        # pins an extra replicated copy once the caller drops theirs
        self.repo = getattr(dispatcher, "repo", repo)

    # -- bucketing ---------------------------------------------------------

    def bucket_for(self, batch: int) -> int:
        for b in self.buckets:
            if b >= batch:
                return b
        b = self.buckets[-1]
        while b < batch:          # beyond the ladder: grow geometrically
            b *= 2
        return b

    @staticmethod
    def _pad_rows(x: Array, bucket: int) -> Array:
        """Pad a (B, ...) array to (bucket, ...) by replicating row 0 —
        padding rows recompute a real query, so no masking is needed and
        results for them are simply sliced off."""
        b = x.shape[0]
        if b == bucket:
            return x
        reps = jnp.broadcast_to(x[:1], (bucket - b,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)

    def _pad_tree(self, tree, bucket: int):
        return jax.tree.map(lambda x: self._pad_rows(x, bucket), tree)

    # -- executable cache --------------------------------------------------

    def _executable(self, key, build):
        """Cache lookup; returns (fn, cached) so the dispatch path can book
        the hit/miss through `stats.count` uniformly for every op."""
        fn = self._executables.get(key)
        cached = fn is not None
        if not cached:
            fn = build()
            self._executables[key] = fn
        return fn, cached

    # -- query construction ------------------------------------------------

    def build_queries(
        self, pointsets: Sequence[np.ndarray]
    ) -> DatasetIndex:
        """Index a ragged list of query point sets as one (B, ...) batch.

        Point counts are bucketed to the next power of two (so repeated
        traffic reuses executables) and the B tree builds run as one
        vmapped dispatch.  Queries are replicated (never sharded): both
        dispatch paths consume the same batched query index.
        """
        n_max = max(int(p.shape[0]) for p in pointsets)
        n_bucket = self.leaf_capacity
        while n_bucket < n_max:
            n_bucket *= 2
        depth = index_lib.depth_for(n_bucket, self.leaf_capacity)
        pts, val, depth = pad_batch(pointsets, self.leaf_capacity, depth)
        bucket = self.bucket_for(len(pointsets))
        pts = self._pad_rows(pts, bucket)
        val = self._pad_rows(val, bucket)
        build, cached = self._executable(
            ("build", bucket, pts.shape[1], depth),
            lambda: jax.jit(partial(index_lib.build_index_batch,
                                    depth=depth)),
        )
        q_batch = build(pts, val)
        self.stats.count("build_queries", len(pointsets), bucket,
                         cached=cached, internal=True)
        return jax.tree.map(lambda x: x[: len(pointsets)], q_batch)

    # -- dataset-granularity ops ------------------------------------------

    def range_search(self, r_lo, r_hi):
        """RangeS for B query boxes -> dataset masks (B, B_pad)."""
        r_lo = jnp.atleast_2d(jnp.asarray(r_lo, jnp.float32))
        r_hi = jnp.atleast_2d(jnp.asarray(r_hi, jnp.float32))
        B = r_lo.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("range_search", bucket), self.dispatch.build_range_search)
        masks, _ = fn(self._pad_rows(r_lo, bucket),
                      self._pad_rows(r_hi, bucket))
        self.stats.count("range_search", B, bucket, cached=cached)
        return masks[:B]

    def topk_ia(self, q_lo, q_hi, k: int):
        """Top-k IA for B query boxes -> (vals, ids) each (B, k)."""
        q_lo = jnp.atleast_2d(jnp.asarray(q_lo, jnp.float32))
        q_hi = jnp.atleast_2d(jnp.asarray(q_hi, jnp.float32))
        B = q_lo.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("topk_ia", bucket, k),
            lambda: self.dispatch.build_topk_ia(k))
        vals, ids = fn(self._pad_rows(q_lo, bucket),
                       self._pad_rows(q_hi, bucket))
        self.stats.count("topk_ia", B, bucket, cached=cached)
        return vals[:B], ids[:B]

    def topk_gbo(self, q_sigs, k: int):
        """Top-k GBO for B query signatures -> (vals, ids) each (B, k)."""
        q_sigs = jnp.asarray(q_sigs)
        if q_sigs.ndim == 1:
            q_sigs = q_sigs[None, :]
        B = q_sigs.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("topk_gbo", bucket, k),
            lambda: self.dispatch.build_topk_gbo(k))
        vals, ids = fn(self._pad_rows(q_sigs, bucket))
        self.stats.count("topk_gbo", B, bucket, cached=cached)
        return vals[:B], ids[:B]

    def topk_hausdorff_approx(self, q_batch: DatasetIndex, k: int, eps):
        """ApproHaus for a (B, ...) query-index batch -> (vals, ids, eps_eff)."""
        B = q_batch.points.shape[0]
        bucket = self.bucket_for(B)
        key = ("approx_haus", bucket, q_batch.points.shape[1], k)
        fn, cached = self._executable(
            key, lambda: self.dispatch.build_topk_hausdorff_approx(k))
        padded = self._pad_tree(q_batch, bucket)
        vals, ids, eps_eff = fn(padded, eps=jnp.float32(eps))
        self.stats.count("topk_hausdorff_approx", B, bucket, cached=cached)
        return vals[:B], ids[:B], eps_eff[:B]

    def topk_hausdorff(self, q_idx: DatasetIndex, k: int, *,
                       refine_levels: int = 3, chunk: int = 32):
        """ExactHaus for ONE query — the device-resident branch-and-bound
        pipeline (single dispatch, `lax.while_loop` refinement; per-shard
        loops + tau all-reduce under a ShardedDispatcher).

        Returns (vals (k,), ids (k,), SearchStats); the stats are also
        folded into ``self.stats`` (cumulative evaluated count and the
        pruned fraction per op) instead of being discarded.
        """
        fn, cached = self._executable(
            ("exact_haus", q_idx.points.shape[0], k, refine_levels, chunk),
            lambda: self.dispatch.build_topk_hausdorff(k, refine_levels,
                                                       chunk))
        vals, ids, nodes, cand_after, evaluated = fn(q_idx)
        self.stats.count("topk_hausdorff", 1, 1, cached=cached)
        stats = search.SearchStats(
            int(nodes), int(cand_after), int(evaluated),
            1.0 - int(evaluated) / max(self._n_valid, 1),
        )
        self.stats.record_search("topk_hausdorff", stats)
        return vals, ids, stats

    # -- point-granularity ops --------------------------------------------

    def range_points(self, ds_ids, r_lo, r_hi):
        """RangeP for B (dataset id, box) requests -> take masks (B, n_pad)."""
        ds_ids = jnp.atleast_1d(jnp.asarray(ds_ids, jnp.int32))
        r_lo = jnp.atleast_2d(jnp.asarray(r_lo, jnp.float32))
        r_hi = jnp.atleast_2d(jnp.asarray(r_hi, jnp.float32))
        B = ds_ids.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("range_points", bucket), self.dispatch.build_range_points)
        take, _ = fn(self._pad_rows(ds_ids, bucket),
                     self._pad_rows(r_lo, bucket),
                     self._pad_rows(r_hi, bucket))
        self.stats.count("range_points", B, bucket, cached=cached)
        return take[:B]

    def nnp(self, ds_ids, q_batch: DatasetIndex):
        """Tree-pruned NNP for B (query, dataset id) requests ->
        (dists (B, nq), idx (B, nq))."""
        ds_ids = jnp.atleast_1d(jnp.asarray(ds_ids, jnp.int32))
        B = ds_ids.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("nnp", bucket, q_batch.points.shape[1]),
            self.dispatch.build_nnp)
        dists, idxs, _ = fn(self._pad_rows(ds_ids, bucket),
                            self._pad_tree(q_batch, bucket))
        self.stats.count("nnp", B, bucket, cached=cached)
        return dists[:B], idxs[:B]
