"""QueryEngine: the batched multi-query execution engine.

The engine owns a resident :class:`Repository` and turns ragged streams of
incoming queries into fixed-shape device work:

  * **shape bucketing** — a batch of B queries is padded (by replicating the
    first row) up to the smallest configured bucket >= B, so the number of
    distinct compiled shapes is bounded by the bucket ladder, not by the
    traffic;
  * **executable cache** — one jitted executable per (op, bucket, k) key,
    built lazily on first use and reused for every later batch that lands
    in the same bucket (hits/misses are counted for observability);
  * **single dispatch** — every op lowers to exactly one device computation
    per batch via the vmapped forms in :mod:`repro.engine.batched_ops`;
    no per-query Python loop, no per-chunk host sync.

Query point sets are themselves bucketed: `build_queries` pads a ragged
list of point sets to a power-of-two point capacity and builds all their
ball-tree indexes in one vmapped build.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core import search
from repro.core.build import pad_batch
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository
from repro.engine import batched_ops

Array = jax.Array

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@dataclass
class EngineStats:
    """Cumulative engine observability counters."""
    queries: int = 0
    dispatches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    padded_queries: int = 0          # bucket padding overhead actually paid
    per_op: dict = field(default_factory=dict)

    def count(self, op: str, batch: int, bucket: int) -> None:
        self.queries += batch
        self.dispatches += 1
        self.padded_queries += bucket - batch
        self.per_op[op] = self.per_op.get(op, 0) + batch


class QueryEngine:
    """Batched search over a resident repository (see module docstring)."""

    def __init__(
        self,
        repo: Repository,
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        leaf_capacity: int = 16,
    ):
        self.repo = repo
        self.buckets = tuple(sorted(buckets))
        self.leaf_capacity = leaf_capacity
        self.stats = EngineStats()
        self._executables: dict = {}

    # -- bucketing ---------------------------------------------------------

    def bucket_for(self, batch: int) -> int:
        for b in self.buckets:
            if b >= batch:
                return b
        b = self.buckets[-1]
        while b < batch:          # beyond the ladder: grow geometrically
            b *= 2
        return b

    @staticmethod
    def _pad_rows(x: Array, bucket: int) -> Array:
        """Pad a (B, ...) array to (bucket, ...) by replicating row 0 —
        padding rows recompute a real query, so no masking is needed and
        results for them are simply sliced off."""
        b = x.shape[0]
        if b == bucket:
            return x
        reps = jnp.broadcast_to(x[:1], (bucket - b,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)

    def _pad_tree(self, tree, bucket: int):
        return jax.tree.map(lambda x: self._pad_rows(x, bucket), tree)

    # -- executable cache --------------------------------------------------

    def _executable(self, key, build):
        fn = self._executables.get(key)
        if fn is None:
            fn = build()
            self._executables[key] = fn
            self.stats.cache_misses += 1
        else:
            self.stats.cache_hits += 1
        return fn

    # -- query construction ------------------------------------------------

    def build_queries(
        self, pointsets: Sequence[np.ndarray]
    ) -> DatasetIndex:
        """Index a ragged list of query point sets as one (B, ...) batch.

        Point counts are bucketed to the next power of two (so repeated
        traffic reuses executables) and the B tree builds run as one
        vmapped dispatch.
        """
        n_max = max(int(p.shape[0]) for p in pointsets)
        n_bucket = self.leaf_capacity
        while n_bucket < n_max:
            n_bucket *= 2
        depth = index_lib.depth_for(n_bucket, self.leaf_capacity)
        pts, val, depth = pad_batch(pointsets, self.leaf_capacity, depth)
        bucket = self.bucket_for(len(pointsets))
        pts = self._pad_rows(pts, bucket)
        val = self._pad_rows(val, bucket)
        build = self._executable(
            ("build", bucket, pts.shape[1], depth),
            lambda: jax.jit(partial(index_lib.build_index_batch,
                                    depth=depth)),
        )
        q_batch = build(pts, val)
        return jax.tree.map(lambda x: x[: len(pointsets)], q_batch)

    # -- dataset-granularity ops ------------------------------------------

    def range_search(self, r_lo, r_hi):
        """RangeS for B query boxes -> dataset masks (B, B_pad)."""
        r_lo = jnp.atleast_2d(jnp.asarray(r_lo, jnp.float32))
        r_hi = jnp.atleast_2d(jnp.asarray(r_hi, jnp.float32))
        B = r_lo.shape[0]
        bucket = self.bucket_for(B)
        fn = self._executable(
            ("range_search", bucket),
            lambda: jax.jit(batched_ops.range_search_batched),
        )
        masks, _ = fn(self.repo, self._pad_rows(r_lo, bucket),
                      self._pad_rows(r_hi, bucket))
        self.stats.count("range_search", B, bucket)
        return masks[:B]

    def topk_ia(self, q_lo, q_hi, k: int):
        """Top-k IA for B query boxes -> (vals, ids) each (B, k)."""
        q_lo = jnp.atleast_2d(jnp.asarray(q_lo, jnp.float32))
        q_hi = jnp.atleast_2d(jnp.asarray(q_hi, jnp.float32))
        B = q_lo.shape[0]
        bucket = self.bucket_for(B)
        fn = self._executable(
            ("topk_ia", bucket, k),
            lambda: jax.jit(partial(batched_ops.topk_ia_batched, k=k)),
        )
        vals, ids = fn(self.repo, self._pad_rows(q_lo, bucket),
                       self._pad_rows(q_hi, bucket))
        self.stats.count("topk_ia", B, bucket)
        return vals[:B], ids[:B]

    def topk_gbo(self, q_sigs, k: int):
        """Top-k GBO for B query signatures -> (vals, ids) each (B, k)."""
        q_sigs = jnp.asarray(q_sigs)
        if q_sigs.ndim == 1:
            q_sigs = q_sigs[None, :]
        B = q_sigs.shape[0]
        bucket = self.bucket_for(B)
        fn = self._executable(
            ("topk_gbo", bucket, k),
            lambda: jax.jit(partial(batched_ops.topk_gbo_batched, k=k)),
        )
        vals, ids = fn(self.repo, self._pad_rows(q_sigs, bucket))
        self.stats.count("topk_gbo", B, bucket)
        return vals[:B], ids[:B]

    def topk_hausdorff_approx(self, q_batch: DatasetIndex, k: int, eps):
        """ApproHaus for a (B, ...) query-index batch -> (vals, ids, eps_eff)."""
        B = q_batch.points.shape[0]
        bucket = self.bucket_for(B)
        key = ("approx_haus", bucket, q_batch.points.shape[1], k)
        fn = self._executable(
            key,
            lambda: jax.jit(
                partial(batched_ops.topk_hausdorff_approx_batched, k=k)
            ),
        )
        padded = self._pad_tree(q_batch, bucket)
        vals, ids, eps_eff = fn(self.repo, padded, eps=jnp.float32(eps))
        self.stats.count("topk_hausdorff_approx", B, bucket)
        return vals[:B], ids[:B], eps_eff[:B]

    def topk_hausdorff(self, q_idx: DatasetIndex, k: int, *,
                       refine_levels: int = 3, chunk: int = 32):
        """ExactHaus for ONE query — the device-resident branch-and-bound
        pipeline (single dispatch, `lax.while_loop` refinement)."""
        fn = self._executable(
            ("exact_haus", q_idx.points.shape[0], k, refine_levels, chunk),
            lambda: partial(search._topk_hausdorff_device, k=k,
                            refine_levels=refine_levels, chunk=chunk),
        )
        vals, ids, *_ = fn(self.repo, q_idx)
        self.stats.count("topk_hausdorff", 1, 1)
        return vals, ids

    # -- point-granularity ops --------------------------------------------

    def range_points(self, ds_ids, r_lo, r_hi):
        """RangeP for B (dataset id, box) requests -> take masks (B, n_pad)."""
        ds_ids = jnp.atleast_1d(jnp.asarray(ds_ids, jnp.int32))
        r_lo = jnp.atleast_2d(jnp.asarray(r_lo, jnp.float32))
        r_hi = jnp.atleast_2d(jnp.asarray(r_hi, jnp.float32))
        B = ds_ids.shape[0]
        bucket = self.bucket_for(B)
        fn = self._executable(
            ("range_points", bucket),
            lambda: jax.jit(batched_ops.range_points_batched),
        )
        take, _ = fn(self.repo, self._pad_rows(ds_ids, bucket),
                     self._pad_rows(r_lo, bucket),
                     self._pad_rows(r_hi, bucket))
        self.stats.count("range_points", B, bucket)
        return take[:B]

    def nnp(self, ds_ids, q_batch: DatasetIndex):
        """Tree-pruned NNP for B (query, dataset id) requests ->
        (dists (B, nq), idx (B, nq))."""
        ds_ids = jnp.atleast_1d(jnp.asarray(ds_ids, jnp.int32))
        B = ds_ids.shape[0]
        bucket = self.bucket_for(B)
        fn = self._executable(
            ("nnp", bucket, q_batch.points.shape[1]),
            lambda: jax.jit(batched_ops.nnp_pruned_batched),
        )
        dists, idxs, _ = fn(self.repo, self._pad_rows(ds_ids, bucket),
                            self._pad_tree(q_batch, bucket))
        self.stats.count("nnp", B, bucket)
        return dists[:B], idxs[:B]
