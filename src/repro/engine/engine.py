"""QueryEngine: the batched multi-query execution engine.

The engine owns a resident :class:`Repository` and turns ragged streams of
incoming queries into fixed-shape device work:

  * **shape bucketing** — a batch of B queries is padded (by replicating the
    first row) up to the smallest configured bucket >= B, so the number of
    distinct compiled shapes is bounded by the bucket ladder, not by the
    traffic;
  * **executable cache** — one jitted executable per (op, bucket, k) key,
    built lazily on first use and reused for every later batch that lands
    in the same bucket (every dispatch records a hit or a miss, so
    `stats.cache_hits + stats.cache_misses == stats.dispatches`);
  * **single dispatch** — every op lowers to exactly one device computation
    per batch; no per-query Python loop, no per-chunk host sync.

Every dataset-granularity op — ExactHaus included — is a first-class
batched op: `topk_hausdorff` accepts a (B, ...) query-index batch and
answers it with ONE device dispatch (shared phase-2 work frontier, see
`core/search.py`), riding the same bucket ladder and executable cache as
the rest.

In front of the dispatch path sits a small **result cache** (LRU, keyed by
(op, k, query content digest)): repeated queries short-circuit BEFORE
bucketing, so only the rows that miss form the dispatched batch.  Hits and
misses are booked in `EngineStats.result_cache_hits` / `.result_cache_
misses` — distinct from the executable-cache counters, which keep counting
compiled-program reuse per dispatch.  ``result_cache_size=0`` disables the
cache entirely (the benchmarks do this so repeats measure dispatch, not
memoization).

Dispatch is **pluggable**: the engine delegates the construction of every
device callable to a dispatcher object.  :class:`LocalDispatcher` (the
default) closes each executable over the single-device repository and the
vmapped forms in :mod:`repro.engine.batched_ops`;
:class:`repro.engine.sharded.ShardedDispatcher` (selected by passing
``mesh=``) places the repository's dataset slots across a mesh axis and
merges per-shard results on device.  Bucketing, the executable cache, the
result cache, query construction, and :class:`EngineStats` are shared
between the two — sharded and unsharded engines differ ONLY in the
callables they cache.

Query point sets are themselves bucketed: `build_queries` pads a ragged
list of point sets to a power-of-two point capacity and builds all their
ball-tree indexes in one vmapped build.

The public entry point is the DECLARATIVE one: :meth:`QueryEngine.search`
takes a mixed ``list[Query | Pipeline]`` (see :mod:`repro.engine.query`),
compiles it into per-(op, statics, query-shape) dispatch groups
(:mod:`repro.engine.plan`), and returns one uniform :class:`SearchResult`
per input, in input order.  The per-op batch methods (``range_search``,
``topk_ia``, ...) are kept as DEPRECATED wrappers that construct Query
rows and delegate to ``search()`` — same results, same stats accounting,
one extra split/stack per batch.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core import join_search, point_search, search
from repro.core.build import pad_batch
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository
from repro.engine import batched_ops
from repro.engine import plan as plan_lib
from repro.kernels import autotune
from repro.engine.query import Pipeline, Query, SearchResult  # noqa: F401

Array = jax.Array

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)
DEFAULT_RESULT_CACHE = 256


def _digest(*parts) -> bytes:
    """Content digest of query-side payload arrays (result-cache key)."""
    h = hashlib.blake2b(digest_size=16)
    for p in parts:
        a = np.asarray(p)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.digest()


def _take_rows(x, sel):
    """Row subset for a miss sub-batch (sel is None = all rows)."""
    return x if sel is None else x[np.asarray(sel)]


def _take_tree_rows(tree, sel):
    if sel is None:
        return tree
    idx = np.asarray(sel)
    return jax.tree.map(lambda x: x[idx], tree)


def _split_tuple(raw):
    """Per-row entries of a tuple-of-arrays dispatch output — device-array
    slices, so splitting for the cache never syncs to the host."""
    n = raw[0].shape[0]
    return [tuple(a[i] for a in raw) for i in range(n)]


def _join_tuple(rows):
    return tuple(jnp.stack([r[c] for r in rows])
                 for c in range(len(rows[0])))


@dataclass
class EngineStats:
    """Cumulative engine observability counters.

    Every dispatch is recorded through :meth:`count`, which also books the
    executable-cache outcome — the invariant
    ``cache_hits + cache_misses == dispatches`` holds at all times and is
    asserted in tests.  ``per_op`` keeps the same breakdown per op name.

    The RESULT cache keeps its own counters (:meth:`count_result_cache`),
    distinct from the executable-cache ones: ``result_cache_hits`` counts
    query rows answered from memoized results (no dispatch at all), while
    ``cache_hits``/``cache_misses`` keep describing compiled-executable
    reuse for the dispatches that do run.  Under a live repository,
    entries cached at a RETIRED epoch are purged eagerly on every epoch
    install and counted in ``epoch_invalidations`` — a repeat of the same
    query after a mutation forms a fresh key and is booked as a result-
    cache MISS (then a dispatch), never a silent eviction, so the
    ``cache_hits + cache_misses == dispatches`` invariant is undisturbed
    by mutations.

    The PLANNER books its own counters on top (:meth:`count_group`):
    ``plan_groups`` / ``group_counts[op]`` count the dispatch groups a
    ``search()`` call compiled (one group = one batched dispatch path, op
    groups and pipeline stage-2 groups alike), and ``pipeline_stage1`` /
    ``pipeline_stage2`` count pipeline queries whose respective stage
    executed.  None of these touch the executable-cache invariant.
    """
    queries: int = 0                 # client queries ANSWERED (ops only)
    dispatches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    padded_queries: int = 0          # bucket padding overhead actually paid
    result_cache_hits: int = 0       # query rows served from the result LRU
    result_cache_misses: int = 0     # query rows that had to dispatch
    epoch_invalidations: int = 0     # result rows retired by a repo epoch
    mutations_coalesced: int = 0     # mutations that shared another's publish
    prepare_overlap_seconds: float = 0.0   # prepare time hidden under serving
    publish_seconds: list = field(default_factory=list)  # per-publish wall s
    plan_groups: int = 0             # dispatch groups compiled by search()
    replica_subgroups: int = 0       # replica row-blocks those groups spanned
    pipeline_stage1: int = 0         # pipelines whose dataset stage ran
    pipeline_stage2: int = 0         # pipelines whose point stage ran
    group_counts: dict = field(default_factory=dict)   # op -> groups
    per_op: dict = field(default_factory=dict)
    latency_ewma: dict = field(default_factory=dict)   # op -> EWMA seconds
    op_seconds: dict = field(default_factory=dict)     # op -> total seconds

    #: EWMA smoothing for per-op dispatch latency (seconds).  0.2 keeps
    #: roughly the last ~10 dispatches' worth of signal — stable enough
    #: for the adaptive server's straggler window, fresh enough to track
    #: a shift in traffic shape within a few batches.
    EWMA_ALPHA = 0.2

    def count(self, op: str, batch: int, bucket: int, *,
              cached: bool, internal: bool = False) -> None:
        """Record ONE dispatch.  ``internal=True`` (build_queries) books the
        dispatch and its cache outcome but keeps `queries`/`padded_queries`
        counting only answered client queries — a query that flows through
        build_queries AND an op must not be double-counted.  The per-op
        breakdown still records the batch under the internal op's name."""
        if not internal:
            self.queries += batch
            self.padded_queries += bucket - batch
        self.dispatches += 1
        if cached:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        per = self.per_op.setdefault(
            op, {"queries": 0, "dispatches": 0, "hits": 0, "misses": 0})
        per["queries"] += batch
        per["dispatches"] += 1
        per["hits" if cached else "misses"] += 1

    def count_result_cache(self, op: str, hits: int, misses: int) -> None:
        """Record one result-cache lookup pass over a query batch: `hits`
        rows were served from the LRU, `misses` rows went on to dispatch.
        Kept strictly separate from the executable-cache counters.

        Cache-hit rows ARE answered client queries, so they count toward
        ``queries``/``per_op[op]['queries']`` here; the miss rows are
        counted by :meth:`count` when their dispatch runs — each answered
        row is counted exactly once either way."""
        self.result_cache_hits += hits
        self.result_cache_misses += misses
        self.queries += hits
        per = self.per_op.setdefault(
            op, {"queries": 0, "dispatches": 0, "hits": 0, "misses": 0})
        per["queries"] += hits
        per["result_hits"] = per.get("result_hits", 0) + hits
        per["result_misses"] = per.get("result_misses", 0) + misses

    def record_publish(self, seconds: float, coalesced: int = 0) -> None:
        """Book one mutation PUBLISH (the batched slot write + upper-tree
        rebuild + atomic swap installing a group of prepared mutations):
        its wall time joins the publish latency distribution, and
        ``coalesced`` counts the mutations beyond the first that shared
        this publish (group size - 1; a lone mutation books 0)."""
        self.publish_seconds.append(seconds)
        self.mutations_coalesced += coalesced

    def publish_percentile_ms(self, p: float) -> float:
        """p-th percentile of per-publish wall time, in ms (0 if no
        publish has been recorded)."""
        if not self.publish_seconds:
            return 0.0
        import numpy as _np
        return 1e3 * float(_np.percentile(
            _np.asarray(self.publish_seconds), p))

    @property
    def publish_p50_ms(self) -> float:
        return self.publish_percentile_ms(50.0)

    @property
    def publish_p99_ms(self) -> float:
        return self.publish_percentile_ms(99.0)

    def record_latency(self, op: str, seconds: float) -> None:
        """Book one dispatch group's wall-clock latency: cumulative
        ``op_seconds[op]`` plus an EWMA (``latency_ewma[op]``) that the
        adaptive server reads to size its straggler window.  First sample
        seeds the EWMA directly."""
        self.op_seconds[op] = self.op_seconds.get(op, 0.0) + seconds
        prev = self.latency_ewma.get(op)
        self.latency_ewma[op] = (
            seconds if prev is None
            else prev + self.EWMA_ALPHA * (seconds - prev))

    def count_group(self, op: str, subgroups: int = 1) -> None:
        """Record ONE dispatch group compiled by the planner (an op group
        of a mixed batch, or a pipeline stage-2 group booked under its
        point op's name).  Kept in ``group_counts`` — NOT inside
        ``per_op`` — so the per-op hit/miss/dispatch breakdown stays
        exactly the executable-dispatch accounting.

        ``subgroups`` is the number of replica row-blocks the group's
        planned rows span (1 on local/1-D-sharded dispatch; up to R under
        a :class:`~repro.engine.replicated.ReplicatedDispatcher` — a
        planning-level metric, booked whether or not the rows later hit
        the result cache): ``plan_groups`` keeps counting compiled
        groups, while ``group_counts[op]`` and ``replica_subgroups``
        account for the sub-groups, so ``replica_subgroups >=
        plan_groups`` always."""
        self.plan_groups += 1
        self.replica_subgroups += subgroups
        self.group_counts[op] = self.group_counts.get(op, 0) + subgroups

    def _fold_stats(self, op: str, stats, fields: tuple) -> None:
        """Shared fold for one dispatch's per-query stats (a single stats
        value or a sequence from one batched dispatch): each named counter
        field accumulates as a sum across the batch, ``pruned_fraction``
        records the latest dispatch's mean across its queries."""
        batch = list(stats) if isinstance(stats, (list, tuple)) else [stats]
        if not batch:
            return
        per = self.per_op.setdefault(
            op, {"queries": 0, "dispatches": 0, "hits": 0, "misses": 0})
        for name in fields:
            per[name] = (per.get(name, 0)
                         + sum(getattr(s, name) for s in batch))
        per["pruned_fraction"] = (
            sum(s.pruned_fraction for s in batch) / len(batch))

    def record_point_search(self, op: str, stats) -> None:
        """Fold one point-granularity dispatch's per-query
        :class:`~repro.core.point_search.PointStats` into the per-op
        breakdown — the point-op sibling of :meth:`record_search`
        (RangeP books leaf-slab pruning, NNP the Eq. 4 pair-grid
        pruning)."""
        self._fold_stats(op, stats, ("nodes_evaluated", "leaves_scanned"))

    def record_search(self, op: str, stats) -> None:
        """Fold one dispatch's :class:`~repro.core.search.SearchStats` into
        the per-op breakdown.  ExactHaus books these on every dispatch
        (the engine never discards its SearchStats)."""
        self._fold_stats(op, stats, ("nodes_evaluated",
                                     "candidates_after_bounds",
                                     "exact_evaluations"))


class LocalDispatcher:
    """Single-device dispatch: one jitted executable per op over the
    resident repository.

    Each ``build_*`` returns a callable taking only the query-side
    operands; the repository rides along as a LATE-BOUND leading jit
    argument — the callable reads ``self.repo`` at call time (not a
    closed-over constant, so XLA never bakes the arrays in, and not a
    bind-time `partial`, so a live mutation that swaps ``self.repo`` for
    a same-shape successor takes effect on the very next dispatch with
    the SAME compiled executable).  The attribute swap is atomic, so a
    dispatch sees either the whole old repository or the whole new one —
    never a torn mix.

    ``repo_epoch`` is the LAYOUT epoch: bumped by a live repository only
    when the slot-array shapes change (capacity-tier growth), and folded
    into every executable-cache key, so executables whose build closed
    over the old slot count are retired rather than re-served.
    """

    name = "local"
    #: layout epoch — bumped on slot-shape changes (live tier growth);
    #: part of every executable-cache key like `autotune.epoch()`
    repo_epoch = 0

    def __init__(self, repo: Repository):
        self.repo = repo
        self.n_slots = repo.n_slots

    def _bind(self, impl):
        jitted = jax.jit(impl)

        def call(*args, **kw):
            return jitted(self.repo, *args, **kw)

        return call

    def build_range_search(self):
        return self._bind(batched_ops.range_search_batched)

    def build_topk_ia(self, k: int):
        return self._bind(partial(batched_ops.topk_ia_batched, k=k))

    def build_topk_gbo(self, k: int):
        return self._bind(partial(batched_ops.topk_gbo_batched, k=k))

    def build_topk_hausdorff_approx(self, k: int):
        return self._bind(
            partial(batched_ops.topk_hausdorff_approx_batched, k=k))

    def build_topk_hausdorff(self, k: int, refine_levels: int, chunk: int):
        # batched end-to-end: (B, ...) query batch -> one device dispatch
        # (search._topk_hausdorff_device_batched is already jitted); late
        # repo binding like every other op
        def call(q_batch):
            return batched_ops.topk_hausdorff_batched(
                self.repo, q_batch, k=k, refine_levels=refine_levels,
                chunk=chunk)

        return call

    def build_range_points(self):
        return self._bind(batched_ops.range_points_batched)

    def build_nnp(self):
        return self._bind(batched_ops.nnp_pruned_batched)

    def build_topk_overlap(self, k: int, chunk: int):
        return self._bind(partial(batched_ops.topk_join_batched, k=k,
                                  mode="overlap", chunk=chunk))

    def build_topk_coverage(self, k: int, chunk: int):
        return self._bind(partial(batched_ops.topk_join_batched, k=k,
                                  mode="coverage", chunk=chunk))

    def build_join_rerank(self, mode: str):
        # dataset→dataset pipeline stage 2: row-wise exact join score of
        # stage-1 winner slots (gathered by id on device) vs the query row
        def impl(repo, ds_ids, q_pts, q_val):
            d_pts = repo.ds_index.points[ds_ids]
            d_val = repo.ds_index.valid[ds_ids]
            return join_search.pair_scores(repo, d_pts, d_val,
                                           q_pts, q_val, mode)

        return self._bind(impl)


class QueryEngine:
    """Batched search over a resident repository (see module docstring).

    Passing ``mesh=`` (a `jax.sharding.Mesh`) selects the sharded dispatch
    path: dataset slots are placed across ``shard_spec`` (a mesh axis name,
    default ``"data"``) and per-shard results are merged on device —
    bit-identical to the local path (asserted in
    tests/test_engine_sharded.py).  A mesh that also carries a
    ``replica_spec`` axis (default ``"replica"``; build one with
    :func:`~repro.engine.replicated.replica_mesh`) selects the
    REPLICA-PARALLEL dispatcher instead: the slot shards replicate across
    replica groups and each group serves its own slice of every batch's
    rows — still bit-identical (tests/test_engine_replicated.py).
    """

    def __init__(
        self,
        repo: Repository,
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        leaf_capacity: int = 16,
        mesh=None,
        shard_spec: str = "data",
        replica_spec: str = "replica",
        dispatcher=None,
        result_cache_size: int = DEFAULT_RESULT_CACHE,
        default_chunk: int = 32,
    ):
        self.buckets = tuple(sorted(buckets))
        self.leaf_capacity = leaf_capacity
        self.default_chunk = default_chunk
        self.stats = EngineStats()
        self._executables: dict = {}
        self.result_cache_size = result_cache_size
        self._result_cache: OrderedDict = OrderedDict()
        self._n_valid = int(repo.ds_valid.sum())
        # live-repository versioning: the DATA epoch (bumped on every
        # mutation; part of every dataset-op result-cache key) and the
        # per-slot epochs (point-op keys carry their target slot's epoch,
        # so mutations of OTHER datasets never invalidate them)
        self._repo_epoch = 0
        self._slot_epochs = None
        if dispatcher is None:
            if mesh is not None:
                # a mesh carrying a replica axis selects replica-parallel
                # dispatch (query rows split across replica groups);
                # otherwise the 1-D data-sharded path
                if replica_spec in getattr(mesh, "axis_names", ()):
                    from repro.engine.replicated import ReplicatedDispatcher
                    dispatcher = ReplicatedDispatcher(
                        repo, mesh, axis=shard_spec,
                        replica_axis=replica_spec)
                else:
                    from repro.engine.sharded import ShardedDispatcher
                    dispatcher = ShardedDispatcher(repo, mesh,
                                                   axis=shard_spec)
            else:
                dispatcher = LocalDispatcher(repo)
        self.dispatch = dispatcher
        # hold the dispatcher's PLACED repository (the sharded copy under a
        # ShardedDispatcher) rather than the builder's, so the engine never
        # pins an extra replicated copy once the caller drops theirs
        self.repo = getattr(dispatcher, "repo", repo)

    # -- autotuning --------------------------------------------------------

    def tune(self, **kw):
        """One-time measured sweep of the kernel dispatch constants for
        THIS engine's repository shapes (see :mod:`repro.engine.tune`).
        Installs per-(backend, shape-bucket) routing verdicts in the
        process-global autotune table — gated on bitwise identity with the
        ref path, so tuned routing never shifts a result — and picks the
        fastest ExactHaus refinement ``chunk`` as ``self.default_chunk``.
        Returns the tuner's report dict."""
        from repro.engine.tune import tune_engine
        return tune_engine(self, **kw)

    # -- bucketing ---------------------------------------------------------

    def bucket_for(self, batch: int) -> int:
        for b in self.buckets:
            if b >= batch:
                return b
        b = self.buckets[-1]
        while b < batch:          # beyond the ladder: grow geometrically
            b *= 2
        return b

    def _plan_subgroups(self, batch: int) -> int:
        """Replica row-blocks a `batch`-row dispatch group spans under
        this engine's dispatcher (1 unless the dispatcher splits rows
        across replica groups) — the planner feeds this to
        :meth:`EngineStats.count_group`."""
        f = getattr(self.dispatch, "row_subgroups", None)
        return 1 if f is None else f(batch, self.bucket_for(batch))

    @staticmethod
    def _pad_rows(x: Array, bucket: int) -> Array:
        """Pad a (B, ...) array to (bucket, ...) by replicating row 0 —
        padding rows recompute a real query, so no masking is needed and
        results for them are simply sliced off."""
        b = x.shape[0]
        if b == bucket:
            return x
        reps = jnp.broadcast_to(x[:1], (bucket - b,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)

    def _pad_tree(self, tree, bucket: int):
        return jax.tree.map(lambda x: self._pad_rows(x, bucket), tree)

    # -- executable cache --------------------------------------------------

    def _executable(self, key, build):
        """Cache lookup; returns (fn, cached) so the dispatch path can book
        the hit/miss through `stats.count` uniformly for every op.

        The autotune table epoch is part of every key: executables close
        over routing decisions made at build time (kernel vs ref, tile
        sizes), so a `tune()` that installs new configs must NOT keep
        serving stale compilations — the epoch bump retires them.  The
        dispatcher's LAYOUT epoch rides along for the same reason: builds
        close over slot-count constants, so a live capacity-tier growth
        must retire them too (data-only mutations leave both epochs alone
        and keep every executable)."""
        key = (autotune.epoch(),
               getattr(self.dispatch, "repo_epoch", 0)) + tuple(key)
        fn = self._executables.get(key)
        cached = fn is not None
        if not cached:
            fn = build()
            self._executables[key] = fn
        return fn, cached

    # -- repository epochs (live mutations) -------------------------------

    @property
    def repo_epoch(self) -> int:
        """The DATA epoch of the resident repository (0 forever on a
        frozen engine; bumped by :class:`~repro.engine.live.LiveRepository`
        on every published mutation)."""
        return self._repo_epoch

    def slot_epoch(self, ds_id) -> int:
        """Per-slot mutation epoch of dataset ``ds_id`` (0 on a frozen
        engine) — the component point-op result keys carry, so caches for
        UNTOUCHED datasets survive mutations elsewhere."""
        se = self._slot_epochs
        return 0 if se is None else int(se[int(ds_id)])

    def set_repo_epoch(self, epoch: int, slot_epochs=None,
                       touched=None) -> None:
        """Install a new repository epoch after a live mutation.

        ``epoch`` must be monotonically increasing; ``slot_epochs`` (an
        int array indexed by slot) replaces the per-slot epoch table.
        Result-cache entries keyed at retired epochs are purged EAGERLY
        and booked in ``stats.epoch_invalidations`` — they are retired
        versions, not capacity evictions, and the counter makes the
        distinction observable.  Executables are NOT touched: data
        mutations reuse every compiled program (the layout epoch on the
        dispatcher handles shape changes separately).

        ``touched`` (optional) is the exact set of slots this publish
        wrote: invalidation is then PRECISE for point-granularity rows —
        only entries keyed on a touched slot are even inspected, so
        entries for untouched slots survive a publish without a per-key
        epoch probe (a coalesced N-mutation publish makes ONE such sweep,
        not N).  Dataset-granularity rows always retire on a data-epoch
        move: any slot write can change a whole-repository answer."""
        if epoch < self._repo_epoch:
            raise ValueError(
                f"repository epoch must be monotone: {epoch} < "
                f"{self._repo_epoch}")
        self._repo_epoch = int(epoch)
        if slot_epochs is not None:
            self._slot_epochs = slot_epochs
        stale = []
        for key in list(self._result_cache):
            if key[0] in ("range_points", "nnp"):
                # (op, ds_id, slot_epoch, ...)
                if touched is not None and key[1] not in touched:
                    continue               # precise retention: untouched
                if key[2] != self.slot_epoch(key[1]):
                    stale.append(key)
            elif key[1] != self._repo_epoch:
                # (op, repo_epoch, ...)
                stale.append(key)
        for key in stale:
            self._result_cache.pop(key, None)
        self.stats.epoch_invalidations += len(stale)

    # -- result cache ------------------------------------------------------

    def _cache_insert(self, keys, rows) -> None:
        for key, row in zip(keys, rows):
            self._result_cache[key] = row           # inserts at MRU end
        while len(self._result_cache) > self.result_cache_size:
            self._result_cache.popitem(last=False)

    def _serve_cached(self, op: str, keys, dispatch, split, join):
        """Serve per-query result rows through the result cache (LRU).

        ``keys`` holds one hashable content key per query row;
        ``dispatch(sel)`` runs the op for row positions ``sel`` (or ALL
        rows when ``sel is None``) as one batch; ``split(raw)`` slices a
        dispatch output into per-row entries (device-array slices — lazy,
        no host sync); ``join(rows)`` reassembles rows into the op's
        output shape.

        Repeated queries short-circuit BEFORE bucketing: only DISTINCT
        miss rows form the dispatched sub-batch (duplicate rows inside one
        batch ride their twin's dispatch and are booked as cache hits, so
        ``result_cache_misses`` counts exactly the rows that went through
        a dispatch).  The common cold case — every row a distinct miss —
        returns the dispatch output UNCHANGED, so a no-repeat workload
        pays only the key digests."""
        out_rows = [None] * len(keys)
        miss: list = []
        hits = 0
        for i, key in enumerate(keys):
            row = self._result_cache.get(key)
            if row is None:
                miss.append(i)
            else:
                self._result_cache.move_to_end(key)
                out_rows[i] = row
                hits += 1
        uniq_pos: dict = {}            # key -> row index in the sub-batch
        uniq: list = []
        for i in miss:
            if keys[i] not in uniq_pos:
                uniq_pos[keys[i]] = len(uniq)
                uniq.append(i)
        self.stats.count_result_cache(
            op, hits + (len(miss) - len(uniq)), len(uniq))
        if not hits and len(uniq) == len(keys):    # all-distinct cold batch
            raw = dispatch(None)
            self._cache_insert(keys, split(raw))
            return raw
        if uniq:
            rows = split(dispatch(uniq))
            self._cache_insert([keys[i] for i in uniq], rows)
            for i in miss:
                out_rows[i] = rows[uniq_pos[keys[i]]]
        return join(out_rows)

    # -- query construction ------------------------------------------------

    def build_queries(
        self, pointsets: Sequence[np.ndarray]
    ) -> DatasetIndex:
        """Index a ragged list of query point sets as one (B, ...) batch.

        Point counts are bucketed to the next power of two (so repeated
        traffic reuses executables) and the B tree builds run as one
        vmapped dispatch.  Queries are replicated (never sharded): both
        dispatch paths consume the same batched query index.
        """
        n_max = max(int(p.shape[0]) for p in pointsets)
        n_bucket = self.leaf_capacity
        while n_bucket < n_max:
            n_bucket *= 2
        depth = index_lib.depth_for(n_bucket, self.leaf_capacity)
        pts, val, depth = pad_batch(pointsets, self.leaf_capacity, depth)
        bucket = self.bucket_for(len(pointsets))
        pts = self._pad_rows(pts, bucket)
        val = self._pad_rows(val, bucket)
        build, cached = self._executable(
            ("build", bucket, pts.shape[1], depth),
            lambda: jax.jit(partial(index_lib.build_index_batch,
                                    depth=depth)),
        )
        q_batch = build(pts, val)
        self.stats.count("build_queries", len(pointsets), bucket,
                         cached=cached, internal=True)
        return jax.tree.map(lambda x: x[: len(pointsets)], q_batch)

    # -- declarative entry point ------------------------------------------

    def search(self, queries: Sequence) -> list:
        """THE unified entry point: answer a mixed declarative batch.

        ``queries`` is a list of :class:`~repro.engine.query.Query` and/or
        :class:`~repro.engine.query.Pipeline` values covering any mix of
        the seven ops.  The planner (:mod:`repro.engine.plan`) compiles
        the batch into per-(op, statics, query-shape) dispatch groups —
        each group one batched dispatch over the bucket ladder, executable
        cache, and result cache (cache hits short-circuit per row) — runs
        pipeline dataset stages inside those groups, then feeds the
        winning dataset ids to the point stages with the id handoff
        staying on device.  Returns one
        :class:`~repro.engine.query.SearchResult` per input, in INPUT
        order.
        """
        return plan_lib.execute(self, queries)

    # -- per-op group executors (one batched dispatch path each) ----------

    def _exec_range_search(self, r_lo, r_hi):
        """RangeS for B query boxes -> dataset masks (B, B_pad)."""
        r_lo = jnp.atleast_2d(jnp.asarray(r_lo, jnp.float32))
        r_hi = jnp.atleast_2d(jnp.asarray(r_hi, jnp.float32))
        if not self.result_cache_size:
            return self._range_search_dispatch(r_lo, r_hi)
        lo_np, hi_np = np.asarray(r_lo), np.asarray(r_hi)
        keys = [("range_search", self._repo_epoch,
                 _digest(lo_np[i], hi_np[i]))
                for i in range(lo_np.shape[0])]
        return self._serve_cached(
            "range_search", keys,
            lambda sel: self._range_search_dispatch(
                _take_rows(r_lo, sel), _take_rows(r_hi, sel)),
            split=lambda masks: [masks[i] for i in range(masks.shape[0])],
            join=jnp.stack)

    def _range_search_dispatch(self, r_lo, r_hi):
        B = r_lo.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("range_search", bucket), self.dispatch.build_range_search)
        masks, _ = fn(self._pad_rows(r_lo, bucket),
                      self._pad_rows(r_hi, bucket))
        self.stats.count("range_search", B, bucket, cached=cached)
        return masks[:B]

    def _exec_topk_ia(self, q_lo, q_hi, k: int):
        """Top-k IA for B query boxes -> (vals, ids) each (B, k)."""
        q_lo = jnp.atleast_2d(jnp.asarray(q_lo, jnp.float32))
        q_hi = jnp.atleast_2d(jnp.asarray(q_hi, jnp.float32))
        if not self.result_cache_size:
            return self._topk_ia_dispatch(q_lo, q_hi, k)
        lo_np, hi_np = np.asarray(q_lo), np.asarray(q_hi)
        keys = [("topk_ia", self._repo_epoch, k, _digest(lo_np[i], hi_np[i]))
                for i in range(lo_np.shape[0])]
        return self._serve_cached(
            "topk_ia", keys,
            lambda sel: self._topk_ia_dispatch(
                _take_rows(q_lo, sel), _take_rows(q_hi, sel), k),
            split=_split_tuple, join=_join_tuple)

    def _topk_ia_dispatch(self, q_lo, q_hi, k: int):
        B = q_lo.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("topk_ia", bucket, k),
            lambda: self.dispatch.build_topk_ia(k))
        vals, ids = fn(self._pad_rows(q_lo, bucket),
                       self._pad_rows(q_hi, bucket))
        self.stats.count("topk_ia", B, bucket, cached=cached)
        return vals[:B], ids[:B]

    def _exec_topk_gbo(self, q_sigs, k: int):
        """Top-k GBO for B query signatures -> (vals, ids) each (B, k)."""
        q_sigs = jnp.asarray(q_sigs)
        if q_sigs.ndim == 1:
            q_sigs = q_sigs[None, :]
        if not self.result_cache_size:
            return self._topk_gbo_dispatch(q_sigs, k)
        sigs_np = np.asarray(q_sigs)
        keys = [("topk_gbo", self._repo_epoch, k, _digest(sigs_np[i]))
                for i in range(sigs_np.shape[0])]
        return self._serve_cached(
            "topk_gbo", keys,
            lambda sel: self._topk_gbo_dispatch(_take_rows(q_sigs, sel), k),
            split=_split_tuple, join=_join_tuple)

    def _topk_gbo_dispatch(self, q_sigs, k: int):
        B = q_sigs.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("topk_gbo", bucket, k),
            lambda: self.dispatch.build_topk_gbo(k))
        vals, ids = fn(self._pad_rows(q_sigs, bucket))
        self.stats.count("topk_gbo", B, bucket, cached=cached)
        return vals[:B], ids[:B]

    def _exec_topk_join(self, op: str, q_pts, q_val, k: int):
        """Joinable top-k (``topk_overlap`` / ``topk_coverage``) for B raw
        query point sets -> (vals (B, k), ids (B, k), list[SearchStats]).

        Scores are exact integers, so cached rows replay bit-identically;
        keys carry the repository epoch (the bound phase reads resident
        coarse signatures and the refine reads resident points, so ANY
        published mutation may change a row) — `set_repo_epoch` retires
        them wholesale like every dataset-granularity op."""
        q_pts = jnp.asarray(q_pts, jnp.float32)
        q_val = jnp.asarray(q_val, bool)
        if not self.result_cache_size:
            return self._topk_join_dispatch(op, q_pts, q_val, k)
        pts_np, val_np = np.asarray(q_pts), np.asarray(q_val)
        keys = [(op, self._repo_epoch, k, _digest(pts_np[i], val_np[i]))
                for i in range(pts_np.shape[0])]
        return self._serve_cached(
            op, keys,
            lambda sel: self._topk_join_dispatch(
                op, _take_rows(q_pts, sel), _take_rows(q_val, sel), k),
            split=lambda raw: [(raw[0][i], raw[1][i], raw[2][i])
                               for i in range(len(raw[2]))],
            join=lambda rows: (jnp.stack([r[0] for r in rows]),
                               jnp.stack([r[1] for r in rows]),
                               [r[2] for r in rows]))

    def _topk_join_dispatch(self, op: str, q_pts, q_val, k: int):
        B = q_pts.shape[0]
        bucket = self.bucket_for(B)
        chunk = self.default_chunk
        key = (op, bucket, q_pts.shape[1], k, chunk)
        fn, cached = self._executable(
            key, lambda: getattr(self.dispatch, "build_" + op)(k, chunk))
        vals, ids, nodes, cand_after, evaluated = fn(
            self._pad_rows(q_pts, bucket), self._pad_rows(q_val, bucket))
        self.stats.count(op, B, bucket, cached=cached)
        stats = join_search.join_stats_host(
            self._n_valid, evaluated[:B], nodes[:B], cand_after[:B])
        self.stats.record_search(op, stats)
        return vals[:B], ids[:B], stats

    def _exec_join_rerank(self, op: str, ds_ids, q_pts, q_val):
        """Stage-2 dataset→dataset scoring: row-wise exact join score of
        winner slot `ds_ids[t]` vs query row t -> (T,) int32 on device.

        Like the point-stage executors, the device-resident id handoff
        path skips the result cache (host keys would force a mid-pipeline
        sync); the executable rides the bucket ladder as usual."""
        mode = "overlap" if op == "topk_overlap" else "coverage"
        T = ds_ids.shape[0]
        bucket = self.bucket_for(T)
        key = ("join_rerank", mode, bucket, q_pts.shape[1])
        fn, cached = self._executable(
            key, lambda: self.dispatch.build_join_rerank(mode))
        scores = fn(self._pad_rows(jnp.asarray(ds_ids, jnp.int32), bucket),
                    self._pad_rows(jnp.asarray(q_pts, jnp.float32), bucket),
                    self._pad_rows(jnp.asarray(q_val, bool), bucket))
        # stage-2 rows count like the point-stage dispatches do (one row
        # per stage-1 winner), keeping hits+misses == dispatches intact
        self.stats.count(op, T, bucket, cached=cached)
        return scores[:T]

    def _exec_topk_hausdorff_approx(self, q_batch: DatasetIndex, k: int,
                                    eps):
        """ApproHaus for a (B, ...) query-index batch -> (vals, ids,
        eps_eff)."""
        if not self.result_cache_size:
            return self._topk_hausdorff_approx_dispatch(q_batch, k, eps)
        pts, val = np.asarray(q_batch.points), np.asarray(q_batch.valid)
        # depth is part of the key: (points, valid, depth) fully determine
        # a DatasetIndex built by this codebase (node stats are derived
        # from them), so same points under a different tree never collide
        keys = [("approx_haus", self._repo_epoch, k, float(eps),
                 q_batch.depth,
                 _digest(pts[i], val[i])) for i in range(pts.shape[0])]
        return self._serve_cached(
            "topk_hausdorff_approx", keys,
            lambda sel: self._topk_hausdorff_approx_dispatch(
                _take_tree_rows(q_batch, sel), k, eps),
            split=_split_tuple, join=_join_tuple)

    def _topk_hausdorff_approx_dispatch(self, q_batch, k: int, eps):
        B = q_batch.points.shape[0]
        bucket = self.bucket_for(B)
        key = ("approx_haus", bucket, q_batch.points.shape[1], k)
        fn, cached = self._executable(
            key, lambda: self.dispatch.build_topk_hausdorff_approx(k))
        padded = self._pad_tree(q_batch, bucket)
        vals, ids, eps_eff = fn(padded, eps=jnp.float32(eps))
        self.stats.count("topk_hausdorff_approx", B, bucket, cached=cached)
        return vals[:B], ids[:B], eps_eff[:B]

    def _exec_topk_hausdorff(self, q_batch: DatasetIndex, k: int,
                             refine_levels: int = 3,
                             chunk: int | None = None):
        """ExactHaus for a (B, ...) query-index batch: ONE device dispatch
        (shared phase-2 work frontier; per-shard loops + batched tau
        all-reduce under a ShardedDispatcher) -> (vals (B, k), ids (B, k),
        list[SearchStats]).

        ``chunk=None`` (the default) resolves to the engine's tuned
        ``default_chunk`` BEFORE any cache key is formed — chunk only
        chunks the refinement sweep (vals/ids are bit-identical under any
        chunk; the `evaluated` counter granularity changes), so retuning
        it between calls is always safe."""
        if chunk is None:
            chunk = self.default_chunk
        if not self.result_cache_size:
            return self._topk_hausdorff_dispatch(
                q_batch, k, refine_levels, chunk)
        pts, val = np.asarray(q_batch.points), np.asarray(q_batch.valid)
        # depth in the key for the same reason as ApproHaus (a
        # different tree over the same points changes the SearchStats)
        keys = [("exact_haus", self._repo_epoch, k, refine_levels, chunk,
                 q_batch.depth,
                 _digest(pts[i], val[i])) for i in range(pts.shape[0])]
        return self._serve_cached(
            "topk_hausdorff", keys,
            lambda sel: self._topk_hausdorff_dispatch(
                _take_tree_rows(q_batch, sel), k, refine_levels, chunk),
            split=lambda raw: [(raw[0][i], raw[1][i], raw[2][i])
                               for i in range(len(raw[2]))],
            join=lambda rows: (jnp.stack([r[0] for r in rows]),
                               jnp.stack([r[1] for r in rows]),
                               [r[2] for r in rows]))

    def _topk_hausdorff_dispatch(self, q_batch, k: int, refine_levels: int,
                                 chunk: int):
        """One batched ExactHaus device dispatch + per-query SearchStats."""
        B = q_batch.points.shape[0]
        bucket = self.bucket_for(B)
        key = ("exact_haus", bucket, q_batch.points.shape[1], k,
               refine_levels, chunk)
        fn, cached = self._executable(
            key, lambda: self.dispatch.build_topk_hausdorff(k, refine_levels,
                                                            chunk))
        padded = self._pad_tree(q_batch, bucket)
        vals, ids, nodes, cand_after, evaluated = fn(padded)
        self.stats.count("topk_hausdorff", B, bucket, cached=cached)
        nodes = np.asarray(nodes)
        cand_after = np.asarray(cand_after)
        evaluated = np.asarray(evaluated)
        stats = [
            search.SearchStats(
                int(nodes[i]), int(cand_after[i]), int(evaluated[i]),
                1.0 - int(evaluated[i]) / max(self._n_valid, 1),
            )
            for i in range(B)
        ]
        self.stats.record_search("topk_hausdorff", stats)
        return vals[:B], ids[:B], stats

    def _exec_range_points(self, ds_ids, r_lo, r_hi):
        """RangeP for B (dataset id, box) requests -> (take masks
        (B, n_pad), list[PointStats]).

        Point ops ride the result cache too, but ONLY when ``ds_ids``
        arrives host-resident (the planner's op-group path and the legacy
        shims): pipeline stage 2 hands winning ids over ON DEVICE, and
        forming host cache keys there would force a sync in the middle of
        the pipeline — so that path dispatches directly.  Keys carry the
        target slot's mutation epoch, so a live mutation of dataset j
        retires exactly the entries that touched j.  Cached rows keep
        their PointStats; :meth:`EngineStats.record_point_search` books
        only the rows that actually dispatched."""
        if self.result_cache_size and not isinstance(ds_ids, jax.Array):
            ids_np = np.atleast_1d(np.asarray(ds_ids, np.int32))
            lo_np = np.atleast_2d(np.asarray(r_lo, np.float32))
            hi_np = np.atleast_2d(np.asarray(r_hi, np.float32))
            keys = [("range_points", int(ids_np[i]),
                     self.slot_epoch(ids_np[i]),
                     _digest(lo_np[i], hi_np[i]))
                    for i in range(ids_np.shape[0])]
            return self._serve_cached(
                "range_points", keys,
                lambda sel: self._range_points_dispatch(
                    _take_rows(ids_np, sel), _take_rows(lo_np, sel),
                    _take_rows(hi_np, sel)),
                split=lambda raw: [(raw[0][i], raw[1][i])
                                   for i in range(len(raw[1]))],
                join=lambda rows: (jnp.stack([r[0] for r in rows]),
                                   [r[1] for r in rows]))
        return self._range_points_dispatch(ds_ids, r_lo, r_hi)

    def _range_points_dispatch(self, ds_ids, r_lo, r_hi):
        """One batched RangeP dispatch; the traversal's scanned-leaf mask
        is no longer discarded: per-query leaf pruning stats are computed
        from it (device-side sums, one tiny transfer) and folded into
        ``EngineStats`` via :meth:`EngineStats.record_point_search`."""
        ds_ids = jnp.atleast_1d(jnp.asarray(ds_ids, jnp.int32))
        r_lo = jnp.atleast_2d(jnp.asarray(r_lo, jnp.float32))
        r_hi = jnp.atleast_2d(jnp.asarray(r_hi, jnp.float32))
        B = ds_ids.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("range_points", bucket), self.dispatch.build_range_points)
        take, scanned = fn(self._pad_rows(ds_ids, bucket),
                           self._pad_rows(r_lo, bucket),
                           self._pad_rows(r_hi, bucket))
        self.stats.count("range_points", B, bucket, cached=cached)
        n_leaves = int(scanned.shape[1])
        sc = np.asarray(jnp.sum(scanned[:B], axis=1))
        stats = [
            point_search.PointStats(
                n_leaves, int(sc[i]),
                float(1.0 - int(sc[i]) / max(n_leaves, 1)))
            for i in range(B)
        ]
        self.stats.record_point_search("range_points", stats)
        return take[:B], stats

    def _exec_nnp(self, ds_ids, q_batch: DatasetIndex):
        """Tree-pruned NNP for B (query, dataset id) requests ->
        (dists (B, nq), idx (B, nq), list[PointStats]).

        Same host-gated result caching as RangeP (see
        :meth:`_exec_range_points`): cacheable only when the ids arrive
        host-resident; the on-device stage-2 handoff dispatches
        directly."""
        if self.result_cache_size and not isinstance(ds_ids, jax.Array):
            ids_np = np.atleast_1d(np.asarray(ds_ids, np.int32))
            pts = np.asarray(q_batch.points)
            val = np.asarray(q_batch.valid)
            keys = [("nnp", int(ids_np[i]), self.slot_epoch(ids_np[i]),
                     q_batch.depth, _digest(pts[i], val[i]))
                    for i in range(ids_np.shape[0])]
            return self._serve_cached(
                "nnp", keys,
                lambda sel: self._nnp_dispatch(
                    _take_rows(ids_np, sel), _take_tree_rows(q_batch, sel)),
                split=lambda raw: [(raw[0][i], raw[1][i], raw[2][i])
                                   for i in range(len(raw[2]))],
                join=lambda rows: (jnp.stack([r[0] for r in rows]),
                                   jnp.stack([r[1] for r in rows]),
                                   [r[2] for r in rows]))
        return self._nnp_dispatch(ds_ids, q_batch)

    def _nnp_dispatch(self, ds_ids, q_batch: DatasetIndex):
        """One batched NNP dispatch through
        `core/point_search.nnp_pruned_core` (the Eq. 4 pair-grid prune)
        on BOTH dispatchers; the surviving ``pair_live`` mask is surfaced
        as per-query PointStats — the same counters the host `nnp_pruned`
        reports — instead of being thrown away."""
        ds_ids = jnp.atleast_1d(jnp.asarray(ds_ids, jnp.int32))
        B = ds_ids.shape[0]
        bucket = self.bucket_for(B)
        fn, cached = self._executable(
            ("nnp", bucket, q_batch.points.shape[1]),
            self.dispatch.build_nnp)
        dists, idxs, pair_live = fn(self._pad_rows(ds_ids, bucket),
                                    self._pad_tree(q_batch, bucket))
        self.stats.count("nnp", B, bucket, cached=cached)
        pairs = int(pair_live.shape[1] * pair_live.shape[2])
        live = np.asarray(jnp.sum(pair_live[:B], axis=(1, 2)))
        stats = [
            point_search.PointStats(
                pairs, int(live[i]),
                float(1.0 - int(live[i]) / max(pairs, 1)))
            for i in range(B)
        ]
        self.stats.record_point_search("nnp", stats)
        return dists[:B], idxs[:B], stats

    # -- legacy per-op batch methods (deprecated shims over search()) -----

    @staticmethod
    def _host_tree_rows(tree):
        """Split a (B, ...) index batch into host-array rows (ONE device
        sync for the whole tree, then free np views) for Query
        construction in the legacy shims."""
        np_tree = jax.tree.map(np.asarray, tree)
        B = np_tree.points.shape[0]
        return [jax.tree.map(lambda x, i=i: x[i], np_tree)
                for i in range(B)]

    def range_search(self, r_lo, r_hi):
        """DEPRECATED shim (use `search`): RangeS for B query boxes ->
        dataset masks (B, B_pad)."""
        lo = np.atleast_2d(np.asarray(r_lo, np.float32))
        hi = np.atleast_2d(np.asarray(r_hi, np.float32))
        res = self.search([Query(op="range_search", r_lo=lo[i], r_hi=hi[i])
                           for i in range(lo.shape[0])])
        return jnp.asarray(np.stack([r.mask for r in res]))

    def topk_ia(self, q_lo, q_hi, k: int):
        """DEPRECATED shim (use `search`): top-k IA for B query boxes ->
        (vals, ids) each (B, k)."""
        lo = np.atleast_2d(np.asarray(q_lo, np.float32))
        hi = np.atleast_2d(np.asarray(q_hi, np.float32))
        res = self.search([Query(op="topk_ia", r_lo=lo[i], r_hi=hi[i], k=k)
                           for i in range(lo.shape[0])])
        return (jnp.asarray(np.stack([r.vals for r in res])),
                jnp.asarray(np.stack([r.ids for r in res])))

    def topk_gbo(self, q_sigs, k: int):
        """DEPRECATED shim (use `search`): top-k GBO for B query
        signatures -> (vals, ids) each (B, k)."""
        sigs = np.asarray(q_sigs)
        if sigs.ndim == 1:
            sigs = sigs[None, :]
        res = self.search([Query(op="topk_gbo", q_sig=sigs[i], k=k)
                           for i in range(sigs.shape[0])])
        return (jnp.asarray(np.stack([r.vals for r in res])),
                jnp.asarray(np.stack([r.ids for r in res])))

    def topk_overlap(self, pointsets, k: int):
        """Convenience shim (use `search`): joinable top-k by grid-cell
        overlap for B raw query point sets -> (vals (B, k), ids (B, k),
        list[SearchStats])."""
        return self._join_shim("topk_overlap", pointsets, k)

    def topk_coverage(self, pointsets, k: int):
        """Convenience shim (use `search`): joinable top-k by grid-cell
        coverage (query points inside cells the winner occupies) ->
        (vals (B, k), ids (B, k), list[SearchStats])."""
        return self._join_shim("topk_coverage", pointsets, k)

    def _join_shim(self, op: str, pointsets, k: int):
        res = self.search([Query(op=op, q=np.asarray(ps, np.float32), k=k)
                           for ps in pointsets])
        return (jnp.asarray(np.stack([r.vals for r in res])),
                jnp.asarray(np.stack([r.ids for r in res])),
                [r.stats for r in res])

    def topk_hausdorff_approx(self, q_batch: DatasetIndex, k: int, eps):
        """DEPRECATED shim (use `search`): ApproHaus for a (B, ...)
        query-index batch -> (vals, ids, eps_eff)."""
        res = self.search([
            Query(op="topk_hausdorff_approx", q_index=row, k=k, eps=eps)
            for row in self._host_tree_rows(q_batch)])
        return (jnp.asarray(np.stack([r.vals for r in res])),
                jnp.asarray(np.stack([r.ids for r in res])),
                jnp.asarray(np.stack([r.extras["eps_eff"] for r in res])))

    def topk_hausdorff(self, q_batch: DatasetIndex, k: int, *,
                       refine_levels: int = 3, chunk: int = 32):
        """DEPRECATED shim (use `search`): ExactHaus — the device-resident
        branch-and-bound pipeline for a (B, ...) query-index batch OR a
        single query index.

        A batch costs ONE device dispatch (shared phase-2 work frontier;
        per-shard loops + batched tau all-reduce under a
        ShardedDispatcher), bucketed through the same shape ladder as
        every other op.  Per-query (vals, ids) are bit-identical to the
        solo pipeline and `topk_hausdorff_host`.

        Returns (vals (B, k), ids (B, k), list[SearchStats]) for a batch,
        or (vals (k,), ids (k,), SearchStats) for a single query; the
        stats are also folded into ``self.stats`` (summed counters, mean
        pruned fraction per dispatch).
        """
        single = q_batch.points.ndim == 2
        if single:
            q_batch = jax.tree.map(lambda x: x[None], q_batch)
        res = self.search([
            Query(op="topk_hausdorff", q_index=row, k=k,
                  refine_levels=refine_levels, chunk=chunk)
            for row in self._host_tree_rows(q_batch)])
        vals = jnp.asarray(np.stack([r.vals for r in res]))
        ids = jnp.asarray(np.stack([r.ids for r in res]))
        stats = [r.stats for r in res]
        if single:
            return vals[0], ids[0], stats[0]
        return vals, ids, stats

    def range_points(self, ds_ids, r_lo, r_hi):
        """DEPRECATED shim (use `search`): RangeP for B (dataset id, box)
        requests -> take masks (B, n_pad)."""
        ds = np.atleast_1d(np.asarray(ds_ids, np.int32))
        lo = np.atleast_2d(np.asarray(r_lo, np.float32))
        hi = np.atleast_2d(np.asarray(r_hi, np.float32))
        res = self.search([
            Query(op="range_points", ds_id=int(ds[i]), r_lo=lo[i],
                  r_hi=hi[i])
            for i in range(ds.shape[0])])
        return jnp.asarray(np.stack([r.mask for r in res]))

    def nnp(self, ds_ids, q_batch: DatasetIndex):
        """DEPRECATED shim (use `search`): tree-pruned NNP for B (query,
        dataset id) requests -> (dists (B, nq), idx (B, nq))."""
        ds = np.atleast_1d(np.asarray(ds_ids, np.int32))
        rows = self._host_tree_rows(q_batch)
        res = self.search([
            Query(op="nnp", ds_id=int(ds[i]), q_index=rows[i])
            for i in range(ds.shape[0])])
        return (jnp.asarray(np.stack([r.vals for r in res])),
                jnp.asarray(np.stack([r.ids for r in res])))
