"""Declarative query spec for the unified `QueryEngine.search` entry point.

The paper's pitch is ONE system spanning coarse dataset search and fine
point search; this module is the API form of that claim.  A client builds
frozen :class:`Query` values (an op tag plus typed params) — or a two-stage
:class:`Pipeline` (dataset-level top-k feeding a point-level op inside the
winners) — and hands a mixed list of them to ``engine.search``; every
result comes back as a uniform :class:`SearchResult` in input order.

The specs are deliberately dumb data: validation happens at construction
(`__post_init__`), planning and dispatch live in :mod:`repro.engine.plan`,
and the arithmetic stays in the engine's per-op executors.  Nothing here
touches a device.

Op tags and their required params:

    =====================  ==========================================
    op                     params
    =====================  ==========================================
    range_search           r_lo, r_hi
    topk_ia                q_lo=r_lo, q_hi=r_hi, k
    topk_gbo               q_sig, k
    topk_hausdorff_approx  q (raw points) or q_index, k, eps
    topk_hausdorff         q or q_index, k [, refine_levels, chunk]
    range_points           ds_id, r_lo, r_hi
    nnp                    ds_id, q or q_index
    topk_overlap           q (raw points), k
    topk_coverage          q (raw points), k
    =====================  ==========================================

The joinable ops (``topk_overlap`` / ``topk_coverage``) rank repository
datasets by grid-cell joinability with the query point set (see
:mod:`repro.core.join_search`); they take RAW points only — the scoring
grid needs no ball tree.  They may drive a Pipeline's first stage like
any dataset top-k, and uniquely may also serve as its SECOND stage
(a dataset→dataset pipeline: stage-1 winners re-ranked by joinability
with the stage-2 query set, the id handoff staying on device).

Index-consuming ops accept either a raw ``(n, d)`` point array (``q``) —
the planner batches the ball-tree builds per dispatch group — or a
pre-built single-query :class:`~repro.core.index.DatasetIndex` row
(``q_index``), which is what the legacy batch methods pass through.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

OPS = (
    "range_search", "topk_ia", "topk_gbo", "topk_hausdorff_approx",
    "topk_hausdorff", "range_points", "nnp", "topk_overlap",
    "topk_coverage",
)
#: joinable dataset ops (grid overlap / coverage) — dataset-granularity
#: top-k ops that can also RE-RANK a pipeline's stage-1 winners
DATASET_RERANK_OPS = ("topk_overlap", "topk_coverage")
#: dataset-granularity ops returning a top-k id list — the only ops that can
#: drive a Pipeline's first stage (RangeS returns a mask, not ranked ids)
DATASET_TOPK_OPS = (
    "topk_ia", "topk_gbo", "topk_hausdorff_approx", "topk_hausdorff",
) + DATASET_RERANK_OPS
#: ops a Pipeline's second stage may run: point ops inside each winner, or
#: a joinable op re-ranking the winners themselves (dataset→dataset)
POINT_OPS = ("range_points", "nnp")

# params that must be present (not None) per op; ds_id is checked separately
# because a Pipeline's point stage legitimately leaves it None
_REQUIRED = {
    "range_search": ("r_lo", "r_hi"),
    "topk_ia": ("r_lo", "r_hi", "k"),
    "topk_gbo": ("q_sig", "k"),
    "topk_hausdorff_approx": ("k", "eps"),
    "topk_hausdorff": ("k",),
    "range_points": ("r_lo", "r_hi"),
    "nnp": (),
    "topk_overlap": ("q", "k"),
    "topk_coverage": ("q", "k"),
}
_NEEDS_QUERY_SET = ("topk_hausdorff_approx", "topk_hausdorff", "nnp")


@dataclass(frozen=True)
class Query:
    """One declarative search request (see module docstring for the op
    table).  Frozen: a Query is immutable once constructed, so the planner
    may regroup/reorder freely and the result cache can trust its content.
    """

    op: str
    r_lo: Any = None          # (d,) box corner — RangeS/IA/RangeP
    r_hi: Any = None
    q_sig: Any = None         # (w,) z-order signature — GBO
    q: Any = None             # raw (n, d) query point set
    q_index: Any = None       # pre-built single-query DatasetIndex row
    ds_id: Any = None         # target dataset — RangeP/NNP (None in a
                              # Pipeline's point stage: filled from stage 1)
    k: int | None = None
    eps: float | None = None
    refine_levels: int = 3    # ExactHaus static params
    chunk: int | None = None  # None -> the engine's tuned default_chunk

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; valid ops: {OPS}")
        for name in _REQUIRED[self.op]:
            if getattr(self, name) is None:
                raise ValueError(f"Query(op={self.op!r}) requires {name!r}")
        if self.op in _NEEDS_QUERY_SET:
            if self.q is None and self.q_index is None:
                raise ValueError(
                    f"Query(op={self.op!r}) requires q or q_index")
            if self.q is not None and self.q_index is not None:
                raise ValueError(
                    f"Query(op={self.op!r}): pass q OR q_index, not both")
            if self.q_index is not None and not (
                    hasattr(self.q_index, "points")
                    and hasattr(self.q_index, "depth")):
                raise ValueError(
                    f"Query(op={self.op!r}): q_index must be a built "
                    f"DatasetIndex row (got {type(self.q_index)!r}); "
                    f"pass raw points as q= instead")
        if self.op in DATASET_RERANK_OPS and self.q_index is not None:
            raise ValueError(
                f"Query(op={self.op!r}) scores on the shared grid — pass "
                f"raw points as q=, not a built index row")

    # -- planning keys -----------------------------------------------------

    def statics(self) -> tuple:
        """The static (compile-relevant / shared-scalar) part of the query:
        two queries may share one device dispatch iff their op AND statics
        agree — the same compatibility rule serve_search grouped by."""
        if (self.op == "topk_ia" or self.op == "topk_gbo"
                or self.op in DATASET_RERANK_OPS):
            return (self.k,)
        if self.op == "topk_hausdorff_approx":
            return (self.k, float(self.eps))
        if self.op == "topk_hausdorff":
            return (self.k, self.refine_levels, self.chunk)
        return ()

    def query_shape_sig(self, leaf_capacity: int) -> tuple:
        """Shape signature of the query point set, for grouping: raw sets
        group together (the grouped `build_queries` pads them to one
        capacity, exactly like the serving front-end always did), while
        pre-built index rows group by their actual (capacity, depth) so
        stacking them is shape-exact."""
        if self.op not in _NEEDS_QUERY_SET:
            return ()
        if self.q_index is not None:
            return ("idx", int(self.q_index.points.shape[-2]),
                    self.q_index.depth)
        return ("raw",)

    def built_capacity(self, leaf_capacity: int) -> int:
        """Point capacity `build_queries` would pad this query's set to if
        built ALONE — the stage-2 grouping key for pipelines (host-side,
        no device sync)."""
        if self.q_index is not None:
            return int(self.q_index.points.shape[-2])
        n = int(np.asarray(self.q).shape[0])
        cap = leaf_capacity
        while cap < n:
            cap *= 2
        return cap


@dataclass(frozen=True)
class Pipeline:
    """The paper's multi-granularity case study as ONE first-class query:
    ``dataset_stage`` (a top-k dataset op) selects the k winning dataset
    ids, which feed ``point_stage`` (RangeP or NNP) restricted to those
    datasets — one point query per winner, the id handoff staying on
    device.  Planned as two engine dispatches: stage 1 rides the mixed-op
    groups alongside ordinary queries; stage 2 groups across pipelines.

    ``point_stage`` may instead be a joinable op (``topk_overlap`` /
    ``topk_coverage``): a dataset→dataset pipeline where the stage-1
    winners are exactly re-scored against the stage's own query set and
    re-ranked to its top-``k`` (ties keep stage-1 rank order); the winner
    ids still never leave the device before stage-2 scoring.
    """

    dataset_stage: Query
    point_stage: Query

    def __post_init__(self):
        if self.dataset_stage.op not in DATASET_TOPK_OPS:
            raise ValueError(
                f"Pipeline dataset_stage must be a top-k dataset op "
                f"{DATASET_TOPK_OPS}, got {self.dataset_stage.op!r}")
        if (self.point_stage.op not in POINT_OPS
                and self.point_stage.op not in DATASET_RERANK_OPS):
            raise ValueError(
                f"Pipeline point_stage must be a point op {POINT_OPS} or "
                f"a joinable re-rank op {DATASET_RERANK_OPS}, "
                f"got {self.point_stage.op!r}")
        if self.point_stage.ds_id is not None:
            raise ValueError(
                "Pipeline point_stage.ds_id must be None — the ids come "
                "from the dataset stage's top-k")


@dataclass(frozen=True)
class SearchResult:
    """Uniform per-query result of ``engine.search`` (input order).

    Field population by op:

      * ``range_search``          — ``mask`` (B_pad,) dataset hit mask
      * ``topk_ia`` / ``topk_gbo``— ``vals``/``ids`` (k,)
      * ``topk_hausdorff_approx`` — ``vals``/``ids`` (k,),
        ``extras['eps_eff']``
      * ``topk_hausdorff``        — ``vals``/``ids`` (k,), ``stats``
        (:class:`~repro.core.search.SearchStats`)
      * ``range_points``          — ``mask`` (n_pad,) point take mask,
        ``stats`` (:class:`~repro.core.point_search.PointStats`)
      * ``nnp``                   — ``vals`` NN dists / ``ids`` NN indices
        (nq,), ``mask`` query-point validity, ``stats`` (PointStats)
      * ``pipeline``              — stage-2 outputs stacked over the k
        winners (``mask`` (k, n_pad) takes for RangeP; ``vals``/``ids``
        (k, nq) for NNP), ``extras['stage1']`` the full stage-1
        SearchResult, ``extras['ds_ids']`` the winner ids and
        ``extras['valid']`` their >= 0 mask (k past the valid dataset
        count yields -1 sentinels whose stage-2 rows are masked out).

    Array fields are materialized numpy row views of the group's dispatch
    output (one materialization per dispatch, free per-row slicing — a
    per-row device op would cost more than a small dispatch); ``stats``
    entries are host values.  Inside a Pipeline the stage-1 -> stage-2 id
    handoff does NOT go through these views: the planner slices the ids
    from the device-resident dispatch output directly.
    """

    op: str
    vals: Any = None
    ids: Any = None
    mask: Any = None
    stats: Any = None
    extras: dict = field(default_factory=dict)
