"""Pallas kernel for GBO (Def. 7): popcount(AND) between signature stacks.

Signatures are fixed-width uint32 bitsets (zorder.py).  The tile computes
counts for a (TA, TB) block of dataset pairs, looping the (small, static)
word axis and accumulating popcounts in VREGs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TA = 256
TB = 256


def _intersect_kernel(sa_ref, sb_ref, o_ref, *, n_words: int):
    sa = sa_ref[...]
    sb = sb_ref[...]
    acc = jnp.zeros((sa.shape[0], sb.shape[0]), jnp.int32)
    for w in range(n_words):
        both = sa[:, w][:, None] & sb[:, w][None, :]
        acc += jax.lax.population_count(both).astype(jnp.int32)
    o_ref[...] = acc


def intersect_counts(
    sa: jax.Array,
    sb: jax.Array,
    *,
    ta: int = TA,
    tb: int = TB,
    interpret: bool = False,
) -> jax.Array:
    """GBO count matrix (na, nb) int32 between signature stacks."""
    na, W = sa.shape
    nb = sb.shape[0]
    grid = (na // ta, nb // tb)
    kernel = functools.partial(_intersect_kernel, n_words=W)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ta, W), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, W), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ta, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((na, nb), jnp.int32),
        interpret=interpret,
    )(sa, sb)
