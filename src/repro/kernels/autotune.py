"""Autotuned dispatch constants for the Pallas kernel entry points.

The seed wrappers in :mod:`repro.kernels.ops` hard-coded their routing
constants (``tq=256 / td=512`` streaming tiles, the ``tile=128`` slab of
the pair-grid evaluator, ``tn=tm=256`` bound tiles) and the kernel-vs-ref
switch was a fixed size threshold.  This module replaces those constants
with a small measured table:

* :func:`resolve` — the ONLY routing decision point.  Every public op in
  ``ops.py`` calls it from plain Python (BEFORE its inner jit boundary)
  with the operand shapes; it returns the :class:`KernelConfig` whose
  fields land in the jitted implementation as explicit static arguments.
  Routing is therefore never baked into a traced program: a table update
  changes what the wrapper passes, and the engine keys its executable
  cache on :func:`epoch` so a tuner update can never leave a stale cached
  executable serving old constants.
* :func:`ensure_tuned` — the one-time measured sweep.  Callers (the
  engine's ``tune()``, benchmarks) hand it a runner per candidate config;
  the winner is cached per ``(backend, op, shape bucket)`` and
  :func:`epoch` is bumped.  Tuning is strictly OPT-IN: until a sweep runs,
  :data:`DEFAULTS` reproduce the seed constants exactly, so untuned code
  paths behave (and route) precisely as before.

Correctness is guarded twice: the per-element arithmetic of every kernel
is the shared coordinate-unrolled form of its ``ref.*`` oracle (fp
min/max reassociation is exact, so tiling changes no bits wherever XLA
makes the same FMA-contraction choice — shape-dependent on CPU), and the
engine's ``tune()`` sweep only admits a candidate after checking its
output is BITWISE equal to the untuned default route at the probe shape.
A tuned table can therefore only ever change SPEED.  The
routing-boundary suite in ``tests/test_kernels.py`` asserts equality at
and around every threshold.

Environment overrides (read dynamically, so tests and CI can flip them
per-process):

* ``REPRO_FORCE_KERNEL=1`` — route every default call through the Pallas
  kernel path regardless of size (thresholds drop to 1; tile sizes keep
  their tuned/default values, so small inputs are padded up to one tile).
  CI uses this to give the interpret-mode kernels real CPU coverage.
* ``REPRO_FORCE_REF=1`` — route every default call through the pure-jnp
  oracles.

Explicit per-call arguments always win over both the table and the
environment: ``use_kernel=False`` pins the ref path (callers inside
vmapped frontier code rely on this), ``use_kernel=True`` forces the
kernel path at ANY size (the wrappers pad up to one tile), and explicit
tile sizes also become the routing thresholds, exactly like the seed
keyword defaults did.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, replace

import jax

__all__ = [
    "KernelConfig", "DEFAULTS", "resolve", "lookup", "ensure_tuned",
    "set_config", "epoch", "table_key", "bucket", "report", "clear",
]


@dataclass(frozen=True)
class KernelConfig:
    """Routing decision + tile constants for one kernel entry point.

    ``tq``/``td`` are the Pallas tile sizes along the two streamed operand
    axes (``tn``/``tm`` for the bound matrices, ``tb``/``ts`` for the
    fused bound grid, ``ta``/``tb`` for set intersection — the wrappers
    map their historical keyword names onto these two fields).  ``tile``
    is the sub-threshold streaming slab of the pair-grid evaluator.
    ``min_q``/``min_d`` are the routing thresholds; ``None`` means "the
    tile size", which reproduces the seed rule ``n >= tile``.  A tuned
    table entry stores ``min_q = min_d = 1`` so its kernel-vs-ref verdict
    applies to the whole shape bucket it was measured for.
    """

    use_kernel: bool = True
    tq: int = 256
    td: int = 512
    tile: int = 128
    min_q: int | None = None
    min_d: int | None = None

    def thresholds(self) -> tuple[int, int]:
        return (self.tq if self.min_q is None else self.min_q,
                self.td if self.min_d is None else self.min_d)


#: Seed routing constants per op — the exact values the wrappers hard-coded
#: before the tuner existed.  An untuned process resolves to these.
DEFAULTS: dict[str, KernelConfig] = {
    "directed_hausdorff": KernelConfig(True, 256, 512),
    "nn_distance": KernelConfig(True, 256, 512),
    "hausdorff_grid": KernelConfig(True, 256, 512, tile=128),
    "bound_matrices": KernelConfig(True, 256, 256),
    "set_intersect": KernelConfig(True, 256, 256),
    # fused (B, S) bound grid: B rides the engine's query-batch bucket
    # ladder, so the kernel only pays off for very large batches; the
    # conservative default keeps the fused jnp oracle until a sweep says
    # otherwise
    "bound_grid": KernelConfig(True, 8, 128, min_q=256, min_d=256),
}

_table: dict[tuple, KernelConfig] = {}
_epoch: int = 0


def epoch() -> int:
    """Monotone tuner-table version.  The engine folds this into its
    executable-cache keys, so a table update (``set_config`` /
    ``ensure_tuned``) transparently invalidates every executable that was
    built under older routing constants."""
    return _epoch


def bucket(n: int) -> int:
    """Power-of-two shape bucket (same ladder the engine pads batches to)."""
    b = 1
    n = int(n)
    while b < n:
        b *= 2
    return b


def table_key(op: str, shape) -> tuple:
    """Cache key for one tuning decision: (backend, op, bucketed shape)."""
    return (jax.default_backend(), op) + tuple(bucket(s) for s in shape)


def lookup(op: str, shape) -> KernelConfig:
    """Table/default/env lookup — pure host-side dict work, safe to call
    at trace time (the wrappers call it while tracing outer jits)."""
    base = _table.get(table_key(op, shape), DEFAULTS[op])
    if os.environ.get("REPRO_FORCE_KERNEL"):
        return replace(base, use_kernel=True, min_q=1, min_d=1)
    if os.environ.get("REPRO_FORCE_REF"):
        return replace(base, use_kernel=False)
    return base


def resolve(
    op: str,
    shape,
    *,
    tq: int | None = None,
    td: int | None = None,
    tile: int | None = None,
    use_kernel: bool | None = None,
) -> KernelConfig:
    """Final routing decision for one call: explicit arguments beat the
    table, the table beats :data:`DEFAULTS`.

    Returns a config whose ``use_kernel`` is the RESOLVED verdict for this
    shape: the seed threshold rule (``n_q >= min_q and n_d >= min_d``)
    applied to the effective thresholds — explicit tile sizes double as
    thresholds, exactly like the seed keyword defaults did.  An explicit
    ``use_kernel=True`` forces the kernel path at any size (the wrappers
    pad up to one tile); explicit ``False`` pins the ref path.
    """
    cfg = lookup(op, shape)
    min_q, min_d = cfg.thresholds()
    if tq is not None:
        min_q = tq
    if td is not None:
        min_d = td
    eff = replace(
        cfg,
        tq=cfg.tq if tq is None else tq,
        td=cfg.td if td is None else td,
        tile=cfg.tile if tile is None else tile,
        min_q=min_q,
        min_d=min_d,
    )
    n_q, n_d = int(shape[0]), int(shape[1])
    if use_kernel is not None:
        kernel = bool(use_kernel)
    else:
        kernel = eff.use_kernel and n_q >= min_q and n_d >= min_d
    return replace(eff, use_kernel=kernel)


def set_config(op: str, shape, cfg: KernelConfig) -> None:
    """Install one tuned entry and bump :func:`epoch`."""
    global _epoch
    _table[table_key(op, shape)] = cfg
    _epoch += 1


def clear() -> None:
    """Drop every tuned entry (tests).  Bumps :func:`epoch` so engines
    holding executables built under tuned constants re-key."""
    global _epoch
    _table.clear()
    _epoch += 1


def ensure_tuned(
    op: str,
    shape,
    runner,
    candidates,
    *,
    repeats: int = 3,
    force: bool = False,
    timer=time.perf_counter,
):
    """One-time measured sweep for ``(op, shape bucket)``.

    ``runner(cfg)`` must execute the op under candidate ``cfg`` and block
    until the result is ready; it runs once for warmup/compile and then
    ``repeats`` timed times per candidate.  The fastest candidate is
    installed via :func:`set_config` (bumping :func:`epoch`) and returned
    with the per-candidate timings.  A cached decision short-circuits
    unless ``force=True`` — the sweep is one-time per process.

    Must be called from plain Python (never inside a trace): it measures
    wall-clock and mutates the process-global table.
    """
    key = table_key(op, shape)
    if key in _table and not force:
        return _table[key], None
    timings = []
    for cfg in candidates:
        runner(cfg)                       # warmup / compile
        t0 = timer()
        for _ in range(repeats):
            runner(cfg)
        timings.append((timer() - t0) / repeats)
    best = min(range(len(candidates)), key=timings.__getitem__)
    chosen = candidates[best]
    set_config(op, shape, chosen)
    info = {
        "key": key,
        "timings_s": timings,
        "chosen": best,
        "use_kernel": chosen.use_kernel,
    }
    return chosen, info


def report() -> dict:
    """Snapshot of every tuned decision (observability / bench records)."""
    return {
        "epoch": _epoch,
        "entries": {
            repr(k): {
                "use_kernel": v.use_kernel,
                "tq": v.tq, "td": v.td, "tile": v.tile,
                "min_q": v.min_q, "min_d": v.min_d,
            }
            for k, v in _table.items()
        },
    }
