"""Pallas kernel for the paper's fast bound estimation (Eq. 4).

Computes the (LB, UB) Hausdorff bound matrices between two node frontiers
from ONE center-distance evaluation per node pair — the paper's O(1)-bound
insight is what turns the whole frontier into a single dense tile sweep
(DESIGN.md sec. 2).  Tiles are (TN, TM); both outputs share the sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # python float: baked into the kernel, not a captured const

TN = 256
TM = 256

# fused (B, S) bound grid: query-batch x corpus-slot tiles
TB = 8
TS = 128


def _bound_kernel(oq_ref, rq_ref, od_ref, rd_ref, lb_ref, ub_ref, *, n_coords: int):
    oq = oq_ref[...]
    od = od_ref[...]
    # ref.unrolled_sq_dists' exact accumulation (first square, then adds
    # in coordinate order) so the tile stays bitwise equal to the oracle
    acc = None
    for c in range(n_coords):
        diff = oq[:, c][:, None] - od[:, c][None, :]
        sq = diff * diff
        acc = sq if acc is None else acc + sq
    cd = jnp.sqrt(acc)
    rq = rq_ref[...][:, None]
    rd = rd_ref[...]
    # square rd at its own (TM,) shape BEFORE broadcasting, exactly like
    # ref.bound_matrix's (rd * rd)[None, :] — fusing the square into the
    # broadcast add invites an FMA contraction the oracle doesn't have
    rd2 = (rd * rd)[None, :]
    lb_ref[...] = jnp.maximum(cd - rd[None, :], 0.0)
    ub_ref[...] = jnp.sqrt(acc + rd2) + rq


def bound_matrices(
    oq: jax.Array,
    rq: jax.Array,
    od: jax.Array,
    rd: jax.Array,
    *,
    n_coords: int,
    tn: int = TN,
    tm: int = TM,
    interpret: bool = False,
):
    """Eq. 4 (lb, ub) matrices, each (nq, nd) f32.  Shapes pre-padded."""
    nq = oq.shape[0]
    nd = od.shape[0]
    grid = (nq // tn, nd // tm)
    kernel = functools.partial(_bound_kernel, n_coords=n_coords)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, oq.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((tn,), lambda i, j: (i,)),
            pl.BlockSpec((tm, od.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((tm,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
            pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, nd), jnp.float32),
            jax.ShapeDtypeStruct((nq, nd), jnp.float32),
        ],
        interpret=interpret,
    )(oq, rq, od, rd)


def _bound_grid_kernel(oq_ref, rq_ref, qok_ref, od_ref, rd_ref, dok_ref,
                       lb_ref, ub_ref, *, levels: tuple, n_coords: int):
    """One (query-tile, slot-tile) step of the fused multi-level bound
    reduction: every tree level's (LB, UB) frontier values from ONE dense
    center-distance evaluation over the full node range.

    oq_ref (TB, N, W) / rq_ref (TB, N) / qok_ref (TB, N): query-tree tile
    od_ref (TS, N, W) / rd_ref (TS, N) / dok_ref (TS, N): corpus tile
    lb_ref, ub_ref (L, TB, TS): per-level reduced bounds for this tile

    The dense (TB, N, TS, N) bound tensors live only in VMEM/VREGs for
    this tile; each level then reduces its static node slice [a, b) on
    both node axes.  Per-element arithmetic matches
    `ref.frontier_bound_levels` exactly (coordinate-unrolled squares,
    same add order, rd squared at its own shape), and fp min/max are
    exactly associative — kernel-vs-ref bitwise equality holds wherever
    XLA makes the same FMA-contraction choice for the two program shapes
    (shape-dependent on CPU; tests assert it at verified shapes and the
    engine tuner gates kernel routing on it per shape bucket).
    """
    # (TB, TS, N, N) accumulation in ref.unrolled_sq_dists' exact axis
    # layout and add order, so XLA emits the identical contraction as the
    # jnp oracle and the kernel stays bitwise equal to the ref path
    oq = oq_ref[...]
    od = od_ref[...]
    acc = None
    for c in range(n_coords):
        diff = oq[:, :, c][:, None, :, None] - od[:, :, c][None, :, None, :]
        sq = diff * diff
        acc = sq if acc is None else acc + sq
    cd = jnp.sqrt(acc)
    rd = rd_ref[...]
    # square rd at its own (TS, N) shape before broadcasting, exactly like
    # ref.frontier_bound_levels (see _bound_kernel for why)
    rd2 = (rd * rd)[None, :, None, :]
    lb = jnp.maximum(cd - rd[None, :, None, :], 0.0)
    ub = jnp.sqrt(acc + rd2) + rq_ref[...][:, None, :, None]
    dok = dok_ref[...][None, :, None, :]
    lb = jnp.where(dok, lb, BIG)
    ub = jnp.where(dok, ub, BIG)
    qok = qok_ref[...][:, None, :]
    for l, (a, b) in enumerate(levels):
        okl = qok[..., a:b]
        row_lb = jnp.min(lb[:, :, a:b, a:b], axis=-1)
        row_ub = jnp.min(ub[:, :, a:b, a:b], axis=-1)
        lb_ref[l] = jnp.max(jnp.where(okl, row_lb, -BIG), axis=-1)
        ub_ref[l] = jnp.max(jnp.where(okl, row_ub, -BIG), axis=-1)


def bound_grid(
    oq: jax.Array,
    rq: jax.Array,
    q_ok: jax.Array,
    od: jax.Array,
    rd: jax.Array,
    d_ok: jax.Array,
    *,
    levels: tuple,
    n_coords: int,
    tb: int = TB,
    ts: int = TS,
    interpret: bool = False,
):
    """Fused multi-level (B, S) frontier bounds: the kernel counterpart of
    `ref.frontier_bound_levels`.

    oq (B, N, W) / rq, q_ok (B, N) x od (S, N, W) / rd, d_ok (S, N) ->
    (LB, UB) each (len(levels), B, S) f32.  B % tb == 0 and S % ts == 0
    (ops.py pads; padded rows carry q_ok/d_ok = False).
    """
    B, N = rq.shape
    S = rd.shape[0]
    L = len(levels)
    grid = (B // tb, S // ts)
    kernel = functools.partial(_bound_grid_kernel, levels=tuple(levels),
                               n_coords=n_coords)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, N, oq.shape[-1]), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tb, N), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, N), lambda i, j: (i, 0)),
            pl.BlockSpec((ts, N, od.shape[-1]), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((ts, N), lambda i, j: (j, 0)),
            pl.BlockSpec((ts, N), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, tb, ts), lambda i, j: (0, i, j)),
            pl.BlockSpec((L, tb, ts), lambda i, j: (0, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, B, S), jnp.float32),
            jax.ShapeDtypeStruct((L, B, S), jnp.float32),
        ],
        interpret=interpret,
    )(oq, rq, q_ok, od, rd, d_ok)
