"""Pallas kernel for the paper's fast bound estimation (Eq. 4).

Computes the (LB, UB) Hausdorff bound matrices between two node frontiers
from ONE center-distance evaluation per node pair — the paper's O(1)-bound
insight is what turns the whole frontier into a single dense tile sweep
(DESIGN.md sec. 2).  Tiles are (TN, TM); both outputs share the sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TN = 256
TM = 256


def _bound_kernel(oq_ref, rq_ref, od_ref, rd_ref, lb_ref, ub_ref, *, n_coords: int):
    oq = oq_ref[...]
    od = od_ref[...]
    acc = jnp.zeros((oq.shape[0], od.shape[0]), jnp.float32)
    for c in range(n_coords):
        diff = oq[:, c][:, None] - od[:, c][None, :]
        acc += diff * diff
    cd = jnp.sqrt(acc)
    rq = rq_ref[...][:, None]
    rd = rd_ref[...][None, :]
    lb_ref[...] = jnp.maximum(cd - rd, 0.0)
    ub_ref[...] = jnp.sqrt(acc + rd * rd) + rq


def bound_matrices(
    oq: jax.Array,
    rq: jax.Array,
    od: jax.Array,
    rd: jax.Array,
    *,
    n_coords: int,
    tn: int = TN,
    tm: int = TM,
    interpret: bool = False,
):
    """Eq. 4 (lb, ub) matrices, each (nq, nd) f32.  Shapes pre-padded."""
    nq = oq.shape[0]
    nd = od.shape[0]
    grid = (nq // tn, nd // tm)
    kernel = functools.partial(_bound_kernel, n_coords=n_coords)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, oq.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((tn,), lambda i, j: (i,)),
            pl.BlockSpec((tm, od.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((tm,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
            pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, nd), jnp.float32),
            jax.ShapeDtypeStruct((nq, nd), jnp.float32),
        ],
        interpret=interpret,
    )(oq, rq, od, rd)
