"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel allclose tests and the fallback
implementation for tiny shapes.  They materialize the full O(nq x nd)
distance matrix — exactly the HBM blow-up the kernels avoid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

BIG = jnp.float32(3.4e38)


def directed_hausdorff(q: Array, d: Array, q_valid: Array, d_valid: Array) -> Array:
    """H(Q -> D) = max_{p in Q} min_{p' in D} ||p - p'|| with masks."""
    diff = q[:, None, :] - d[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    d2 = jnp.where(d_valid[None, :], d2, BIG)
    nnd = jnp.sqrt(jnp.min(d2, axis=1))
    nnd = jnp.where(q_valid, nnd, -BIG)
    return jnp.max(nnd)


def nn_distance(q: Array, d: Array, q_valid: Array, d_valid: Array):
    """Per-Q-point nearest neighbor in D: (dists (nq,), idx (nq,))."""
    diff = q[:, None, :] - d[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    d2 = jnp.where(d_valid[None, :], d2, BIG)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist = jnp.sqrt(jnp.min(d2, axis=1))
    dist = jnp.where(q_valid, dist, 0.0)
    idx = jnp.where(q_valid, idx, -1)
    return dist, idx


def bound_matrix(oq: Array, rq: Array, od: Array, rd: Array):
    """Paper Eq. 4 bound matrices between two node frontiers.

    oq (nq, dim), rq (nq,), od (nd, dim), rd (nd,) ->
    (lb, ub) each (nq, nd).
    """
    diff = oq[:, None, :] - od[None, :, :]
    cd2 = jnp.sum(diff * diff, axis=-1)
    cd = jnp.sqrt(cd2)
    lb = jnp.maximum(cd - rd[None, :], 0.0)
    ub = jnp.sqrt(cd2 + (rd * rd)[None, :]) + rq[:, None]
    return lb, ub


def set_intersect_count(sa: Array, sb: Array) -> Array:
    """GBO counts between two signature stacks: sa (na, W) u32, sb (nb, W)
    -> (na, nb) int32 popcount(AND) totals."""
    both = sa[:, None, :] & sb[None, :, :]
    return jax.lax.population_count(both).astype(jnp.int32).sum(axis=-1)
