"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel allclose tests and the fallback
implementation for tiny shapes.  They materialize the full O(nq x nd)
distance matrix — exactly the HBM blow-up the kernels avoid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

BIG = jnp.float32(3.4e38)


def unrolled_sq_dists(a: Array, b: Array) -> Array:
    """sum_c (a[..., c] - b[..., c])**2 with the coordinate axis UNROLLED
    into a running per-coordinate accumulation.

    `a` and `b` must already be broadcast-compatible up to the trailing
    coordinate axis.  Unrolling avoids materializing a (..., dim) diff
    tensor and reducing it — XLA:CPU emits a far better loop nest (the
    exact-Hausdorff hot path) — and the arithmetic per entry is the same
    squares added in the same coordinate order, so results stay bit-stable
    across eager/jit/vmap contexts.  This is the ONE definition of the
    squared-distance accumulation shared by every site that must stay
    bitwise identical (masked_sq_dists, bound_matrix, and the slab loop in
    `ops.directed_hausdorff_grid`); the ExactHaus bit-identity suites
    assert the contract.
    """
    d2 = None
    for c in range(a.shape[-1]):
        diff = a[..., c] - b[..., c]
        sq = diff * diff
        d2 = sq if d2 is None else d2 + sq
    return d2


def masked_sq_dists(q: Array, d: Array, d_valid: Array) -> Array:
    """(nq, nd) squared distances with invalid D columns masked to BIG."""
    d2 = unrolled_sq_dists(q[:, None, :], d[None, :, :])
    return jnp.where(d_valid[None, :], d2, BIG)


def directed_hausdorff(q: Array, d: Array, q_valid: Array, d_valid: Array) -> Array:
    """H(Q -> D) = max_{p in Q} min_{p' in D} ||p - p'|| with masks."""
    d2 = masked_sq_dists(q, d, d_valid)
    nnd = jnp.sqrt(jnp.min(d2, axis=1))
    nnd = jnp.where(q_valid, nnd, -BIG)
    return jnp.max(nnd)


def nn_distance(q: Array, d: Array, q_valid: Array, d_valid: Array):
    """Per-Q-point nearest neighbor in D: (dists (nq,), idx (nq,)).

    Distances use :func:`masked_sq_dists` (the shared coordinate-unrolled
    accumulation) so the oracle's per-entry arithmetic is bitwise the same
    as the NN kernel's tile arithmetic — the kernel-vs-ref routing
    boundary can then never shift a distance by even one ulp.
    """
    d2 = masked_sq_dists(q, d, d_valid)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist = jnp.sqrt(jnp.min(d2, axis=1))
    dist = jnp.where(q_valid, dist, 0.0)
    idx = jnp.where(q_valid, idx, -1)
    return dist, idx


def bound_matrix(oq: Array, rq: Array, od: Array, rd: Array):
    """Paper Eq. 4 bound matrices between two node frontiers.

    oq (nq, dim), rq (nq,), od (nd, dim), rd (nd,) ->
    (lb, ub) each (nq, nd).

    The center-distance matrix uses :func:`unrolled_sq_dists` (same bits,
    bit-stable across eager/jit/vmap — the bound phases run eager in the
    host oracle and vmapped under jit in the batched engine, and their
    candidate counters are asserted equal).
    """
    cd2 = unrolled_sq_dists(oq[:, None, :], od[None, :, :])
    cd = jnp.sqrt(cd2)
    lb = jnp.maximum(cd - rd[None, :], 0.0)
    ub = jnp.sqrt(cd2 + (rd * rd)[None, :]) + rq[:, None]
    return lb, ub


def frontier_bound_levels(oq: Array, rq: Array, q_ok: Array,
                          od: Array, rd: Array, d_ok: Array,
                          levels: tuple):
    """Fused multi-level (B, S) frontier bound reduction (Eq. 4 + the
    min/max frontier collapse of `core.search.frontier_bounds`), every
    level in ONE pass over the node range.

    oq (B, N, dim) / rq (B, N) / q_ok (B, N) are the query trees' node
    centers/radii/occupancy over the contiguous node range covering every
    level; od (S, N, dim) / rd (S, N) / d_ok (S, N) likewise for the
    corpus trees.  ``levels`` is a static tuple of (start, stop) node
    slices — one per tree level, applied to BOTH node axes (the bound
    phases always compare level l against level l).

    Returns (LB, UB), each (n_levels, B, S): for level slice [a, b),

        LB[l, b, s] = max_{i in q_ok} min_{j in d_ok} lb(i, j)

    over nodes i, j in [a, b), and symmetrically for UB — the per-level
    value `frontier_bounds` computes from its per-level `bound_matrix`.
    The per-entry arithmetic is the same coordinate-unrolled form and fp
    min/max reductions are exactly associative, so the REDUCTION order
    changes no bits; residual deviation vs the per-level composition is
    XLA's shape-dependent FMA contraction on the squared-distance
    accumulation (~1 ulp, asserted within tolerance by the bound_phases
    benchmark).  What the suites assert BITWISE is kernel-vs-ref equality
    of this fused op at verified shapes (tests/test_kernels.py) and
    cross-path ExactHaus equality (all pipelines consume this one op).
    """
    cd2 = unrolled_sq_dists(oq[:, None, :, None, :], od[None, :, None, :, :])
    cd = jnp.sqrt(cd2)                       # (B, S, N, N)
    # square rd at its own (S, N) shape before broadcasting, matching
    # ref.bound_matrix's (rd * rd)[None, :]
    rd2 = (rd * rd)[None, :, None, :]
    lb = jnp.maximum(cd - rd[None, :, None, :], 0.0)
    ub = jnp.sqrt(cd2 + rd2) + rq[:, None, :, None]
    lb = jnp.where(d_ok[None, :, None, :], lb, BIG)
    ub = jnp.where(d_ok[None, :, None, :], ub, BIG)
    ok = q_ok[:, None, :]
    LBs, UBs = [], []
    for a, b in levels:
        okl = ok[..., a:b]
        row_lb = jnp.min(lb[:, :, a:b, a:b], axis=-1)
        row_ub = jnp.min(ub[:, :, a:b, a:b], axis=-1)
        LBs.append(jnp.max(jnp.where(okl, row_lb, -BIG), axis=-1))
        UBs.append(jnp.max(jnp.where(okl, row_ub, -BIG), axis=-1))
    return jnp.stack(LBs), jnp.stack(UBs)


def set_intersect_count(sa: Array, sb: Array) -> Array:
    """GBO counts between two signature stacks: sa (na, W) u32, sb (nb, W)
    -> (na, nb) int32 popcount(AND) totals."""
    both = sa[:, None, :] & sb[None, :, :]
    return jax.lax.population_count(both).astype(jnp.int32).sum(axis=-1)
