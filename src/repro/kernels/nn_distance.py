"""Pallas kernel for NNP (paper Sec. VI-B.2): per-query-point nearest
neighbor distance AND index over a streamed point set.

Same streaming scheme as hausdorff.py with a second output carrying the
running argmin (global D row index, built from the tile offset + iota).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # python float: baked into the kernel, not a captured const

TQ = 256
TD = 512


def _nn_kernel(q_ref, d_ref, dvalid_ref, dist_ref, idx_ref, *, n_coords: int, td: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.full(dist_ref.shape, BIG, jnp.float32)
        idx_ref[...] = jnp.full(idx_ref.shape, -1, jnp.int32)

    q = q_ref[...]
    d = d_ref[...]
    # ref.unrolled_sq_dists' exact accumulation (see hausdorff.py) so the
    # kernel stays bitwise equal to the ref oracle across routing changes
    acc = None
    for c in range(n_coords):
        diff = q[:, c][:, None] - d[:, c][None, :]
        sq = diff * diff
        acc = sq if acc is None else acc + sq
    acc = jnp.where(dvalid_ref[...][None, :], acc, BIG)
    tile_min = jnp.min(acc, axis=1)
    tile_arg = jnp.argmin(acc, axis=1).astype(jnp.int32) + j * td
    better = tile_min < dist_ref[...]
    dist_ref[...] = jnp.where(better, tile_min, dist_ref[...])
    idx_ref[...] = jnp.where(better, tile_arg, idx_ref[...])


def nn_sq_dists(
    q: jax.Array,
    d: jax.Array,
    d_valid: jax.Array,
    *,
    n_coords: int,
    tq: int = TQ,
    td: int = TD,
    interpret: bool = False,
):
    """(nq,) min squared distance + (nq,) argmin D row index."""
    nq = q.shape[0]
    nd = d.shape[0]
    grid = (nq // tq, nd // td)
    kernel = functools.partial(_nn_kernel, n_coords=n_coords, td=td)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, q.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((td, d.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((td,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tq,), lambda i, j: (i,)),
            pl.BlockSpec((tq,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq,), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        interpret=interpret,
    )(q, d, d_valid)
