"""Pallas TPU kernel for the directed Hausdorff hot spot (paper Sec. VI-A.2).

Scheme (DESIGN.md sec. 6): flash-attention-style streaming reduction.
The grid is (Q-tiles, D-tiles); for each Q tile we keep a running per-row
nearest-neighbor distance in the output block (VMEM-resident across the
D-tile sweep, because the output BlockSpec maps every j to the same block).
The |Q| x |D| distance matrix only ever exists one (TQ, TD) tile at a time
in VMEM/VREGs — it is never materialized in HBM.

Layout: points are (n, COORD_PAD) with the coordinate dim padded to a small
static width; the squared distance uses the broadcast-subtract form, unrolled
over coordinates (exact, no |x|^2-2xy cancellation), which is VPU-friendly
since the (TQ, TD) tile is the vectorized shape.

The final max over Q rows happens in the jit wrapper (ops.py) — it is O(nq)
and fuses into the surrounding graph.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # python float: baked into the kernel, not a captured const

# default tile sizes: (TQ, TD) fp32 tile = 256*512*4B = 512 KiB << 16 MiB VMEM
TQ = 256
TD = 512
COORD_PAD = 8


def _min_dist_kernel(q_ref, d_ref, dvalid_ref, o_ref, *, n_coords: int):
    """One (Q-tile, D-tile) step: update running per-Q-row min distance.

    q_ref      (TQ, COORD_PAD) f32 : Q tile
    d_ref      (TD, COORD_PAD) f32 : D tile
    dvalid_ref (TD,)           bool: D slot validity
    o_ref      (TQ,)           f32 : running min of SQUARED distances
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, BIG, jnp.float32)

    q = q_ref[...]
    d = d_ref[...]
    # ref.unrolled_sq_dists' exact accumulation (first square, then adds in
    # coordinate order — no zero init), so the tile arithmetic compiles to
    # the identical contraction as the jnp oracle and routing never
    # changes bits
    acc = None
    for c in range(n_coords):  # static unroll over true coord count
        diff = q[:, c][:, None] - d[:, c][None, :]
        sq = diff * diff
        acc = sq if acc is None else acc + sq
    acc = jnp.where(dvalid_ref[...][None, :], acc, BIG)
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(acc, axis=1))


def min_sq_dists(
    q: jax.Array,
    d: jax.Array,
    d_valid: jax.Array,
    *,
    n_coords: int,
    tq: int = TQ,
    td: int = TD,
    interpret: bool = False,
) -> jax.Array:
    """Per-Q-row min squared distance to any valid D row.

    q (nq, COORD_PAD), d (nd, COORD_PAD), d_valid (nd,) -> (nq,) f32.
    nq % tq == 0 and nd % td == 0 (ops.py pads).
    """
    nq = q.shape[0]
    nd = d.shape[0]
    grid = (nq // tq, nd // td)
    kernel = functools.partial(_min_dist_kernel, n_coords=n_coords)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, q.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((td, d.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((td,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.float32),
        interpret=interpret,
    )(q, d, d_valid)


def _min_dist_grid_kernel(q_ref, d_ref, dvalid_ref, o_ref, *, n_coords: int):
    """One (pair, Q-tile, D-tile) step of the (B, C) pair-grid evaluator.

    q_ref      (1, TQ, W)    f32 : Q tile of pair (b, c) = (bc//C, bc%C)
    d_ref      (1, 1, TD, W) f32 : D tile of that pair
    dvalid_ref (1, 1, TD)    bool
    o_ref      (1, 1, TQ)    f32 : running per-Q-row min SQUARED distance

    Same flash-attention-style running reduction as `_min_dist_kernel`,
    but the pair index is a grid axis — the whole (B, C) frontier is ONE
    kernel launch instead of a vmap of per-pair launches.  The D-tile
    axis is the fastest grid dimension, so the output block persists in
    VMEM across the k sweep and is initialized at k == 0.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, BIG, jnp.float32)

    q = q_ref[0]
    d = d_ref[0, 0]
    acc = None  # ref.unrolled_sq_dists' accumulation, as in _min_dist_kernel
    for c in range(n_coords):  # static unroll over true coord count
        diff = q[:, c][:, None] - d[:, c][None, :]
        sq = diff * diff
        acc = sq if acc is None else acc + sq
    acc = jnp.where(dvalid_ref[0, 0][None, :], acc, BIG)
    o_ref[0, 0] = jnp.minimum(o_ref[0, 0], jnp.min(acc, axis=1))


def min_sq_dists_grid(
    q: jax.Array,
    ds: jax.Array,
    ds_valid: jax.Array,
    *,
    n_coords: int,
    tq: int = TQ,
    td: int = TD,
    interpret: bool = False,
) -> jax.Array:
    """Per-Q-row min squared distance for every (query, chunk-slot) pair.

    q (B, nq, W), ds (B, C, nd, W), ds_valid (B, C, nd) -> (B, C, nq) f32.
    nq % tq == 0 and nd % td == 0 (ops.py pads).  One grid over
    (B*C pairs, Q tiles, D tiles); bitwise equal to running
    `min_sq_dists` per pair (identical tile arithmetic, exact min
    reassociation).
    """
    B, C, nd, _ = ds.shape
    nq = q.shape[1]
    grid = (B * C, nq // tq, nd // td)
    kernel = functools.partial(_min_dist_grid_kernel, n_coords=n_coords)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, q.shape[-1]),
                         lambda bc, i, k: (bc // C, i, 0)),
            pl.BlockSpec((1, 1, td, ds.shape[-1]),
                         lambda bc, i, k: (bc // C, bc % C, k, 0)),
            pl.BlockSpec((1, 1, td), lambda bc, i, k: (bc // C, bc % C, k)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq), lambda bc, i, k: (bc // C, bc % C, i)),
        out_shape=jax.ShapeDtypeStruct((B, C, nq), jnp.float32),
        interpret=interpret,
    )(q, ds, ds_valid)
