"""Pallas TPU kernel for the directed Hausdorff hot spot (paper Sec. VI-A.2).

Scheme (DESIGN.md sec. 6): flash-attention-style streaming reduction.
The grid is (Q-tiles, D-tiles); for each Q tile we keep a running per-row
nearest-neighbor distance in the output block (VMEM-resident across the
D-tile sweep, because the output BlockSpec maps every j to the same block).
The |Q| x |D| distance matrix only ever exists one (TQ, TD) tile at a time
in VMEM/VREGs — it is never materialized in HBM.

Layout: points are (n, COORD_PAD) with the coordinate dim padded to a small
static width; the squared distance uses the broadcast-subtract form, unrolled
over coordinates (exact, no |x|^2-2xy cancellation), which is VPU-friendly
since the (TQ, TD) tile is the vectorized shape.

The final max over Q rows happens in the jit wrapper (ops.py) — it is O(nq)
and fuses into the surrounding graph.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # python float: baked into the kernel, not a captured const

# default tile sizes: (TQ, TD) fp32 tile = 256*512*4B = 512 KiB << 16 MiB VMEM
TQ = 256
TD = 512
COORD_PAD = 8


def _min_dist_kernel(q_ref, d_ref, dvalid_ref, o_ref, *, n_coords: int):
    """One (Q-tile, D-tile) step: update running per-Q-row min distance.

    q_ref      (TQ, COORD_PAD) f32 : Q tile
    d_ref      (TD, COORD_PAD) f32 : D tile
    dvalid_ref (TD,)           bool: D slot validity
    o_ref      (TQ,)           f32 : running min of SQUARED distances
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, BIG, jnp.float32)

    q = q_ref[...]
    d = d_ref[...]
    acc = jnp.zeros((q.shape[0], d.shape[0]), jnp.float32)
    for c in range(n_coords):  # static unroll over true coord count
        diff = q[:, c][:, None] - d[:, c][None, :]
        acc += diff * diff
    acc = jnp.where(dvalid_ref[...][None, :], acc, BIG)
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(acc, axis=1))


def min_sq_dists(
    q: jax.Array,
    d: jax.Array,
    d_valid: jax.Array,
    *,
    n_coords: int,
    tq: int = TQ,
    td: int = TD,
    interpret: bool = False,
) -> jax.Array:
    """Per-Q-row min squared distance to any valid D row.

    q (nq, COORD_PAD), d (nd, COORD_PAD), d_valid (nd,) -> (nq,) f32.
    nq % tq == 0 and nd % td == 0 (ops.py pads).
    """
    nq = q.shape[0]
    nd = d.shape[0]
    grid = (nq // tq, nd // td)
    kernel = functools.partial(_min_dist_kernel, n_coords=n_coords)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, q.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((td, d.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((td,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.float32),
        interpret=interpret,
    )(q, d, d_valid)
