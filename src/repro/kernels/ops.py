"""jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, coordinate-dim padding, the TPU/interpret
switch (this container is CPU: kernels run with interpret=True, which
executes the kernel body in Python — correctness path; TPU is the perf
target), and tiny-shape fallbacks to the pure-jnp oracles in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bound_matrix as _bm
from repro.kernels import hausdorff as _haus
from repro.kernels import nn_distance as _nn
from repro.kernels import ref
from repro.kernels import set_intersect as _si

Array = jax.Array

INTERPRET = jax.default_backend() != "tpu"
BIG = ref.BIG


def _pad_rows(x: Array, mult: int, fill=0.0) -> Array:
    n = x.shape[0]
    target = max(mult, ((n + mult - 1) // mult) * mult)
    if target == n:
        return x
    pad = [(0, target - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


def _pad_coords(x: Array, width: int) -> Array:
    d = x.shape[-1]
    if d >= width:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, width - d)])


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def directed_hausdorff(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int = 256, td: int = 512, use_kernel: bool = True,
) -> Array:
    """H(Q -> D), masked.  Kernel path streams D tiles (no HBM matrix)."""
    if not use_kernel or q.shape[0] < tq or d.shape[0] < td:
        return ref.directed_hausdorff(q, d, q_valid, d_valid)
    n_coords = q.shape[-1]
    width = max(8, n_coords)
    qp = _pad_rows(_pad_coords(q, width), tq)
    dp = _pad_rows(_pad_coords(d, width), td)
    dv = _pad_rows(d_valid, td, fill=False)
    mins = _haus.min_sq_dists(qp, dp, dv, n_coords=n_coords, tq=tq, td=td,
                              interpret=INTERPRET)
    nnd = jnp.sqrt(jnp.minimum(mins[: q.shape[0]], BIG))
    nnd = jnp.where(q_valid, nnd, -BIG)
    return jnp.max(nnd)


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def nn_distance(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int = 256, td: int = 512, use_kernel: bool = True,
):
    """Per-Q-point NN distance + D index (NNP hot loop)."""
    if not use_kernel or q.shape[0] < tq or d.shape[0] < td:
        return ref.nn_distance(q, d, q_valid, d_valid)
    n_coords = q.shape[-1]
    width = max(8, n_coords)
    qp = _pad_rows(_pad_coords(q, width), tq)
    dp = _pad_rows(_pad_coords(d, width), td)
    dv = _pad_rows(d_valid, td, fill=False)
    d2, idx = _nn.nn_sq_dists(qp, dp, dv, n_coords=n_coords, tq=tq, td=td,
                              interpret=INTERPRET)
    d2 = d2[: q.shape[0]]
    idx = idx[: q.shape[0]]
    dist = jnp.sqrt(jnp.minimum(d2, BIG))
    dist = jnp.where(q_valid, dist, 0.0)
    idx = jnp.where(q_valid, idx, -1)
    return dist, idx


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def directed_hausdorff_batched(
    q: Array, ds: Array, q_valid: Array, ds_valid: Array,
    *, tq: int = 256, td: int = 512, use_kernel: bool = True,
) -> Array:
    """H(Q -> D_i) for one query against a stack of datasets (B, n, d).

    One device dispatch for the whole stack — the engine's and ExactHaus
    phase 2's hot path."""
    return jax.vmap(
        lambda d, dv: directed_hausdorff(q, d, q_valid, dv, tq=tq, td=td,
                                         use_kernel=use_kernel)
    )(ds, ds_valid)


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def nn_distance_batched(
    qs: Array, ds: Array, qs_valid: Array, ds_valid: Array,
    *, tq: int = 256, td: int = 512, use_kernel: bool = True,
):
    """Per-point NN for B (query, dataset) pairs: (B, nq) dists + ids."""
    return jax.vmap(
        lambda q, d, qv, dv: nn_distance(q, d, qv, dv, tq=tq, td=td,
                                         use_kernel=use_kernel)
    )(qs, ds, qs_valid, ds_valid)


@functools.partial(jax.jit, static_argnames=("tn", "tm", "use_kernel"))
def bound_matrices(
    oq: Array, rq: Array, od: Array, rd: Array,
    *, tn: int = 256, tm: int = 256, use_kernel: bool = True,
):
    """Eq. 4 (lb, ub) matrices over two node frontiers."""
    if not use_kernel or oq.shape[0] < tn or od.shape[0] < tm:
        return ref.bound_matrix(oq, rq, od, rd)
    n_coords = oq.shape[-1]
    width = max(8, n_coords)
    nq, nd = oq.shape[0], od.shape[0]
    oqp = _pad_rows(_pad_coords(oq, width), tn)
    odp = _pad_rows(_pad_coords(od, width), tm)
    rqp = _pad_rows(rq, tn)
    rdp = _pad_rows(rd, tm)
    lb, ub = _bm.bound_matrices(oqp, rqp, odp, rdp, n_coords=n_coords,
                                tn=tn, tm=tm, interpret=INTERPRET)
    return lb[:nq, :nd], ub[:nq, :nd]


@functools.partial(jax.jit, static_argnames=("ta", "tb", "use_kernel"))
def set_intersect_counts(
    sa: Array, sb: Array, *, ta: int = 256, tb: int = 256,
    use_kernel: bool = True,
) -> Array:
    """GBO count matrix between signature stacks (na, W) x (nb, W)."""
    if not use_kernel or sa.shape[0] < ta or sb.shape[0] < tb:
        return ref.set_intersect_count(sa, sb)
    na, nb = sa.shape[0], sb.shape[0]
    sap = _pad_rows(sa, ta)
    sbp = _pad_rows(sb, tb)
    out = _si.intersect_counts(sap, sbp, ta=ta, tb=tb, interpret=INTERPRET)
    return out[:na, :nb]
