"""jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, coordinate-dim padding, the TPU/interpret
switch (this container is CPU: kernels run with interpret=True, which
executes the kernel body in Python — correctness path; TPU is the perf
target), and tiny-shape fallbacks to the pure-jnp oracles in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bound_matrix as _bm
from repro.kernels import hausdorff as _haus
from repro.kernels import nn_distance as _nn
from repro.kernels import ref
from repro.kernels import set_intersect as _si

Array = jax.Array

INTERPRET = jax.default_backend() != "tpu"
BIG = ref.BIG


def _pad_rows(x: Array, mult: int, fill=0.0) -> Array:
    n = x.shape[0]
    target = max(mult, ((n + mult - 1) // mult) * mult)
    if target == n:
        return x
    pad = [(0, target - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


def _pad_coords(x: Array, width: int) -> Array:
    d = x.shape[-1]
    if d >= width:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, width - d)])


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def directed_hausdorff(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int = 256, td: int = 512, use_kernel: bool = True,
) -> Array:
    """H(Q -> D), masked.  Kernel path streams D tiles (no HBM matrix)."""
    if not use_kernel or q.shape[0] < tq or d.shape[0] < td:
        return ref.directed_hausdorff(q, d, q_valid, d_valid)
    n_coords = q.shape[-1]
    width = max(8, n_coords)
    qp = _pad_rows(_pad_coords(q, width), tq)
    dp = _pad_rows(_pad_coords(d, width), td)
    dv = _pad_rows(d_valid, td, fill=False)
    mins = _haus.min_sq_dists(qp, dp, dv, n_coords=n_coords, tq=tq, td=td,
                              interpret=INTERPRET)
    nnd = jnp.sqrt(jnp.minimum(mins[: q.shape[0]], BIG))
    nnd = jnp.where(q_valid, nnd, -BIG)
    return jnp.max(nnd)


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def nn_distance(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int = 256, td: int = 512, use_kernel: bool = True,
):
    """Per-Q-point NN distance + D index (NNP hot loop)."""
    if not use_kernel or q.shape[0] < tq or d.shape[0] < td:
        return ref.nn_distance(q, d, q_valid, d_valid)
    n_coords = q.shape[-1]
    width = max(8, n_coords)
    qp = _pad_rows(_pad_coords(q, width), tq)
    dp = _pad_rows(_pad_coords(d, width), td)
    dv = _pad_rows(d_valid, td, fill=False)
    d2, idx = _nn.nn_sq_dists(qp, dp, dv, n_coords=n_coords, tq=tq, td=td,
                              interpret=INTERPRET)
    d2 = d2[: q.shape[0]]
    idx = idx[: q.shape[0]]
    dist = jnp.sqrt(jnp.minimum(d2, BIG))
    dist = jnp.where(q_valid, dist, 0.0)
    idx = jnp.where(q_valid, idx, -1)
    return dist, idx


@functools.partial(jax.jit,
                   static_argnames=("tile", "tq", "td", "use_kernel"))
def directed_hausdorff_grid(
    q: Array, ds: Array, q_valid: Array, ds_valid: Array, *,
    tile: int = 128, tq: int = 256, td: int = 512, use_kernel: bool = True,
) -> Array:
    """H(Q_b -> D_{b,j}) over a (B, C) query x candidate-chunk grid.

    q (B, nq, d) queries against ds (B, C, nd, d) per-query candidate
    stacks -> (B, C).  The hot path of batched ExactHaus phase 2: one
    fused evaluation for every (query, chunk-slot) pair in the shared
    work frontier.

    Kernel-sized shapes (nq >= tq and nd >= td) route to the Pallas
    streaming kernel vmapped over the pair grid — the same routing rule
    and kernel as :func:`directed_hausdorff`, so the host oracle's
    per-pair evaluations take the identical code path at every shape.
    Below the thresholds the D point axis is streamed in ``tile``-wide
    slabs with a running minimum (non-multiple nd is padded with invalid
    columns), so the intermediate is (B, C, nq, tile) instead of the full
    (B, C, nq, nd) matrix.  Bitwise equal to `ref.directed_hausdorff` per
    pair: the per-entry arithmetic is `ref.unrolled_sq_dists` on each
    slab, and fp min/max are exactly associative, so the slab
    reassociation changes no bits (asserted by the ExactHaus bit-identity
    suites).
    """
    B, C, nd, n_coords = ds.shape
    nq = q.shape[1]

    if use_kernel and nq >= tq and nd >= td:
        width = max(8, n_coords)
        qp = _pad_coords(q, width)
        qp = jnp.pad(qp, ((0, 0), (0, -nq % tq), (0, 0)))
        dp = _pad_coords(ds, width)
        dp = jnp.pad(dp, ((0, 0), (0, 0), (0, -nd % td), (0, 0)))
        dv = jnp.pad(ds_valid, ((0, 0), (0, 0), (0, -nd % td)))

        def per_pair(qp_i, dp_ij, dv_ij):
            return _haus.min_sq_dists(qp_i, dp_ij, dv_ij,
                                      n_coords=n_coords, tq=tq, td=td,
                                      interpret=INTERPRET)

        mins = jax.vmap(lambda qp_i, dp_i, dv_i: jax.vmap(
            lambda dp_ij, dv_ij: per_pair(qp_i, dp_ij, dv_ij)
        )(dp_i, dv_i))(qp, dp, dv)[:, :, :nq]
        mins = jnp.minimum(mins, ref.BIG)
    else:
        if nd % tile:
            if nd < tile:
                tile = nd
            else:
                # pad to a tile multiple with invalid columns (masked to
                # BIG inside the slab, so the running min is unchanged)
                # rather than abandoning streaming for the full matrix
                ds = jnp.pad(ds, ((0, 0), (0, 0), (0, -nd % tile), (0, 0)))
                ds_valid = jnp.pad(ds_valid,
                                   ((0, 0), (0, 0), (0, -nd % tile)))
                nd = ds.shape[2]
        n_tiles = nd // tile

        def slab_mins(dp, dv):
            # (B, C, nq, tile) masked squared distances -> (B, C, nq) mins
            d2 = ref.unrolled_sq_dists(q[:, None, :, None, :],
                                       dp[:, :, None, :, :])
            d2 = jnp.where(dv[:, :, None, :], d2, ref.BIG)
            return jnp.min(d2, axis=-1)

        if n_tiles == 1:
            mins = slab_mins(ds, ds_valid)
        else:
            def body(t, acc):
                dp = jax.lax.dynamic_slice_in_dim(ds, t * tile, tile,
                                                  axis=2)
                dv = jax.lax.dynamic_slice_in_dim(ds_valid, t * tile, tile,
                                                  axis=2)
                return jnp.minimum(acc, slab_mins(dp, dv))

            mins = jax.lax.fori_loop(
                0, n_tiles, body,
                jnp.full((B, C, nq), ref.BIG, jnp.float32))
    nnd = jnp.sqrt(mins)
    nnd = jnp.where(q_valid[:, None, :], nnd, -ref.BIG)
    return jnp.max(nnd, axis=-1)


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def nn_distance_batched(
    qs: Array, ds: Array, qs_valid: Array, ds_valid: Array,
    *, tq: int = 256, td: int = 512, use_kernel: bool = True,
):
    """Per-point NN for B (query, dataset) pairs: (B, nq) dists + ids."""
    return jax.vmap(
        lambda q, d, qv, dv: nn_distance(q, d, qv, dv, tq=tq, td=td,
                                         use_kernel=use_kernel)
    )(qs, ds, qs_valid, ds_valid)


@functools.partial(jax.jit, static_argnames=("tn", "tm", "use_kernel"))
def bound_matrices(
    oq: Array, rq: Array, od: Array, rd: Array,
    *, tn: int = 256, tm: int = 256, use_kernel: bool = True,
):
    """Eq. 4 (lb, ub) matrices over two node frontiers."""
    if not use_kernel or oq.shape[0] < tn or od.shape[0] < tm:
        return ref.bound_matrix(oq, rq, od, rd)
    n_coords = oq.shape[-1]
    width = max(8, n_coords)
    nq, nd = oq.shape[0], od.shape[0]
    oqp = _pad_rows(_pad_coords(oq, width), tn)
    odp = _pad_rows(_pad_coords(od, width), tm)
    rqp = _pad_rows(rq, tn)
    rdp = _pad_rows(rd, tm)
    lb, ub = _bm.bound_matrices(oqp, rqp, odp, rdp, n_coords=n_coords,
                                tn=tn, tm=tm, interpret=INTERPRET)
    return lb[:nq, :nd], ub[:nq, :nd]


@functools.partial(jax.jit, static_argnames=("ta", "tb", "use_kernel"))
def set_intersect_counts(
    sa: Array, sb: Array, *, ta: int = 256, tb: int = 256,
    use_kernel: bool = True,
) -> Array:
    """GBO count matrix between signature stacks (na, W) x (nb, W)."""
    if not use_kernel or sa.shape[0] < ta or sb.shape[0] < tb:
        return ref.set_intersect_count(sa, sb)
    na, nb = sa.shape[0], sb.shape[0]
    sap = _pad_rows(sa, ta)
    sbp = _pad_rows(sb, tb)
    out = _si.intersect_counts(sap, sbp, ta=ta, tb=tb, interpret=INTERPRET)
    return out[:na, :nb]
