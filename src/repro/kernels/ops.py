"""Public wrappers around the Pallas kernels.

Each public op is a PLAIN-PYTHON wrapper that resolves its routing
(kernel vs ref oracle, tile sizes) through :mod:`repro.kernels.autotune`
and then calls an inner jitted implementation with the resolved constants
as explicit static arguments.  Keeping the decision outside the jit
boundary means tuned constants are never baked into a traced program —
the autotuner's :func:`autotune.epoch` plus the engine's executable-cache
keys guarantee a table update re-routes every subsequent dispatch.

The inner impls handle padding to tile multiples, coordinate-dim padding,
and the TPU/interpret switch (this container is CPU: kernels run with
interpret=True, which executes the kernel body via XLA ops — correctness
path; TPU is the perf target).  ``use_kernel=False`` pins the pure-jnp
oracle in ref.py; ``use_kernel=True`` forces the kernel at any size (the
padding helpers round tiny inputs up to one tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import bound_matrix as _bm
from repro.kernels import hausdorff as _haus
from repro.kernels import nn_distance as _nn
from repro.kernels import ref
from repro.kernels import set_intersect as _si

Array = jax.Array

INTERPRET = jax.default_backend() != "tpu"
BIG = ref.BIG


def _pad_rows(x: Array, mult: int, fill=0.0) -> Array:
    n = x.shape[0]
    target = max(mult, ((n + mult - 1) // mult) * mult)
    if target == n:
        return x
    pad = [(0, target - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


def _pad_coords(x: Array, width: int) -> Array:
    d = x.shape[-1]
    if d >= width:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, width - d)])


def directed_hausdorff(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int | None = None, td: int | None = None,
    use_kernel: bool | None = None,
) -> Array:
    """H(Q -> D), masked.  Kernel path streams D tiles (no HBM matrix)."""
    cfg = autotune.resolve("directed_hausdorff", (q.shape[0], d.shape[0]),
                           tq=tq, td=td, use_kernel=use_kernel)
    return _directed_hausdorff(q, d, q_valid, d_valid, tq=cfg.tq, td=cfg.td,
                               use_kernel=cfg.use_kernel)


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def _directed_hausdorff(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int, td: int, use_kernel: bool,
) -> Array:
    if not use_kernel:
        return ref.directed_hausdorff(q, d, q_valid, d_valid)
    n_coords = q.shape[-1]
    width = max(8, n_coords)
    qp = _pad_rows(_pad_coords(q, width), tq)
    dp = _pad_rows(_pad_coords(d, width), td)
    dv = _pad_rows(d_valid, td, fill=False)
    mins = _haus.min_sq_dists(qp, dp, dv, n_coords=n_coords, tq=tq, td=td,
                              interpret=INTERPRET)
    nnd = jnp.sqrt(jnp.minimum(mins[: q.shape[0]], BIG))
    nnd = jnp.where(q_valid, nnd, -BIG)
    return jnp.max(nnd)


def nn_distance(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int | None = None, td: int | None = None,
    use_kernel: bool | None = None,
):
    """Per-Q-point NN distance + D index (NNP hot loop)."""
    cfg = autotune.resolve("nn_distance", (q.shape[0], d.shape[0]),
                           tq=tq, td=td, use_kernel=use_kernel)
    return _nn_distance(q, d, q_valid, d_valid, tq=cfg.tq, td=cfg.td,
                        use_kernel=cfg.use_kernel)


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def _nn_distance(
    q: Array, d: Array, q_valid: Array, d_valid: Array,
    *, tq: int, td: int, use_kernel: bool,
):
    if not use_kernel:
        return ref.nn_distance(q, d, q_valid, d_valid)
    n_coords = q.shape[-1]
    width = max(8, n_coords)
    qp = _pad_rows(_pad_coords(q, width), tq)
    dp = _pad_rows(_pad_coords(d, width), td)
    dv = _pad_rows(d_valid, td, fill=False)
    d2, idx = _nn.nn_sq_dists(qp, dp, dv, n_coords=n_coords, tq=tq, td=td,
                              interpret=INTERPRET)
    d2 = d2[: q.shape[0]]
    idx = idx[: q.shape[0]]
    dist = jnp.sqrt(jnp.minimum(d2, BIG))
    dist = jnp.where(q_valid, dist, 0.0)
    idx = jnp.where(q_valid, idx, -1)
    return dist, idx


def directed_hausdorff_grid(
    q: Array, ds: Array, q_valid: Array, ds_valid: Array, *,
    tile: int | None = None, tq: int | None = None, td: int | None = None,
    use_kernel: bool | None = None,
) -> Array:
    """H(Q_b -> D_{b,j}) over a (B, C) query x candidate-chunk grid.

    q (B, nq, d) queries against ds (B, C, nd, d) per-query candidate
    stacks -> (B, C).  The hot path of batched ExactHaus phase 2: one
    fused evaluation for every (query, chunk-slot) pair in the shared
    work frontier.

    Kernel-sized shapes route to ONE Pallas pair-grid launch
    (`hausdorff.min_sq_dists_grid`: grid = (B*C, Q-tiles, D-tiles)),
    bitwise equal per pair to the per-pair streaming kernel and to the
    jitted per-pair op.  Below the thresholds the D point axis is
    streamed in ``tile``-wide slabs with a running minimum (non-multiple
    nd is padded with invalid columns), so the intermediate is
    (B, C, nq, tile) instead of the full (B, C, nq, nd) matrix.  Bitwise
    equal to `ref.directed_hausdorff` per pair on both paths: the
    per-entry arithmetic is `ref.unrolled_sq_dists` on each slab/tile,
    and fp min/max are exactly associative, so the reassociation changes
    no bits (asserted by the ExactHaus bit-identity suites).
    """
    cfg = autotune.resolve("hausdorff_grid", (q.shape[1], ds.shape[2]),
                           tq=tq, td=td, tile=tile, use_kernel=use_kernel)
    return _directed_hausdorff_grid(q, ds, q_valid, ds_valid, tile=cfg.tile,
                                    tq=cfg.tq, td=cfg.td,
                                    use_kernel=cfg.use_kernel)


@functools.partial(jax.jit,
                   static_argnames=("tile", "tq", "td", "use_kernel"))
def _directed_hausdorff_grid(
    q: Array, ds: Array, q_valid: Array, ds_valid: Array, *,
    tile: int, tq: int, td: int, use_kernel: bool,
) -> Array:
    B, C, nd, n_coords = ds.shape
    nq = q.shape[1]

    if use_kernel:
        width = max(8, n_coords)
        qp = _pad_coords(q, width)
        qp = jnp.pad(qp, ((0, 0), (0, -nq % tq), (0, 0)))
        dp = _pad_coords(ds, width)
        dp = jnp.pad(dp, ((0, 0), (0, 0), (0, -nd % td), (0, 0)))
        dv = jnp.pad(ds_valid, ((0, 0), (0, 0), (0, -nd % td)))
        mins = _haus.min_sq_dists_grid(qp, dp, dv, n_coords=n_coords,
                                       tq=tq, td=td,
                                       interpret=INTERPRET)[:, :, :nq]
        mins = jnp.minimum(mins, ref.BIG)
    else:
        if nd % tile:
            if nd < tile:
                tile = nd
            else:
                # pad to a tile multiple with invalid columns (masked to
                # BIG inside the slab, so the running min is unchanged)
                # rather than abandoning streaming for the full matrix
                ds = jnp.pad(ds, ((0, 0), (0, 0), (0, -nd % tile), (0, 0)))
                ds_valid = jnp.pad(ds_valid,
                                   ((0, 0), (0, 0), (0, -nd % tile)))
                nd = ds.shape[2]
        n_tiles = nd // tile

        def slab_mins(dp, dv):
            # (B, C, nq, tile) masked squared distances -> (B, C, nq) mins
            d2 = ref.unrolled_sq_dists(q[:, None, :, None, :],
                                       dp[:, :, None, :, :])
            d2 = jnp.where(dv[:, :, None, :], d2, ref.BIG)
            return jnp.min(d2, axis=-1)

        if n_tiles == 1:
            mins = slab_mins(ds, ds_valid)
        else:
            def body(t, acc):
                dp = jax.lax.dynamic_slice_in_dim(ds, t * tile, tile,
                                                  axis=2)
                dv = jax.lax.dynamic_slice_in_dim(ds_valid, t * tile, tile,
                                                  axis=2)
                return jnp.minimum(acc, slab_mins(dp, dv))

            mins = jax.lax.fori_loop(
                0, n_tiles, body,
                jnp.full((B, C, nq), ref.BIG, jnp.float32))
    nnd = jnp.sqrt(mins)
    nnd = jnp.where(q_valid[:, None, :], nnd, -ref.BIG)
    return jnp.max(nnd, axis=-1)


def nn_distance_batched(
    qs: Array, ds: Array, qs_valid: Array, ds_valid: Array,
    *, tq: int | None = None, td: int | None = None,
    use_kernel: bool | None = None,
):
    """Per-point NN for B (query, dataset) pairs: (B, nq) dists + ids."""
    cfg = autotune.resolve("nn_distance", (qs.shape[1], ds.shape[1]),
                           tq=tq, td=td, use_kernel=use_kernel)
    return _nn_distance_batched(qs, ds, qs_valid, ds_valid, tq=cfg.tq,
                                td=cfg.td, use_kernel=cfg.use_kernel)


@functools.partial(jax.jit, static_argnames=("tq", "td", "use_kernel"))
def _nn_distance_batched(
    qs: Array, ds: Array, qs_valid: Array, ds_valid: Array,
    *, tq: int, td: int, use_kernel: bool,
):
    return jax.vmap(
        lambda q, d, qv, dv: _nn_distance(q, d, qv, dv, tq=tq, td=td,
                                          use_kernel=use_kernel)
    )(qs, ds, qs_valid, ds_valid)


def bound_matrices(
    oq: Array, rq: Array, od: Array, rd: Array,
    *, tn: int | None = None, tm: int | None = None,
    use_kernel: bool | None = None,
):
    """Eq. 4 (lb, ub) matrices over two node frontiers."""
    cfg = autotune.resolve("bound_matrices", (oq.shape[0], od.shape[0]),
                           tq=tn, td=tm, use_kernel=use_kernel)
    return _bound_matrices(oq, rq, od, rd, tn=cfg.tq, tm=cfg.td,
                           use_kernel=cfg.use_kernel)


@functools.partial(jax.jit, static_argnames=("tn", "tm", "use_kernel"))
def _bound_matrices(
    oq: Array, rq: Array, od: Array, rd: Array,
    *, tn: int, tm: int, use_kernel: bool,
):
    if not use_kernel:
        return ref.bound_matrix(oq, rq, od, rd)
    n_coords = oq.shape[-1]
    width = max(8, n_coords)
    nq, nd = oq.shape[0], od.shape[0]
    oqp = _pad_rows(_pad_coords(oq, width), tn)
    odp = _pad_rows(_pad_coords(od, width), tm)
    rqp = _pad_rows(rq, tn)
    rdp = _pad_rows(rd, tm)
    lb, ub = _bm.bound_matrices(oqp, rqp, odp, rdp, n_coords=n_coords,
                                tn=tn, tm=tm, interpret=INTERPRET)
    return lb[:nq, :nd], ub[:nq, :nd]


def bound_grid(
    oq: Array, rq: Array, q_ok: Array, od: Array, rd: Array, d_ok: Array,
    *, levels: tuple, tb: int | None = None, ts: int | None = None,
    use_kernel: bool | None = None,
):
    """Fused multi-level (B, S) frontier bounds — Eq. 4 plus the min/max
    frontier collapse for EVERY tree level in one op.

    oq (B, N, dim) / rq, q_ok (B, N): batched query-tree node
    centers/radii/occupancy over the contiguous node range [0, N);
    od (S, N, dim) / rd, d_ok (S, N): the corpus trees.  ``levels`` is a
    static tuple of per-level (start, stop) node slices.  Returns
    (LB, UB), each (len(levels), B, S) — LB[l, b, s] is exactly the
    scalar `frontier_bounds` reduces level l of pair (b, s) to.

    Kernel-sized batches route to ONE Pallas launch over (B-tiles,
    S-tiles) computing all levels per tile (`bound_matrix.bound_grid`);
    otherwise the fused jnp oracle `ref.frontier_bound_levels` runs.
    Routing stability: every ExactHaus path (host oracle, local batched,
    sharded) calls THIS op at the same shapes, so they route together and
    stay mutually bit-identical (asserted by the equivalence suites);
    kernel-vs-ref bitwise equality is additionally asserted at verified
    shapes and gated per shape bucket by the engine tuner.
    """
    cfg = autotune.resolve("bound_grid", (oq.shape[0], od.shape[0]),
                           tq=tb, td=ts, use_kernel=use_kernel)
    return _bound_grid(oq, rq, q_ok, od, rd, d_ok, levels=tuple(levels),
                       tb=cfg.tq, ts=cfg.td, use_kernel=cfg.use_kernel)


@functools.partial(jax.jit,
                   static_argnames=("levels", "tb", "ts", "use_kernel"))
def _bound_grid(
    oq: Array, rq: Array, q_ok: Array, od: Array, rd: Array, d_ok: Array,
    *, levels: tuple, tb: int, ts: int, use_kernel: bool,
):
    if not use_kernel:
        return ref.frontier_bound_levels(oq, rq, q_ok, od, rd, d_ok, levels)
    n_coords = oq.shape[-1]
    width = max(8, n_coords)
    B, S = oq.shape[0], od.shape[0]
    oqp = _pad_rows(_pad_coords(oq, width), tb)
    rqp = _pad_rows(rq, tb)
    qop = _pad_rows(q_ok, tb, fill=False)
    odp = _pad_rows(_pad_coords(od, width), ts)
    rdp = _pad_rows(rd, ts)
    dop = _pad_rows(d_ok, ts, fill=False)
    lb, ub = _bm.bound_grid(oqp, rqp, qop, odp, rdp, dop,
                            levels=levels, n_coords=n_coords, tb=tb, ts=ts,
                            interpret=INTERPRET)
    return lb[:, :B, :S], ub[:, :B, :S]


def set_intersect_counts(
    sa: Array, sb: Array, *, ta: int | None = None, tb: int | None = None,
    use_kernel: bool | None = None,
) -> Array:
    """GBO count matrix between signature stacks (na, W) x (nb, W)."""
    cfg = autotune.resolve("set_intersect", (sa.shape[0], sb.shape[0]),
                           tq=ta, td=tb, use_kernel=use_kernel)
    return _set_intersect_counts(sa, sb, ta=cfg.tq, tb=cfg.td,
                                 use_kernel=cfg.use_kernel)


@functools.partial(jax.jit, static_argnames=("ta", "tb", "use_kernel"))
def _set_intersect_counts(
    sa: Array, sb: Array, *, ta: int, tb: int, use_kernel: bool,
) -> Array:
    if not use_kernel:
        return ref.set_intersect_count(sa, sb)
    na, nb = sa.shape[0], sb.shape[0]
    sap = _pad_rows(sa, ta)
    sbp = _pad_rows(sb, tb)
    out = _si.intersect_counts(sap, sbp, ta=ta, tb=tb, interpret=INTERPRET)
    return out[:na, :nb]


def plane_weighted_intersect(
    planes: Array, sigs: Array, *, ta: int | None = None,
    tb: int | None = None, use_kernel: bool | None = None,
) -> Array:
    """Weighted popcount matrix for histogram bit planes: given per-row
    count histograms sliced into bit planes (B, P, W) and signatures
    (S, W), returns (B, S) int32 of sum_p 2^p * |plane_p AND sig| — i.e.
    the joinable *coverage* form (points-in-occupied-cells) expressed so
    the whole batch rides ONE (B*P, S) set-intersect dispatch through the
    same autotune routing as GBO."""
    b, p, w = planes.shape
    cnt = set_intersect_counts(planes.reshape(b * p, w), sigs,
                               ta=ta, tb=tb, use_kernel=use_kernel)
    cnt = cnt.reshape(b, p, sigs.shape[0])
    weights = jnp.left_shift(jnp.int32(1), jnp.arange(p, dtype=jnp.int32))
    return jnp.sum(cnt * weights[None, :, None], axis=1, dtype=jnp.int32)
