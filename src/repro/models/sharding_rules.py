"""Logical-axis sharding for model internals.

Layers annotate activations/params with LOGICAL axis names; the mapping to
physical mesh axes is a process-global rule table set by the launcher.  When
no mesh is active (CPU smoke tests) the constraints are no-ops, so the same
model code runs everywhere.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# default rule table: logical name -> physical mesh axis (or None)
_RULES: dict[str, object] = {
    "batch": ("pod", "data"),   # data parallel over pod x data
    "fsdp": "data",             # parameter shard axis (ZeRO-3)
    "tp": "model",              # tensor parallel (heads / ffn / vocab)
    "seq": None,                # sequence axis (set to "model" for SP)
    "expert": None,             # expert axis ("model" under EP)
    "kv": None,                 # kv-heads axis
    "kvseq": None,              # cache time axis ("model" for long contexts)
}


def set_rules(**kw) -> None:
    _RULES.update(kw)


def get_rules() -> dict:
    return dict(_RULES)


def logical_to_spec(axes: tuple) -> P:
    phys = []
    for a in axes:
        if a is None:
            phys.append(None)
        else:
            phys.append(_RULES.get(a))
    return P(*phys)


# the ACTIVE mesh for logical constraints.  `with mesh:` does NOT populate
# jax.sharding.get_abstract_mesh() during tracing in this jax version, so
# constraints must carry a concrete NamedSharding — the launcher calls
# set_mesh() (specs.make_plan / train.py) and shard() builds NamedShardings
# against it.  No mesh set -> no-op (CPU smoke tests).
_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def _mesh_axis_names() -> tuple:
    if _MESH is not None:
        return tuple(_MESH.axis_names)
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return ()
    if am is None or getattr(am, "empty", True):
        return ()
    return tuple(am.axis_names)


def shard(x, *axes):
    """Constrain x's sharding by logical axis names (no-op w/o a mesh)."""
    names = _mesh_axis_names()
    if not names:
        return x
    phys = []
    for a in axes:
        m = None if a is None else _RULES.get(a)
        if isinstance(m, tuple):
            m = tuple(ax for ax in m if ax in names) or None
        elif m is not None and m not in names:
            m = None
        phys.append(m)
    # drop axes that don't divide the dim (GSPMD would pad; replication is
    # cheaper and never wrong for a constraint)
    phys = [
        (None if (m is not None and x.shape[i] % _axis_size(m) != 0) else m)
        for i, m in enumerate(phys)
    ]
    # dedup mesh axes (e.g. EP maps 'expert' AND 'tp' to 'model'): first
    # occurrence wins, later ones replicate
    used: set = set()
    deduped = []
    for m in phys:
        axes_of = m if isinstance(m, tuple) else (m,) if m else ()
        if any(a in used for a in axes_of):
            deduped.append(None)
            continue
        used.update(axes_of)
        deduped.append(m)
    spec = P(*deduped)
    if _MESH is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(_MESH, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def _axis_size(m) -> int:
    if _MESH is None:
        return 1
    if isinstance(m, tuple):
        n = 1
        for a in m:
            n *= _MESH.shape[a]
        return n
    return _MESH.shape[m]
