"""Unified decoder LM covering all assigned families.

The layer stack is ``n_repeats`` scans over the config's ``block_pattern``
(DESIGN.md): params for each pattern position are stacked over repeats and
the forward pass is one ``lax.scan`` (rematerialized when cfg.remat), which
keeps compile time and HLO size flat in depth — essential for the 40-cell
dry-run on a single CPU.

Three entry points:
  forward      — teacher-forced logits (train_4k)
  prefill      — logits + populated caches (prefill_32k)
  decode_step  — one token against live caches (decode_32k / long_500k)
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import config as cfg_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import (ATTN, ATTN_MOE, ATTN_MOE_DENSE, CROSS,
                                 MAMBA, MAMBA_MOE, ModelConfig)
from repro.models.sharding_rules import shard

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ModelConfig, dtype):
    ks = iter(jax.random.split(key, 8))
    p: dict[str, Any] = {"ln1": L.rmsnorm_init(cfg.d_model, dtype)}
    if kind in (ATTN, ATTN_MOE, ATTN_MOE_DENSE, CROSS):
        p["attn"] = L.attn_init(next(ks), cfg, dtype)
    if kind == CROSS:
        p["xattn"] = L.attn_init(next(ks), cfg, dtype)
        p["lnx"] = L.rmsnorm_init(cfg.d_model, dtype)
        p["xgate"] = jnp.zeros((1,), jnp.float32)  # zero-init gated cross-attn
    if kind in (MAMBA, MAMBA_MOE):
        p["mamba"] = ssm_lib.mamba_init(next(ks), cfg, dtype)
    if cfg.d_ff > 0:
        if kind in (ATTN, MAMBA, CROSS, ATTN_MOE_DENSE):
            p["mlp"] = L.mlp_init(next(ks), cfg.d_model, cfg.d_ff,
                                  cfg.n_layers, dtype)
        if kind in (ATTN_MOE, MAMBA_MOE, ATTN_MOE_DENSE):
            p["moe"] = moe_lib.moe_init(next(ks), cfg, dtype)
        p["ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    k_embed, k_head, k_blocks = jax.random.split(key, 3)
    params: dict[str, Any] = {}
    if cfg.embed_input:
        params["embed"] = L.embed_init(k_embed, cfg.vocab_size,
                                       cfg.d_model, dtype)
    params["head"] = (
        None if cfg.tie_embeddings
        else L.embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype)
    )
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)

    rep_keys = jax.random.split(k_blocks, cfg.n_repeats)

    def init_repeat(k):
        pos_keys = jax.random.split(k, len(cfg.block_pattern))
        return tuple(
            _init_block(pk, kind, cfg, dtype)
            for pk, kind in zip(pos_keys, cfg.block_pattern)
        )

    params["blocks"] = jax.vmap(init_repeat)(rep_keys)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Preallocated decode caches, stacked (n_repeats, ...) per position."""
    R = cfg.n_repeats
    hd = cfg.resolved_head_dim
    cache = []
    for kind in cfg.block_pattern:
        c: dict[str, Any] = {}
        if kind in (ATTN, ATTN_MOE, ATTN_MOE_DENSE, CROSS):
            kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
            c["k"] = shard(
                jnp.zeros((R, batch, max_len, cfg.n_kv_heads, hd), kv_dt),
                None, "batch", "kvseq", "kv", None)
            c["v"] = shard(
                jnp.zeros((R, batch, max_len, cfg.n_kv_heads, hd), kv_dt),
                None, "batch", "kvseq", "kv", None)
            if cfg.kv_cache_dtype == "int8":
                c["k_scale"] = shard(
                    jnp.zeros((R, batch, max_len, cfg.n_kv_heads),
                              jnp.bfloat16),
                    None, "batch", "kvseq", "kv")
                c["v_scale"] = shard(
                    jnp.zeros((R, batch, max_len, cfg.n_kv_heads),
                              jnp.bfloat16),
                    None, "batch", "kvseq", "kv")
        if kind in (MAMBA, MAMBA_MOE):
            c["ssm"] = shard(
                jnp.zeros(
                    (R, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
                None, "batch", "tp", None, None)
            c["conv"] = jnp.zeros(
                (R, batch, cfg.conv_width - 1,
                 cfg.d_inner + 2 * cfg.ssm_state), dtype)
        cache.append(c)
    return tuple(cache)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(kind, p, x, cfg, *, ctx, positions, cache=None,
                 cache_len=None, mode="train"):
    """One pattern-position block.  Returns (x, aux, new_cache)."""
    new_cache: dict[str, Any] = {}
    aux = jnp.float32(0.0)

    if kind in (ATTN, ATTN_MOE, ATTN_MOE_DENSE, CROSS):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        int8 = cfg.kv_cache_dtype == "int8"
        kv = None
        if mode == "decode":
            kv = ((cache["k"], cache["v"], cache["k_scale"],
                   cache["v_scale"]) if int8
                  else (cache["k"], cache["v"]))
        out, new_kv = L.self_attention_block(
            p["attn"], h, cfg, positions=positions,
            kv_cache=kv, cache_len=cache_len)
        x = x + out
        if mode == "decode" and int8:
            new_cache["k"], new_cache["v"] = new_kv[0], new_kv[1]
            new_cache["k_scale"], new_cache["v_scale"] = new_kv[2], new_kv[3]
        elif mode != "train":
            if int8:  # prefill: quantize before storing
                kc, ksc = L.quantize_kv(new_kv[0])
                vc, vsc = L.quantize_kv(new_kv[1])
                new_cache["k"], new_cache["k_scale"] = kc, ksc
                new_cache["v"], new_cache["v_scale"] = vc, vsc
            else:
                new_cache["k"] = shard(new_kv[0].astype(jnp.bfloat16),
                                       "batch", "kvseq", "kv", None)
                new_cache["v"] = shard(new_kv[1].astype(jnp.bfloat16),
                                       "batch", "kvseq", "kv", None)
    if kind == CROSS:
        hx = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
        xo = L.cross_attention_block(p["xattn"], hx, ctx, cfg)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * xo
    if kind in (MAMBA, MAMBA_MOE):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        st = (cache["ssm"], cache["conv"]) if mode == "decode" else (None, None)
        out, (new_ssm, new_conv) = ssm_lib.mamba_block(
            p["mamba"], h, cfg, state=st[0], conv_state=st[1])
        x = x + out
        if mode != "train":
            new_cache["ssm"] = new_ssm
            new_cache["conv"] = (new_conv.astype(jnp.bfloat16)
                                 if new_conv is not None else None)

    if cfg.d_ff > 0:
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind in (ATTN, MAMBA, CROSS):
            x = x + L.mlp(p["mlp"], h2)
        elif kind in (ATTN_MOE, MAMBA_MOE):
            y, aux = moe_lib.moe(p["moe"], h2, cfg)
            x = x + y
        elif kind == ATTN_MOE_DENSE:
            y_moe, aux = moe_lib.moe(p["moe"], h2, cfg)
            x = x + L.mlp(p["mlp"], h2) + y_moe
    return x, aux, new_cache


def _stack(cfg: ModelConfig, params, x, *, ctx, positions, caches=None,
           cache_len=None, mode="train"):
    """Scan the block pattern over n_repeats."""

    def body(carry, inputs):
        x, aux = carry
        rep_params, rep_cache = inputs
        new_rep_cache = []
        for i, kind in enumerate(cfg.block_pattern):
            c = rep_cache[i] if rep_cache is not None else None
            x, a, nc = _apply_block(
                kind, rep_params[i], x, cfg, ctx=ctx, positions=positions,
                cache=c, cache_len=cache_len, mode=mode)
            aux = aux + a
            new_rep_cache.append(nc)
        return (x, aux), tuple(new_rep_cache)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.float32(0.0)),
            (params["blocks"], caches),
        )
        return x, aux, new_caches

    # unrolled path: identical math, straight-line HLO (dry-run cost probes
    # — XLA cost_analysis counts a scan body once, see benchmarks/roofline)
    carry = (x, jnp.float32(0.0))
    collected = []
    for r in range(cfg.n_repeats):
        rep = jax.tree.map(lambda a: a[r], (params["blocks"], caches))
        carry, ys = body(carry, rep)
        collected.append(ys)
    x, aux = carry
    new_caches = jax.tree.map(lambda *zs: jnp.stack(zs), *collected) \
        if collected and jax.tree.leaves(collected[0]) else tuple(
            {} for _ in cfg.block_pattern)
    return x, aux, new_caches


def _embed_in(params, cfg, batch):
    if cfg.embed_input:
        x = L.embed(params["embed"], batch["tokens"])
    else:
        x = batch["embeds"]
    return shard(x.astype(jnp.bfloat16), "batch", "seq", None)


def _logits(params, cfg, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["head"] if params["head"] is not None else params["embed"]
    return L.unembed(head, x)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch) -> tuple[Array, Array]:
    """Teacher-forced logits (B, S, V) + moe aux loss."""
    x = _embed_in(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    ctx = batch.get("image_embeds")
    if ctx is not None:
        ctx = ctx.astype(x.dtype)
    x, aux, _ = _stack(cfg, params, x, ctx=ctx, positions=positions,
                       caches=None, mode="train")
    return _logits(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, batch, max_len: int):
    """Run the full prompt; returns (last-token logits, caches, length)."""
    x = _embed_in(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    ctx = batch.get("image_embeds")
    if ctx is not None:
        ctx = ctx.astype(x.dtype)
    caches = init_cache(cfg, B, max_len)
    x, aux, new_caches = _stack(cfg, params, x, ctx=ctx, positions=positions,
                                caches=caches, mode="prefill")
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, _merge_prefill_caches(cfg, caches, new_caches, S), S


def _merge_prefill_caches(cfg, caches, new_caches, S):
    """Place prefill K/V (length S) into the preallocated max_len caches and
    keep SSM/conv states."""
    merged = []
    for i, kind in enumerate(cfg.block_pattern):
        c = dict(caches[i])
        nc = new_caches[i]
        if "k" in nc and nc["k"] is not None:
            c["k"] = jax.lax.dynamic_update_slice_in_dim(
                c["k"], nc["k"].astype(c["k"].dtype), 0, axis=2)
            c["v"] = jax.lax.dynamic_update_slice_in_dim(
                c["v"], nc["v"].astype(c["v"].dtype), 0, axis=2)
        for sk in ("k_scale", "v_scale"):
            if sk in nc and nc[sk] is not None:
                c[sk] = jax.lax.dynamic_update_slice_in_dim(
                    c[sk], nc[sk], 0, axis=2)
        for key in ("ssm", "conv"):
            if key in nc and nc[key] is not None:
                c[key] = nc[key]
        merged.append(c)
    return tuple(merged)


def decode_step(params, cfg: ModelConfig, tokens, caches, cache_len,
                ctx=None):
    """One decode step.  tokens (B, 1) int32 (or embeds (B, 1, d) when
    cfg.embed_input is False); cache_len: live length scalar.
    Returns (logits (B, 1, V), new caches)."""
    batch = {"tokens": tokens} if cfg.embed_input else {"embeds": tokens}
    x = _embed_in(params, cfg, batch)
    positions = jnp.full((1, 1), cache_len, jnp.int32)
    x, aux, new_caches = _stack(cfg, params, x, ctx=ctx, positions=positions,
                                caches=caches, cache_len=cache_len,
                                mode="decode")
    return _logits(params, cfg, x), new_caches
