"""Mixture-of-Experts FFN: top-2 (GShard-style) routing with per-group
capacity [arXiv:2006.16668], group = batch row, so dispatch gathers never
cross the data-parallel shard boundary (DESIGN.md sec. 4).

Sharding: experts are TP-sharded on d_ff by default ("tp" rule works for
any expert count); when n_experts divides the model axis the launcher flips
the rule table to expert-parallel ("ep": expert axis -> model), which is one
of the §Perf hillclimb knobs.

Arctic's dense-residual variant (ATTN_MOE_DENSE) adds a parallel dense
SwiGLU branch: out = mlp(x) + moe(x).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding_rules import shard

Array = jax.Array


def moe_init(key, cfg, dtype=jnp.float32):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff * cfg.n_layers)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * s_in).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * s_in).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) * s_out).astype(dtype),
    }


def moe(params, x: Array, cfg):
    """Top-k capacity-based MoE.  x (B, S, d) -> (y (B, S, d), aux_loss)."""
    B, S, d = x.shape
    E = cfg.n_experts
    k = cfg.top_k_experts
    C = max(1, min(S, int(math.ceil(S * k * cfg.capacity_factor / E))))
    cd = x.dtype

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # (B,S,E)
    top_v, top_i = jax.lax.top_k(probs, k)                    # (B,S,k)
    top_v = top_v / jnp.maximum(top_v.sum(-1, keepdims=True), 1e-9)

    # gate matrix: prob mass for selected (token, expert) pairs else 0
    sel = jax.nn.one_hot(top_i, E, dtype=jnp.float32)         # (B,S,k,E)
    gates = jnp.einsum("bske,bsk->bse", sel, top_v)           # (B,S,E)

    # load-balance aux loss (Switch): E * mean_e(frac_tokens * mean_prob)
    me = probs.mean(axis=(0, 1))
    ce = sel.sum(2).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # per-(group, expert) capacity-C token selection
    gv, gi = jax.lax.top_k(jnp.swapaxes(gates, 1, 2), C)      # (B,E,C)
    live = gv > 0.0

    xe = jnp.take_along_axis(
        x[:, None, :, :], gi[..., None], axis=2
    )                                                         # (B,E,C,d)
    xe = shard(xe, "batch", "expert", None, None)
    # ZeRO-3: gather fsdp-sharded expert weights at use (§Perf iter. 6)
    wg = shard(params["wg"].astype(cd), "expert", None, "tp")
    wu = shard(params["wu"].astype(cd), "expert", None, "tp")
    wd = shard(params["wd"].astype(cd), "expert", "tp", None)
    h_g = shard(jnp.einsum("becd,edf->becf", xe, wg),
                "batch", "expert", None, "tp")
    h_u = shard(jnp.einsum("becd,edf->becf", xe, wu),
                "batch", "expert", None, "tp")
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(cd) * h_u
    h = shard(h, "batch", "expert", None, "tp")
    ye = jnp.einsum("becf,efd->becd", h, wd)
    ye = shard(ye, "batch", "expert", None, None)
    ye = ye * (gv * live)[..., None].astype(cd)

    # scatter-add back within each group
    def combine(y_b, gi_b):                                   # (E,C,d),(E,C)
        return jnp.zeros((S, d), cd).at[gi_b.reshape(-1)].add(
            y_b.reshape(-1, d))

    y = jax.vmap(combine)(ye, gi)
    return shard(y, "batch", "seq", None), aux
