"""Mamba-2 block: state-space duality (SSD) chunked scan [arXiv:2405.21060].

Implements the three execution paths the shapes require:
  * `ssd_chunked`   — training/prefill: chunked quadratic-intra +
                      linear-inter scan (Listing 1 of the paper, jnp form);
  * `ssd_sequential`— tiny-shape oracle for tests;
  * `mamba_decode`  — O(1)-per-token recurrent step for decode_32k/long_500k.

Head (`nheads`) axis is sharded over the TP mesh axis; the (cl x cl)
intra-chunk decay tensor is the memory hot spot and is what the head
sharding keeps per-device-small (DESIGN.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm
from repro.models.sharding_rules import shard

Array = jax.Array


def mamba_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    nh = cfg.ssm_heads
    w = cfg.conv_width
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * N
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + nh), dtype),
        "conv_w": (jax.random.normal(ks[1], (w, conv_ch), jnp.float32)
                   * (1.0 / math.sqrt(w))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype,
                               scale=1.0 / math.sqrt(di * cfg.n_layers)),
    }


def _causal_conv(x: Array, w: Array, b: Array, state: Array | None = None):
    """Depthwise causal conv along seq.  x (B, L, C), w (W, C).

    With `state` (B, W-1, C) runs in streaming mode and also returns the
    updated state (last W-1 inputs)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = xp[:, -(W - 1):, :]
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(W - 1):, :]
    out = jnp.zeros_like(x)
    for i in range(W):  # static unroll, W ~ 4
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)).astype(x.dtype), new_state


def _segsum(a: Array) -> Array:
    """Stable segment-sum: S[..., l, s] = sum_{j=s+1..l} a[..., j] (l >= s).

    a: (..., cl) -> (..., cl, cl) with -inf above the diagonal."""
    cl = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    S = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((cl, cl), bool), k=0)
    return jnp.where(mask, S, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state: Array | None = None):
    """SSD scan.  x (B,L,H,P), dt (B,L,H), A (H,), Bm/Cm (B,L,N).

    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    cl = chunk

    xc = x.reshape(Bsz, nc, cl, H, P)
    dtc = dt.reshape(Bsz, nc, cl, H)
    Bc = Bm.reshape(Bsz, nc, cl, N)
    Cc = Cm.reshape(Bsz, nc, cl, N)

    a = dtc * A[None, None, None, :]                  # (B,nc,cl,H)
    a = shard(a, "batch", None, None, "tp")
    A_cum = jnp.cumsum(a, axis=2)                     # (B,nc,cl,H)

    # ---- intra-chunk (quadratic, per chunk) ----
    Lmat = jnp.exp(_segsum(jnp.moveaxis(a, -1, 2)))   # (B,nc,H,cl,cl)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc,
                        preferred_element_type=jnp.float32)
    dtx = xc * dtc[..., None]                         # (B,nc,cl,H,P)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp", scores, Lmat,
                        dtx, preferred_element_type=jnp.float32)

    # ---- chunk states ----
    decay_end = jnp.exp(A_cum[:, :, -1:, :] - A_cum)  # (B,nc,cl,H)
    S_c = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_end * dtc, xc,
                     preferred_element_type=jnp.float32)

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(A_cum[:, :, -1, :])         # (B,nc,H)

    def step(s_prev, inp):
        dec, s_c = inp                                # (B,H), (B,H,P,N)
        s_new = s_prev * dec[..., None, None] + s_c
        return s_new, s_prev

    s0 = (init_state if init_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))
    final_state, s_prevs = jax.lax.scan(
        step,
        s0.astype(jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)             # (B,nc,H,P,N)

    # ---- off-diagonal contribution ----
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, s_prevs,
                       jnp.exp(A_cum), preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y.astype(x.dtype), final_state


def ssd_sequential(x, dt, A, Bm, Cm, init_state=None):
    """O(L) sequential oracle (tests only)."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    s0 = (init_state if init_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def step(s, inp):
        xt, dtt, Bt, Ct = inp
        dec = jnp.exp(dtt * A)                        # (B,H)
        s = s * dec[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhpn,bn->bhp", s, Ct)
        return s, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    s, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s


def mamba_block(params, x, cfg, *, state=None, conv_state=None,
                sequential: bool = False):
    """Full Mamba-2 block.

    Train/prefill: state/conv_state None -> chunked SSD, returns
    (y, (ssm_state, conv_state)).
    Decode: pass both states, x has L==1, recurrent path.
    """
    Bsz, L, d = x.shape
    di = cfg.d_inner
    N = cfg.ssm_state
    nh = cfg.ssm_heads
    P = cfg.ssm_head_dim
    cd = x.dtype

    in_proj = shard(params["in_proj"].astype(cd), None, "tp")  # ZeRO-3
    zxbcdt = x @ in_proj
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xBC, new_conv = _causal_conv(xBC, params["conv_w"].astype(cd),
                                 params["conv_b"].astype(cd), conv_state)
    x_in, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    x_in = x_in.reshape(Bsz, L, nh, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])

    if state is not None and L == 1:
        # ---- recurrent decode ----
        dt1 = dt[:, 0]                                # (B,H)
        dec = jnp.exp(dt1 * A[None, :])
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, x_in[:, 0].astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32))
        new_state = state * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", new_state,
                       Cm[:, 0].astype(jnp.float32))[:, None]
    else:
        fn = ssd_sequential if sequential else ssd_chunked
        if sequential:
            y, new_state = fn(x_in, dt.astype(jnp.float32), A,
                              Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                              init_state=state)
        else:
            y, new_state = fn(x_in, dt.astype(jnp.float32), A,
                              Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                              cfg.ssm_chunk, init_state=state)

    y = y.astype(jnp.float32) + x_in.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(Bsz, L, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y * jax.nn.silu(z.astype(jnp.float32))
    g = rmsnorm({"scale": params["norm_scale"]}, g.astype(cd), cfg.norm_eps)
    out = g @ shard(params["out_proj"].astype(cd), "tp", None)
    return shard(out, "batch", "seq", None), (new_state, new_conv)
