"""Neural substrate layers: RMSNorm, RoPE, memory-efficient GQA attention,
SwiGLU MLP, embeddings.

All matmuls compute in bf16 with fp32 accumulation (preferred_element_type);
softmax statistics are fp32.  Attention is KV-chunked with an online softmax
(flash-attention schedule in pure JAX): the score matrix never exceeds
(q_chunk x kv_chunk), which is what keeps the 32k-prefill and 32k-decode
dry-run memory analyses sane.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models.sharding_rules import shard

Array = jax.Array
NEG_INF = -1e30


def _dot(a, b, *, prec=None):
    return jnp.einsum(a, b) if isinstance(a, str) else None


# ---------------------------------------------------------------------------
# init helpers (pure: usable under jax.eval_shape for the dry-run)
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, D), positions (..., S) -> same shape."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# memory-efficient attention (online softmax over KV chunks)
# ---------------------------------------------------------------------------


def _attn_q_block(
    q5: Array,            # (B, Bq, KH, G, Dh)
    k: Array,             # (B, T, KH, Dh)
    v: Array,             # (B, T, KH, Dh)
    q_pos: Array,         # (Bq,) absolute positions of this q block
    kv_len: Array | None, # scalar live cache length (decode) or None
    *,
    causal: bool,
    kv_chunk: int,
):
    B, Bq, KH, G, Dh = q5.shape
    T = k.shape[1]
    n_chunks = T // kv_chunk
    scale = 1.0 / math.sqrt(Dh)

    def step(carry, idx):
        m, l, acc = carry
        start = idx * kv_chunk
        kc = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q5, kc, preferred_element_type=jnp.float32
        ) * scale
        kv_pos = start + jnp.arange(kv_chunk)
        mask = jnp.ones((Bq, kv_chunk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if kv_len is not None:
            mask &= (kv_pos < kv_len)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Bq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Bq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (B, KH, G, Bq, Dh) -> (B, Bq, KH*G, Dh)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Bq, KH * G, Dh)
    return out


def attention(
    q: Array,             # (B, S, H, Dh)
    k: Array,             # (B, T, KH, Dh)
    v: Array,             # (B, T, KH, Dh)
    *,
    causal: bool = True,
    q_offset: int | Array = 0,
    kv_len: Array | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Array:
    """GQA attention, O(q_chunk * kv_chunk) score memory.

    `q_offset` is the absolute position of q[0] (decode: current length-1);
    `kv_len` masks a preallocated cache to its live prefix.
    """
    B, S, H, Dh = q.shape
    T = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)

    # pad both sequence dims to chunk multiples; padded kv slots are masked
    # via kv_len (dropping the tail silently was a real truncation bug)
    kv_pad = (-T) % kv_chunk
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    if kv_len is None and (kv_pad or not causal):
        kv_len = jnp.int32(T)
    q_pad = (-S) % q_chunk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    Sp = S + q_pad
    n_q = Sp // q_chunk
    q5 = q.reshape(B, Sp, KH, G, Dh)

    if n_q <= 1:
        pos = q_offset + jnp.arange(Sp)
        out = _attn_q_block(q5, k, v, pos, kv_len,
                            causal=causal, kv_chunk=kv_chunk)
        return out[:, :S]

    def one_block(i):
        qb = jax.lax.dynamic_slice_in_dim(q5, i * q_chunk, q_chunk, axis=1)
        pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        return _attn_q_block(qb, k, v, pos, kv_len,
                             causal=causal, kv_chunk=kv_chunk)

    blocks = jax.lax.map(one_block, jnp.arange(n_q))  # (n_q, B, q_chunk, ...)
    out = jnp.transpose(blocks, (1, 0, 2, 3, 4)).reshape(B, Sp, H, Dh)
    return out[:, :S]


# ---------------------------------------------------------------------------
# int8 KV cache quantization (per-token, per-head absmax scales)
# ---------------------------------------------------------------------------


def quantize_kv(x: Array):
    """x (B, S, KH, Dh) -> (codes int8, scales bf16 (B, S, KH))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.bfloat16)


def dequantize_kv(codes: Array, scale: Array) -> Array:
    return (codes.astype(jnp.bfloat16)
            * scale.astype(jnp.bfloat16)[..., None])


# ---------------------------------------------------------------------------
# attention block (params + forward, self- and cross-)
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ko, (cfg.n_heads * hd, d), dtype,
                         scale=1.0 / math.sqrt(cfg.n_heads * hd * cfg.n_layers)),
    }


def qkv_proj(params, x: Array, cfg, positions: Array | None):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = x.dtype
    # ZeRO-3: gather the fsdp-sharded weights at use (constraining the
    # weight to be data-replicated makes GSPMD all-gather the small bf16
    # weight instead of all-reducing the giant fp32 output partials —
    # EXPERIMENTS.md §Perf iteration 6)
    wq = shard(params["wq"].astype(cd), None, "tp")
    wk = shard(params["wk"].astype(cd), None, "tp")
    wv = shard(params["wv"].astype(cd), None, "tp")
    q = (x @ wq).reshape(B, S, cfg.n_heads, hd)
    k = (x @ wk).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ wv).reshape(B, S, cfg.n_kv_heads, hd)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "tp", None)
    k = shard(k, "batch", "seq", "kv", None)
    v = shard(v, "batch", "seq", "kv", None)
    return q, k, v


def self_attention_block(params, x, cfg, *, positions, kv_cache=None,
                         cache_len=None):
    """Self attention.  Train/prefill: full sequence (returns new kv for the
    cache).  Decode: S==1 with a preallocated (B, T, KH, Dh) cache —
    bf16 (ck, cv) or int8 (ck, cv, k_scale, v_scale)."""
    q, k, v = qkv_proj(params, x, cfg, positions)
    if kv_cache is None:
        out = attention(q, k, v, causal=True,
                        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
        new_kv = (k, v)
    elif len(kv_cache) == 4:
        # int8 cache (§Perf iteration 8): write the quantized new token,
        # dequantize at the attention read (fused — the HBM traffic is the
        # int8 codes + per-(token, head) scales, ~2x less than bf16)
        ck, cv, ks_c, vs_c = kv_cache
        k_codes, k_scale = quantize_kv(k)
        v_codes, v_scale = quantize_kv(v)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k_codes, cache_len,
                                                 axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v_codes, cache_len,
                                                 axis=1)
        ks_c = jax.lax.dynamic_update_slice_in_dim(ks_c, k_scale, cache_len,
                                                   axis=1)
        vs_c = jax.lax.dynamic_update_slice_in_dim(vs_c, v_scale, cache_len,
                                                   axis=1)
        kd = dequantize_kv(ck, ks_c)
        vd = dequantize_kv(cv, vs_c)
        out = attention(q, kd, vd, causal=False, q_offset=cache_len,
                        kv_len=cache_len + 1, q_chunk=1,
                        kv_chunk=kd.shape[1])
        new_kv = (ck, cv, ks_c, vs_c)
        B, S, H, Dh = out.shape
        wo = shard(params["wo"].astype(x.dtype), "tp", None)
        y = out.reshape(B, S, H * Dh).astype(x.dtype) @ wo
        return shard(y, "batch", "seq", None), new_kv
    else:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_len, axis=1)
        # decode runs UNCHUNKED (kv_chunk = full T): scores for one query
        # token are tiny, and with the cache time axis sharded over 'model'
        # the softmax stats + p@V partials reduce with small all-reduces
        # instead of gathering cache chunks (flash-decode layout; §Perf
        # iteration 2 — the chunked scan forced a per-chunk cross-device
        # gather of the time-sharded cache)
        out = attention(q, ck, cv, causal=False, q_offset=cache_len,
                        kv_len=cache_len + 1,
                        q_chunk=1, kv_chunk=ck.shape[1])
        new_kv = (ck, cv)
    B, S, H, Dh = out.shape
    wo = shard(params["wo"].astype(x.dtype), "tp", None)
    y = out.reshape(B, S, H * Dh).astype(x.dtype) @ wo
    return shard(y, "batch", "seq", None), new_kv


def cross_attention_block(params, x, ctx, cfg):
    """Cross-attention to a precomputed (image) context (vlm stub)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = x.dtype
    q = (x @ params["wq"].astype(cd)).reshape(B, S, cfg.n_heads, hd)
    k = (ctx @ params["wk"].astype(cd)).reshape(B, ctx.shape[1],
                                                cfg.n_kv_heads, hd)
    v = (ctx @ params["wv"].astype(cd)).reshape(B, ctx.shape[1],
                                                cfg.n_kv_heads, hd)
    out = attention(q, k, v, causal=False,
                    q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    y = out.reshape(B, S, -1).astype(x.dtype) @ params["wo"].astype(cd)
    return shard(y, "batch", "seq", None)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, n_layers: int, dtype=jnp.float32):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, (d, d_ff), dtype),
        "wu": dense_init(ku, (d, d_ff), dtype),
        "wd": dense_init(kd, (d_ff, d), dtype,
                         scale=1.0 / math.sqrt(d_ff * n_layers)),
    }


def mlp(params, x: Array) -> Array:
    cd = x.dtype
    wg = shard(params["wg"].astype(cd), None, "tp")   # ZeRO-3 gather
    wu = shard(params["wu"].astype(cd), None, "tp")
    wd = shard(params["wd"].astype(cd), "tp", None)
    g = x @ wg
    u = x @ wu
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    h = shard(h, "batch", "seq", "tp")
    return shard(h @ wd, "batch", "seq", None)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": dense_init(key, (vocab, d), dtype, scale=0.02)}


def embed(params, tokens: Array) -> Array:
    return shard(params["table"][tokens], "batch", "seq", None)


def unembed(params, x: Array) -> Array:
    table = shard(params["table"].astype(x.dtype), "tp", None)  # ZeRO-3
    logits = jnp.einsum(
        "bsd,vd->bsv", x, table,
        preferred_element_type=jnp.float32,
    )
    return shard(logits, "batch", "seq", "tp")
