"""Model configuration for the assigned architecture pool.

One frozen dataclass covers dense / MoE / SSM / hybrid / audio / vlm
families.  Heterogeneous stacks (jamba, vision) are expressed as a
``block_pattern``: the layer stack is ``n_layers / len(pattern)`` repeats of
the pattern, and the trainer scans over pattern repeats (so each distinct
layer TYPE is stacked and scanned — static shapes, one compile per type).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

# layer kinds usable inside a block pattern
ATTN = "attn"          # self-attention + dense MLP
ATTN_MOE = "attn_moe"  # self-attention + MoE FFN
ATTN_MOE_DENSE = "attn_moe_dense"  # arctic: attention + (dense MLP || MoE)
MAMBA = "mamba"        # Mamba-2 SSD block + dense MLP
MAMBA_MOE = "mamba_moe"
CROSS = "cross"        # self-attn + cross-attn(image) + dense MLP


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: tuple[str, ...] = (ATTN,)
    head_dim: int = 0             # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- modality stubs ---
    embed_input: bool = True      # False: input_specs provides embeddings
    vision_tokens: int = 0        # >0: cross-attn context length (vlm stub)
    # --- common ---
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- runtime knobs (overridable per run) ---
    remat: bool = True
    scan_layers: bool = True   # False: unroll (dry-run cost probes)
    kv_cache_dtype: str = "bf16"   # "int8": quantized KV cache (§Perf it.8)
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    moe_sharding: str = "tp"      # "tp": d_ff over model axis; "ep": experts
    seq_shard_longctx: bool = True

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, (
            self.name, self.n_layers, self.block_pattern)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def d_inner(self) -> int:      # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return all(k in (MAMBA, MAMBA_MOE) for k in self.block_pattern)

    @property
    def has_attention(self) -> bool:
        return any(k.startswith(("attn", "cross")) for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM or hybrid w/ O(1)-ish KV)."""
        n_attn = sum(1 for k in self.block_pattern if not k.startswith("mamba"))
        return n_attn == 0 or (n_attn / len(self.block_pattern)) <= 0.25

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline terms)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
        attn = qkv + (self.n_heads * hd) * d
        mlp = 3 * d * dff
        moe = self.n_experts * 3 * d * dff
        di = self.d_inner
        nh = self.ssm_heads if self.ssm_state else 0
        # in_proj (z,x,B,C,dt) + out_proj + conv + dt/A/D
        mamba = (
            d * (2 * di + 2 * self.ssm_state + nh)
            + di * d
            + self.conv_width * (di + 2 * self.ssm_state)
            + 3 * nh
        ) if self.ssm_state else 0
        total = 0
        for kind in self.block_pattern:
            if kind == ATTN:
                total += attn + mlp
            elif kind == ATTN_MOE:
                total += attn + moe + d * self.n_experts
            elif kind == ATTN_MOE_DENSE:
                total += attn + moe + mlp + d * self.n_experts
            elif kind == MAMBA:
                total += mamba + mlp
            elif kind == MAMBA_MOE:
                total += mamba + moe + d * self.n_experts
            elif kind == CROSS:
                total += 2 * attn + mlp
        total *= self.n_repeats
        total += v * d * (1 if self.tie_embeddings else 2)   # embed + head
        total += self.n_layers * 2 * d + d                   # norms
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        expert = 3 * d * dff
        dead = (self.n_experts - self.top_k_experts) * expert
        n_moe_layers = sum(
            1 for k in self.block_pattern if k.endswith("moe") or k == ATTN_MOE_DENSE
        ) * self.n_repeats
        return self.param_count() - n_moe_layers * dead


def validate(cfg: ModelConfig) -> None:
    assert cfg.d_model % cfg.n_heads == 0 or cfg.head_dim
    if cfg.n_experts:
        assert cfg.top_k_experts > 0
    if any(k.startswith("mamba") for k in cfg.block_pattern):
        assert cfg.ssm_state > 0
    if CROSS in cfg.block_pattern:
        assert cfg.vision_tokens > 0
