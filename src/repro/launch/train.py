"""End-to-end training driver (deliverable b's main entry point).

    PYTHONPATH=src python -m repro.launch.train --arch spadas_trajlm \
        --steps 200 --batch 8 --seq 256 [--mesh none|test|single|multi]

Wires together: Spadas data curation -> token pipeline -> sharded train
step -> watchdog -> async checkpointing -> (simulated) elastic restart.
On this CPU container use --mesh none/test; the production meshes lower
the same code on 256/512 devices (see dryrun.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import ckpt as ckpt_lib
from repro.data import synthetic, tokens as tok_lib
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sh
from repro.models import sharding_rules
from repro.runtime.straggler import StepWatchdog, StragglerEvent, WatchdogConfig
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def build_pipeline(cfg, args):
    if args.arch == "spadas_trajlm":
        import math
        from repro.data import discovery
        # grid resolution must match the vocab: 4^theta cells + specials
        theta = int(math.log(cfg.vocab_size - 64, 4))
        lake = synthetic.trajectory_repository(args.lake_size, seed=0)
        exemplar = lake[0]
        selected, repo, info = discovery.curate(
            lake, exemplar, k=min(64, args.lake_size), theta=theta)
        print(f"[train] Spadas curated {len(selected)} shards "
              f"(deduped {info['deduped_away']})")
        return discovery.pipeline_from_selection(
            lake, selected, repo, theta=theta, seq_len=args.seq,
            batch=args.batch)
    docs = tok_lib.synthetic_corpus(2048, cfg.vocab_size, seed=0)
    return tok_lib.TokenPipeline(docs, args.seq, args.batch, seed=0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="spadas_trajlm")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test reduced config")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "test", "single", "multi"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lake-size", type=int, default=128)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    cfg = dataclasses.replace(cfg, remat=False) if args.seq <= 512 else cfg
    opt_cfg = opt_lib.OptConfig(lr=args.lr, warmup_steps=20)

    mesh = None
    if args.mesh == "test":
        mesh = mesh_lib.make_test_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")

    pipe = build_pipeline(cfg, args)
    key = jax.random.PRNGKey(0)
    state = ts.init_train_state(key, cfg, opt_cfg,
                                compress=args.compress_grads)
    step_fn = ts.make_train_step(cfg, opt_cfg, compress=args.compress_grads)

    start = 0
    ckpt_dir = Path(args.ckpt_dir) / cfg.name
    if args.resume and ckpt_lib.latest_step(ckpt_dir) is not None:
        state, extra = ckpt_lib.restore(ckpt_dir, state)
        pipe.state = tok_lib.PipelineState.from_dict(extra["pipeline"])
        start = int(extra["step"])
        print(f"[train] resumed from step {start}")

    if mesh is not None:
        sharding_rules.set_mesh(mesh)
        p_shard = sh.param_shardings(
            jax.eval_shape(lambda: state.params), mesh)
        with mesh:
            state = state._replace(
                params=jax.tree.map(jax.device_put, state.params, p_shard))
        # pin gradient shardings to the params (EXPERIMENTS.md §Perf iter. 4)
        step_fn = ts.make_train_step(cfg, opt_cfg,
                                     compress=args.compress_grads,
                                     param_shardings=p_shard)
        jit_ctx = mesh
    else:
        import contextlib
        jit_ctx = contextlib.nullcontext()

    step_fn = jax.jit(step_fn, donate_argnums=(0,))
    saver = ckpt_lib.AsyncSaver()
    watchdog = StepWatchdog(WatchdogConfig())
    losses = []
    with jit_ctx:
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, pipe.next_batch())
            watchdog.start()
            try:
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                watchdog.stop()
            except StragglerEvent as e:
                print(f"[train] straggler detected: {e}; checkpoint + "
                      "remesh would trigger here")
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                print(f"[train] step {step+1} loss={losses[-1]:.4f} "
                      f"ce={float(metrics['ce']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                saver.save(ckpt_dir, step + 1, state,
                           extra={"step": step + 1,
                                  "pipeline": pipe.state.as_dict()})
    saver.wait()
    print(f"[train] done. first loss {losses[0]:.4f} -> last "
          f"{losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
