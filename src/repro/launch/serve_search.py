"""Search serving front-end: request queue + continuous micro-batching.

    PYTHONPATH=src python -m repro.launch.serve_search [--requests 256 ...]
    REPRO_HOST_DEVICES=8 PYTHONPATH=src \
        python -m repro.launch.serve_search --sharded   # data-sharded engine
    REPRO_HOST_DEVICES=8 PYTHONPATH=src \
        python -m repro.launch.serve_search --replicas 2   # 2 x 4 replica mesh

The production shape for the paper's *online* multi-granularity search:
clients submit single queries (mixed types — RangeS / top-k IA / top-k
GBO / ApproHaus / ExactHaus / joinable overlap & coverage at dataset
granularity, RangeP / NNP at point granularity, plus two-stage
dataset→point and dataset→dataset PIPELINES) into a queue; a
dispatcher thread drains the queue continuously and hands the WHOLE mixed
drain to ``QueryEngine.search`` as one declarative batch.  The engine's
planner does the grouping the server used to do by hand — compatible
requests (same op, same static params) share one device dispatch, cache
hits short-circuit per row, and pipeline stage-1 queries ride the same
groups as standalone queries.  Under load the batch grows toward
`max_batch` on its own — classic continuous batching — so throughput
scales with traffic while the executable cache keeps compile cost
amortized across the bucket ladder.

``submit(op=..., **payload)`` is kept as a thin shim that constructs the
:class:`~repro.engine.query.Query` / :class:`~repro.engine.query.Pipeline`
at submission time; clients holding ready-made spec objects can enqueue
them directly with ``submit_query``.

LIVE serving (``--live`` / ``SearchServer(live=...)``): the server fronts
a :class:`~repro.engine.live.LiveRepository` and accepts a MUTATION lane
on the same queue — ``submit_mutation("ingest"|"delete"|"replace", ...)``
enqueues next to queries, so mutations take effect exactly at their
submission point in the stream: the dispatcher splits each drain into
query segments at mutation boundaries, serves each segment as one
declarative batch, and applies the mutations in order between segments.
Every query answered after a mutation sees the post-mutation epoch
(bit-identical to a cold engine over the frozen equivalent — asserted in
tests/test_serve_search.py); in-flight segments keep the consistent
pre-mutation snapshot.

The dispatcher's notion of time is injectable (``clock=``): latency
accounting and the static drain deadline read ``self.clock()``, so tests
drive deterministic virtual time instead of sleeping.
"""
from __future__ import annotations

import argparse
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any

from repro import hostdev

# before the first jax import: let --sharded shard over N forced host
# devices on CPU-only machines (no-op unless REPRO_HOST_DEVICES is set)
hostdev.apply()

import jax
import numpy as np

from repro.core.repo_index import Repository
from repro.engine import Pipeline, Query, QueryEngine, SearchResult

# ops the submit() shim knows how to wrap into a Query/Pipeline; the
# engine's planner handles the grouping, so ANY mix of these may share one
# queue drain (and pipeline stage-1 rows share dispatches with standalone
# queries of the same op)
OPS = (
    "range_search", "topk_ia", "topk_gbo", "topk_hausdorff_approx",
    "topk_hausdorff", "range_points", "nnp", "topk_overlap",
    "topk_coverage", "pipeline",
)


def _to_query(op: str, payload: dict):
    """The submit() shim: legacy (op, payload) -> declarative spec."""
    if op == "pipeline":
        dataset = payload["dataset"]
        point = payload["point"]
        return Pipeline(
            dataset_stage=(dataset if isinstance(dataset, Query)
                           else _to_query(dataset["op"], dataset)),
            point_stage=(point if isinstance(point, Query)
                         else _to_query(point["op"], point)))
    if op == "range_search":
        return Query(op=op, r_lo=payload["r_lo"], r_hi=payload["r_hi"])
    if op == "topk_ia":
        # legacy payload naming: q_lo/q_hi; pipeline specs may say r_lo
        lo = payload.get("q_lo", payload.get("r_lo"))
        hi = payload.get("q_hi", payload.get("r_hi"))
        return Query(op=op, r_lo=lo, r_hi=hi, k=payload["k"])
    if op == "topk_gbo":
        return Query(op=op, q_sig=payload["q_sig"], k=payload["k"])
    if op == "topk_hausdorff_approx":
        return Query(op=op, q=payload["q"], k=payload["k"],
                     eps=payload["eps"])
    if op == "topk_hausdorff":
        return Query(op=op, q=payload["q"], k=payload["k"])
    if op == "range_points":
        return Query(op=op, ds_id=payload.get("ds_id"),
                     r_lo=payload["r_lo"], r_hi=payload["r_hi"])
    if op == "nnp":
        return Query(op=op, ds_id=payload.get("ds_id"), q=payload["q"])
    if op == "topk_overlap" or op == "topk_coverage":
        return Query(op=op, q=payload["q"], k=payload["k"])
    raise ValueError(f"unknown op {op!r}; serving ops: {OPS}")


def _legacy_result(res: SearchResult):
    """Shape a SearchResult like the pre-redesign per-op responses, so
    existing clients keep unpacking what they always unpacked.  Pipeline
    responses are new: they hand back the full SearchResult (stage-2
    rows + ``extras['stage1']``)."""
    if res.op == "range_search" or res.op == "range_points":
        return res.mask
    if res.op == "topk_ia" or res.op == "topk_gbo":
        return (res.vals, res.ids)
    if res.op == "topk_hausdorff_approx":
        return (res.vals, res.ids, res.extras["eps_eff"])
    if res.op == "topk_hausdorff":
        return (res.vals, res.ids, res.stats)
    if res.op == "topk_overlap" or res.op == "topk_coverage":
        return (res.vals, res.ids, res.stats)
    if res.op == "nnp":
        return (res.vals, res.ids)
    return res                              # pipeline: the full result


#: mutation kinds the live lane accepts (LiveRepository methods)
MUTATION_OPS = ("ingest", "delete", "replace")


@dataclass
class Request:
    op: str
    query: Any                              # Query | Pipeline
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class Mutation:
    """One mutation riding the request queue: applied IN ORDER at its
    position in the stream (queries drained before it see the old epoch,
    queries after it the new one)."""
    op: str                                 # ingest | delete | replace
    ds_id: int | None = None
    points: Any = None
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class ServerStats:
    requests: int = 0
    batches: int = 0                        # dispatch groups planned
    batch_size_sum: int = 0
    latency_sum: float = 0.0
    latencies: list = field(default_factory=list)   # per-request seconds
    op_ewma: dict = field(default_factory=dict)     # op -> EWMA latency s
    mutations: int = 0                      # mutation-lane ops applied
    mutation_latency_sum: float = 0.0
    mutation_latencies: list = field(default_factory=list)

    #: same smoothing as EngineStats.EWMA_ALPHA — both feeds estimate
    #: "how long does one more batch of this op take" for the adaptive
    #: straggler window
    EWMA_ALPHA = 0.2

    @property
    def mean_batch(self) -> float:
        return self.batch_size_sum / max(self.batches, 1)

    @property
    def mean_latency_ms(self) -> float:
        return 1e3 * self.latency_sum / max(self.requests, 1)

    def record(self, op: str, seconds: float) -> None:
        """Book one answered request's submit->result latency."""
        self.requests += 1
        self.latency_sum += seconds
        self.latencies.append(seconds)
        prev = self.op_ewma.get(op)
        self.op_ewma[op] = (seconds if prev is None
                            else prev + self.EWMA_ALPHA * (seconds - prev))

    def record_mutation(self, seconds: float) -> None:
        """Book one applied mutation's submit->publish latency (kept out
        of the QUERY latency distribution: mutations are a different
        SLO)."""
        self.mutations += 1
        self.mutation_latency_sum += seconds
        self.mutation_latencies.append(seconds)

    @property
    def mean_mutation_ms(self) -> float:
        return 1e3 * self.mutation_latency_sum / max(self.mutations, 1)

    def percentile_ms(self, p: float) -> float:
        """p-th percentile of per-request latency, in ms (0 if empty)."""
        if not self.latencies:
            return 0.0
        return 1e3 * float(np.percentile(np.asarray(self.latencies), p))

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50.0)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99.0)


class SearchServer:
    """Continuous micro-batching dispatcher over a QueryEngine.

    Two batching policies:

    * **adaptive** (default) — queue-depth-driven: the dispatcher
      greedily takes every request ALREADY enqueued (no waiting while
      there is work to batch); when the queue runs dry it waits one
      straggler window, and every arrival renews that budget, so the
      batch keeps filling while traffic flows and ships the moment one
      full window passes with nothing new.  The window is
      ``min(max_wait, 0.5 x EWMA dispatch latency)`` of the ops in the
      partial batch (fed by :meth:`EngineStats.record_latency`): folding
      a straggler into this batch saves about one dispatch's EWMA, so
      waiting longer than a fraction of it costs more latency than it
      saves.  Under saturating load the windows renew until the batch
      fills; at low load a lone request waits at most one window —
      typically far less than the static ``max_wait`` deadline for
      cheap ops.  When the backlog is deeper than ``max_batch`` the
      drain bound itself scales with queue depth (up to
      ``OVERFILL x max_batch``): a deep queue means dispatch overhead
      dominates, so amortising it over a larger drain raises saturated
      throughput without hurting the (already queue-dominated) tail.
    * **static** (``adaptive=False``) — the seed policy: after the first
      request, keep blocking up to a fixed ``max_wait`` deadline while
      the batch fills.  Kept for A/B measurement
      (``bench_engine --serving`` and ``--static-window`` here).
    """

    #: adaptive drains may grow to this multiple of ``max_batch`` when
    #: the queue is already deeper than ``max_batch`` (bounds worst-case
    #: host memory for one drain at OVERFILL x max_batch requests)
    OVERFILL = 4

    def __init__(
        self,
        engine: QueryEngine | None = None,
        *,
        live=None,
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        adaptive: bool = True,
        clock=time.perf_counter,
    ):
        if engine is None:
            if live is None:
                raise ValueError("SearchServer needs an engine or a live "
                                 "repository")
            engine = live.engine
        elif live is not None and live.engine is not engine:
            raise ValueError("live.engine and engine disagree — pass one")
        self.engine = engine
        self.live = live
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.adaptive = adaptive
        self.clock = clock
        self.stats = ServerStats()
        self._queue: "queue.Queue[Request | Mutation | None]" = queue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._running = False
        # lazy 1-worker pool for the prepare stage of the NEXT mutation
        # run — overlapped with the current query segment (the segment
        # serves the immutable pre-mutation snapshot, so the concurrent
        # row builds are invisible to it)
        self._prep_pool: ThreadPoolExecutor | None = None
        self._segment_span = (0.0, 0.0)
        # first request seen past a mutation run: carried to the next
        # drain so a publish always lands at a drain TAIL and never
        # splits one query segment into two engine calls (stream order
        # is untouched — drain boundaries are free choices)
        self._carry: Request | Mutation | None = None

    # -- client API --------------------------------------------------------

    def submit(self, op: str, **payload: Any) -> Future:
        """Enqueue one query; returns a Future with the op's result.

        Thin shim: the legacy (op, **payload) call is converted to a
        declarative Query/Pipeline HERE (validation included), then
        enqueued like any other spec."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; serving ops: {OPS}")
        if not self._running:
            raise RuntimeError("server is not running (start() it first)")
        return self.submit_query(_to_query(op, payload), op=op)

    def submit_query(self, query, *, op: str | None = None) -> Future:
        """Enqueue a ready-made Query/Pipeline spec."""
        if not isinstance(query, (Query, Pipeline)):
            raise TypeError(f"submit_query takes Query/Pipeline, "
                            f"got {type(query)!r}")
        if not self._running:
            raise RuntimeError("server is not running (start() it first)")
        if op is None:
            op = "pipeline" if isinstance(query, Pipeline) else query.op
        req = Request(op, query, t_submit=self.clock())
        self._queue.put(req)
        if not self._running and not req.future.done():
            # lost the race with a concurrent stop(): its drain may have
            # already passed our request, so fail the future ourselves
            try:
                req.future.set_exception(
                    RuntimeError("server stopped before request ran"))
            except Exception:           # drain got there first
                pass
        return req.future

    def submit_mutation(self, op: str, *, ds_id: int | None = None,
                        points=None) -> Future:
        """Enqueue one live-repository mutation on the request queue.

        Returns a Future resolving to the slot id (ingest/replace) or
        None (delete) once the mutation is PUBLISHED — every query
        submitted after this call that drains behind it is answered at
        the post-mutation epoch."""
        if self.live is None:
            raise RuntimeError("mutation lane needs a live repository "
                               "(SearchServer(live=...))")
        if op not in MUTATION_OPS:
            raise ValueError(f"unknown mutation {op!r}; mutation ops: "
                             f"{MUTATION_OPS}")
        if not self._running:
            raise RuntimeError("server is not running (start() it first)")
        mut = Mutation(op, ds_id=ds_id, points=points,
                       t_submit=self.clock())
        self._queue.put(mut)
        return mut.future

    def start(self) -> "SearchServer":
        self._running = True
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        self._queue.put(None)          # wake the dispatcher
        self._thread.join(timeout=30)
        # fail anything still queued (or carried between drains) so no
        # client Future hangs forever
        if self._carry is not None and not self._carry.future.done():
            self._carry.future.set_exception(
                RuntimeError("server stopped before request ran"))
        self._carry = None
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("server stopped before request ran"))
        if self._prep_pool is not None:
            self._prep_pool.shutdown(wait=True)
            self._prep_pool = None

    # -- dispatcher --------------------------------------------------------

    def _straggler_window(self, batch: list[Request]) -> float:
        """Adaptive wait budget once the queue runs dry: half the EWMA
        dispatch latency of the ops already in the batch (capped by
        max_wait) — the break-even point between folding a straggler
        into this dispatch and shipping without it.  Before any latency
        has been measured, fall back to the static window."""
        ew = self.engine.stats.latency_ewma
        vals = [ew[r.op] for r in batch if r.op in ew]
        if not vals:
            vals = list(ew.values())
        if not vals:
            return self.max_wait
        return min(self.max_wait, 0.5 * max(vals))

    def _drain(self) -> list[Request]:
        """Block for the first request, then fill the batch —
        queue-depth-driven when adaptive (greedy takes, dry-queue
        straggler windows that renew on every arrival, and a drain
        bound that scales to OVERFILL x max_batch under deep backlog),
        fixed max_wait deadline up to max_batch when static (the seed
        policy).

        A drain closes at the first mutation->query transition (the
        query is carried to the next drain): each drain is then at most
        one query segment plus one tail run of mutations, so the
        per-segment planning/dispatch floor is paid once per drain —
        splitting a segment in two costs ~a full extra group floor,
        which under churn was most of the serving collapse."""
        if self._carry is not None:
            first = self._carry
            self._carry = None
        else:
            try:
                first = self._queue.get(timeout=0.1)
            except queue.Empty:
                return []
            if first is None:
                return []
        batch = [first]
        if self.adaptive:
            # depth-scaled bound: when the backlog already exceeds
            # max_batch, per-drain overhead (planning plus one engine
            # dispatch per group) dominates per-request work, so fold
            # up to OVERFILL x max_batch queued requests into this
            # drain.  The planner groups compatible rows into shared
            # dispatches and the bucket ladder pads row counts anyway,
            # so the larger drain amortises fixed costs without
            # triggering new compilation.
            limit = self.max_batch
            if self._queue.qsize() > self.max_batch:
                limit = self.OVERFILL * self.max_batch
            waited = False
            while len(batch) < limit:
                try:
                    req = self._queue.get_nowait()
                except queue.Empty:
                    if waited:
                        break
                    waited = True
                    try:
                        req = self._queue.get(
                            timeout=self._straggler_window(batch))
                    except queue.Empty:
                        break
                if req is None:
                    break
                if (isinstance(batch[-1], Mutation)
                        and not isinstance(req, Mutation)):
                    self._carry = req
                    break
                batch.append(req)
                # every arrival renews the straggler budget: the batch
                # keeps growing while traffic flows and ships the moment
                # one full window passes with no arrival (total wait is
                # bounded by max_batch renewals of <= max_wait each)
                waited = False
            # absorb a contiguous run of mutations sitting just past
            # the drain bound (the first non-mutation after them is
            # carried): their publish then rides THIS drain's tail and
            # their prepare overlaps THIS drain's query segment,
            # instead of opening the next drain with nothing to hide
            # the row builds under
            if not isinstance(batch[-1], Mutation) and self._carry is None:
                while True:
                    try:
                        req = self._queue.get_nowait()
                    except queue.Empty:
                        break
                    if req is None:
                        break
                    if isinstance(req, Mutation):
                        batch.append(req)
                        continue
                    self._carry = req
                    break
            return batch
        deadline = self.clock() + self.max_wait
        while len(batch) < self.max_batch:
            timeout = deadline - self.clock()
            try:
                req = self._queue.get(timeout=max(timeout, 0.0))
            except queue.Empty:
                break
            if req is None:
                break
            if (isinstance(batch[-1], Mutation)
                    and not isinstance(req, Mutation)):
                self._carry = req
                break
            batch.append(req)
        return batch

    def _prepare_ahead(self, muts: list[Mutation]):
        """Kick off the prepare stage (row builds + payload uploads) of
        the next mutation run on the side pool, to overlap with the
        query segment the dispatcher is about to serve.  Safe because
        prepare touches nothing a query observes, and the previous
        group's publish already happened (runs are consumed in stream
        order within one drain)."""
        if self._prep_pool is None:
            self._prep_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="mutation-prepare")

        def work():
            t0 = self.clock()
            group = self.live.prepare_group(
                [(m.op, m.ds_id, m.points) for m in muts])
            return group, t0, self.clock()

        return self._prep_pool.submit(work)

    def _publish_run(self, muts: list[Mutation], prepared) -> None:
        """Install one coalesced run of consecutive mutations: join (or
        run inline) its prepare, book the wall time it hid under the
        preceding query segment, publish the whole group as ONE epoch,
        and resolve every mutation future from the per-item outcomes."""
        if prepared is not None:
            group, tp0, tp1 = prepared.result()
            s0, s1 = self._segment_span
            self.engine.stats.prepare_overlap_seconds += max(
                0.0, min(tp1, s1) - max(tp0, s0))
        else:
            group = self.live.prepare_group(
                [(m.op, m.ds_id, m.points) for m in muts])
        try:
            outcomes = self.live.publish_group(group)
        except Exception as e:
            for m in muts:
                if not m.future.done():
                    m.future.set_exception(e)
            return
        now = self.clock()
        for m, out in zip(muts, outcomes):
            if isinstance(out, Exception):
                if not m.future.done():
                    m.future.set_exception(out)
            else:
                self.stats.record_mutation(now - m.t_submit)
                m.future.set_result(out)

    def _serve_segment(self, segment: list[Request]) -> None:
        """One declarative engine call for a (sub-)drain of queries: the
        planner groups compatible rows into shared dispatches and
        returns per-request results in input order."""
        from repro.engine import plan as plan_lib

        try:
            results = self.engine.search([r.query for r in segment])
        except Exception:
            # a poisoned row fails the whole mixed call; isolate by
            # re-running per request so every healthy future still
            # resolves and only the bad rows carry the exception
            # (the executable cache makes the re-runs cheap)
            results = []
            for r in segment:
                try:
                    results.append(self.engine.search([r.query])[0])
                except Exception as e:
                    results.append(e)
        now = self.clock()
        # dispatch-group count (stage-1 op groups + pipeline stage-2
        # groups), planned locally (host-only grouping) so a client
        # sharing the engine from another thread can't skew the
        # server's own metric; guarded — the accounting must never be
        # able to kill the dispatcher after results exist
        try:
            self.stats.batches += plan_lib.count_groups(
                [r.query for r in segment], self.engine.leaf_capacity)
        except Exception:
            self.stats.batches += 1
        self.stats.batch_size_sum += len(segment)
        for req, res in zip(segment, results):
            self.stats.record(req.op, now - req.t_submit)
            if isinstance(res, Exception):
                if not req.future.done():
                    req.future.set_exception(res)
            else:
                req.future.set_result(_legacy_result(res))

    def _loop(self) -> None:
        while self._running:
            batch = self._drain()
            if not batch:
                continue
            # partition the drain into alternating runs of queries and
            # mutations: each query run is one declarative engine call
            # against the epoch current at ITS point in the stream, and
            # each MUTATION run coalesces into one prepared group whose
            # prepare stage overlaps the query segment just before it
            # (late-bound dispatch keeps that segment on the immutable
            # pre-publish snapshot) and whose publish is a single epoch
            # at the run's stream position
            runs: list[tuple[bool, list]] = []
            for item in batch:
                is_mut = isinstance(item, Mutation)
                if runs and runs[-1][0] == is_mut:
                    runs[-1][1].append(item)
                else:
                    runs.append((is_mut, [item]))
            prepared = None
            for i, (is_mut, items) in enumerate(runs):
                if is_mut:
                    self._publish_run(items, prepared)
                    prepared = None
                    continue
                if i + 1 < len(runs) and runs[i + 1][0]:
                    prepared = self._prepare_ahead(runs[i + 1][1])
                t0 = self.clock()
                self._serve_segment(items)
                self._segment_span = (t0, self.clock())


# ---------------------------------------------------------------------------
# demo / load driver
# ---------------------------------------------------------------------------


def make_traffic(repo: Repository, datasets, n_requests: int, seed: int = 0,
                 mutate_every: int = 0):
    """Pre-build a mixed stream of (op, payload) requests covering all
    nine serving ops PLUS three pipeline kinds (top-k IA -> RangeP inside
    the winners, ApproHaus -> NNP inside the winners — the paper's
    dataset->point workflow — and top-k IA -> topk_overlap re-rank, the
    joinable dataset->dataset workflow), so a drain exercises genuinely
    heterogeneous declarative batches.  Payload construction (signatures
    etc.) happens here, off the submission path, like a real client would
    send ready-made queries.

    ``mutate_every > 0`` adds a MUTATION LANE for live serving: every
    mutate_every-th stream position becomes an ingest / delete / replace
    (round-robin) with a SAFE id discipline — deletes only ever target
    the reserved ids [0, n_ds//4), each at most once; replaces rotate
    over [n_ds//4, n_ds//2) (always live); ingests are fresh jittered
    copies, so they only ever land in freed or new slots.  Point-query
    ds_ids then avoid the delete-reserved range, so every query in the
    stream is valid whenever it drains relative to the mutations."""
    from repro.core import zorder

    rng = np.random.default_rng(seed)
    n_ds = len(datasets)
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
    del_pool = list(range(n_ds // 4)) if mutate_every else []
    rep_pool = list(range(n_ds // 4, n_ds // 2)) if mutate_every else []

    def q_id():
        # with a mutation lane, never reference a deletable id
        if mutate_every and n_ds // 4 < n_ds:
            return int(rng.integers(n_ds // 4, n_ds))
        return int(rng.integers(n_ds))

    def jittered():
        base = datasets[int(rng.integers(n_ds))]
        return (base + rng.normal(0, 0.5, base.shape)).astype(np.float32)

    out = []
    n_mut = 0
    for i in range(n_requests):
        if mutate_every and i and i % mutate_every == 0:
            kind = n_mut % 3
            n_mut += 1
            if kind == 1 and del_pool:
                out.append(("delete", dict(ds_id=del_pool.pop(0))))
            elif kind == 2 and rep_pool:
                sid = rep_pool[n_mut % len(rep_pool)]
                out.append(("replace", dict(ds_id=sid, points=jittered())))
            else:
                out.append(("ingest", dict(points=jittered())))
            continue
        c = rng.uniform(20, 80, 2).astype(np.float32)
        lo, hi = c - 2.0, c + 2.0
        kind = i % 12
        if kind == 0:
            out.append(("range_search", dict(r_lo=lo, r_hi=hi)))
        elif kind == 1:
            out.append(("topk_ia", dict(q_lo=lo, q_hi=hi, k=5)))
        elif kind == 2:
            q = datasets[int(rng.integers(n_ds))]
            sig = np.asarray(zorder.signature(
                jax.numpy.asarray(q), jax.numpy.ones(len(q), bool),
                repo.space_lo, repo.space_hi, 5))
            out.append(("topk_gbo", dict(q_sig=sig, k=5)))
        elif kind == 3:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_hausdorff_approx", dict(q=q, k=5, eps=eps)))
        elif kind == 4:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_hausdorff", dict(q=q, k=5)))
        elif kind == 5:
            out.append(("range_points", dict(
                ds_id=q_id(), r_lo=lo, r_hi=hi)))
        elif kind == 6:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("nnp", dict(ds_id=q_id(), q=q)))
        elif kind == 7:
            # dataset->point pipeline: top-3 IA datasets, then RangeP
            # inside each winner (ids never leave the device)
            wide_lo, wide_hi = c - 10.0, c + 10.0
            out.append(("pipeline", dict(
                dataset=dict(op="topk_ia", r_lo=wide_lo, r_hi=wide_hi, k=3),
                point=dict(op="range_points", r_lo=lo, r_hi=hi))))
        elif kind == 8:
            q = datasets[int(rng.integers(n_ds))][:32]
            out.append(("pipeline", dict(
                dataset=dict(op="topk_hausdorff_approx", q=q, k=3, eps=eps),
                point=dict(op="nnp", q=q))))
        elif kind == 9:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_overlap", dict(q=q, k=5)))
        elif kind == 10:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_coverage", dict(q=q, k=5)))
        else:
            # dataset->dataset pipeline: top-5 IA winners re-ranked by
            # grid-cell overlap with the query set (id handoff on device)
            q = datasets[int(rng.integers(n_ds))][:64]
            wide_lo, wide_hi = c - 10.0, c + 10.0
            out.append(("pipeline", dict(
                dataset=dict(op="topk_ia", r_lo=wide_lo, r_hi=wide_hi, k=5),
                point=dict(op="topk_overlap", q=q, k=3))))
    return out


def main(argv=None):
    from repro.core.build import build_repository
    from repro.data import synthetic

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--datasets", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--static-window", action="store_true",
                    help="use the fixed max-wait batching window instead "
                         "of the queue-depth-driven adaptive policy")
    ap.add_argument("--sharded", action="store_true",
                    help="serve from a ShardedQueryEngine with the resident "
                         "repository sharded over a 1-D data mesh spanning "
                         "all local devices")
    ap.add_argument("--replicas", type=int, default=0, metavar="R",
                    help="serve from a ReplicatedQueryEngine over an R x D "
                         "(replica x data) mesh: the repository is sharded "
                         "over D devices per group and replicated across R "
                         "groups, each drain's rows split over the groups")
    ap.add_argument("--data-shards", type=int, default=None, metavar="D",
                    help="data-axis extent per replica group (default: all "
                         "remaining local devices / R)")
    ap.add_argument("--live", action="store_true",
                    help="serve from a mutable LiveRepository (composes "
                         "with --sharded/--replicas) and open the "
                         "mutation lane")
    ap.add_argument("--mutate-every", type=int, default=0, metavar="N",
                    help="with --live: make every N-th request of the "
                         "measured stream an ingest/delete/replace "
                         "mutation (0 = queries only)")
    args = ap.parse_args(argv)
    if args.mutate_every and not args.live:
        ap.error("--mutate-every requires --live")

    lake = synthetic.trajectory_repository(args.datasets, seed=0)
    live = None
    if args.live:
        from repro.engine import LiveRepository, data_mesh, replica_mesh
        mesh = None
        if args.replicas:
            mesh = replica_mesh(args.replicas, args.data_shards)
        elif args.sharded:
            mesh = data_mesh()
        live = LiveRepository(lake, leaf_capacity=16, theta=5, mesh=mesh)
        engine = live.engine
        repo = live.repo
        print(f"[serve_search] live repository: {live.n_slots} slots "
              f"({len(live.live_ids)} live), "
              f"{'mesh ' + str(tuple(mesh.shape.values())) if mesh else 'local'}"
              f" dispatch, mutation lane open")
    else:
        repo, _ = build_repository(lake, leaf_capacity=16, theta=5)
    if args.live:
        pass
    elif args.replicas:
        from repro.engine.replicated import ReplicatedQueryEngine
        engine = ReplicatedQueryEngine(repo, n_replicas=args.replicas,
                                       n_data=args.data_shards)
        print(f"[serve_search] replicated engine: "
              f"{engine.dispatch.n_replicas} replica group(s) x "
              f"{engine.dispatch.n_shards} data shard(s) "
              f"({engine.dispatch.n_replicas * engine.dispatch.n_shards} "
              f"devices), {engine.dispatch.shard_slots} dataset slots "
              f"per shard")
    elif args.sharded:
        from repro.engine.sharded import ShardedQueryEngine
        engine = ShardedQueryEngine(repo)
        print(f"[serve_search] sharded engine: "
              f"{engine.dispatch.n_shards} shard(s) x "
              f"{engine.dispatch.shard_slots} dataset slots on the "
              f"'{engine.dispatch.axis}' axis")
    else:
        engine = QueryEngine(repo)
    server = SearchServer(engine, live=live, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms,
                          adaptive=not args.static_window)

    # warmup: run the QUERY traffic once, pre-filled BEFORE the
    # dispatcher starts so the warm drains are full-depth and aligned
    # with the measured burst — compiling exactly the bucket shapes AND
    # payload shapes (pipeline queries embed variable-length datasets,
    # which trace per length) the measurement will hit.  Query-only even
    # under --mutate-every: warmup must not consume the one-shot delete
    # budget or move the epoch before measurement.  The result cache is
    # dropped afterwards so measured dispatches re-execute; only the
    # compiled executables carry over.
    warm_traffic = make_traffic(repo, lake, args.requests)
    warm_reqs = [Request(op, _to_query(op, p)) for op, p in warm_traffic]
    for req in warm_reqs:
        server._queue.put(req)
    server.start()
    for req in warm_reqs:
        req.future.result(timeout=600)
    if live is not None and args.mutate_every:
        # warm the MUTATION path too: an ingest (which may trigger a
        # tier growth — compiling the growth executables here, outside
        # the measured window), a replace and a delete compile the
        # row-build stages and the group-of-1 updater; then coalesced
        # groups of sizes {2, 4} compile the BATCHED publish buckets, so
        # the first churn burst in the measured window pays no compile
        # time.  Every probe slot is deleted again so the measured
        # stream starts from the live set its id discipline expects.
        probe = (lake[0] + np.float32(0.25)).astype(np.float32)
        wid = live.ingest(probe)
        live.replace(wid, probe)
        live.delete(wid)
        for width in (2, 4):
            group = live.prepare_group(
                [("ingest", None, probe + np.float32(i))
                 for i in range(width)])
            sids = live.publish_group(group)
            cleanup = live.prepare_group(
                [("delete", sid, None) for sid in sids])
            live.publish_group(cleanup)
        live.bytes_uploaded = 0        # report the measured window only
    engine._result_cache.clear()
    server.stats = ServerStats()       # report the measured window only

    traffic = make_traffic(repo, lake, args.requests,
                           mutate_every=args.mutate_every)
    i0 = engine.stats.epoch_invalidations
    h0, m0 = engine.stats.cache_hits, engine.stats.cache_misses
    p_n0 = len(engine.stats.publish_seconds)
    mc0 = engine.stats.mutations_coalesced
    ov0 = engine.stats.prepare_overlap_seconds
    t0 = time.perf_counter()
    futures = [
        (server.submit_mutation(op, **payload) if op in MUTATION_OPS
         else server.submit(op, **payload))
        for op, payload in traffic
    ]
    for f in futures:
        f.result(timeout=600)
    dt = time.perf_counter() - t0
    server.stop()

    print(f"[serve_search] {args.requests} mixed requests in {dt*1e3:.1f} ms "
          f"-> {args.requests/dt:.1f} QPS")
    print(f"[serve_search] dispatch groups: {server.stats.batches}, "
          f"mean requests/group {server.stats.mean_batch:.1f}, "
          f"mean latency {server.stats.mean_latency_ms:.1f} ms "
          f"(p50 {server.stats.p50_ms:.1f} / p99 {server.stats.p99_ms:.1f}, "
          f"{'adaptive' if server.adaptive else 'static'} window)")
    print(f"[serve_search] engine dispatches: {engine.stats.dispatches}, "
          f"cache hits/misses: {engine.stats.cache_hits}/"
          f"{engine.stats.cache_misses} "
          f"(measured window: {engine.stats.cache_hits - h0}/"
          f"{engine.stats.cache_misses - m0}), pipelines: "
          f"{engine.stats.pipeline_stage1}")
    if live is not None:
        pub = np.asarray(engine.stats.publish_seconds[p_n0:], np.float64)
        pub_p50 = 1e3 * float(np.percentile(pub, 50)) if pub.size else 0.0
        pub_p99 = 1e3 * float(np.percentile(pub, 99)) if pub.size else 0.0
        print(f"[serve_search] mutation lane: {server.stats.mutations} "
              f"applied, mean {server.stats.mean_mutation_ms:.1f} ms; "
              f"epoch {live.epoch} "
              f"(layout {getattr(live.engine.dispatch, 'repo_epoch', 0)}), "
              f"{engine.stats.epoch_invalidations - i0} cached rows retired, "
              f"{live.bytes_uploaded} bytes uploaded, "
              f"{live.n_slots} slots ({len(live.live_ids)} live)")
        print(f"[serve_search] publish pipeline: {pub.size} publishes "
              f"(p50 {pub_p50:.1f} / p99 {pub_p99:.1f} ms), "
              f"{engine.stats.mutations_coalesced - mc0} coalesced, "
              f"{engine.stats.prepare_overlap_seconds - ov0:.3f} s of "
              f"prepare hidden under serving")
    return server.stats


if __name__ == "__main__":
    main()
