"""Search serving front-end: request queue + continuous micro-batching.

    PYTHONPATH=src python -m repro.launch.serve_search [--requests 256 ...]
    REPRO_HOST_DEVICES=8 PYTHONPATH=src \
        python -m repro.launch.serve_search --sharded   # data-sharded engine

The production shape for the paper's *online* multi-granularity search:
clients submit single queries (mixed types — RangeS / top-k IA / top-k
GBO / ApproHaus / ExactHaus at dataset granularity, RangeP / NNP at point
granularity) into a queue; a dispatcher thread drains the queue
continuously, groups
compatible requests (same op, same k), and executes each group as ONE
batched device dispatch through the :class:`QueryEngine`.  Under load the
batch size grows toward `max_batch` on its own — classic continuous
batching — so throughput scales with traffic while the executable cache
keeps compile cost amortized across the bucket ladder.

Replaces the per-request host loop of the old `examples/serve_points.py`.
"""
from __future__ import annotations

import argparse
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any

from repro import hostdev

# before the first jax import: let --sharded shard over N forced host
# devices on CPU-only machines (no-op unless REPRO_HOST_DEVICES is set)
hostdev.apply()

import jax
import numpy as np

from repro.core.repo_index import Repository
from repro.engine import QueryEngine

# ops the dispatcher knows how to group and batch; topk_hausdorff (the
# exact branch-and-bound) is batched like every other op — one grouped
# query-index build and ONE engine dispatch for the group (shared phase-2
# work frontier) — and its per-request results carry the SearchStats
# (evaluated count, pruned fraction) the engine surfaces
OPS = (
    "range_search", "topk_ia", "topk_gbo", "topk_hausdorff_approx",
    "topk_hausdorff", "range_points", "nnp",
)


@dataclass
class Request:
    op: str
    payload: dict
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class ServerStats:
    requests: int = 0
    batches: int = 0
    batch_size_sum: int = 0
    latency_sum: float = 0.0

    @property
    def mean_batch(self) -> float:
        return self.batch_size_sum / max(self.batches, 1)

    @property
    def mean_latency_ms(self) -> float:
        return 1e3 * self.latency_sum / max(self.requests, 1)


class SearchServer:
    """Continuous micro-batching dispatcher over a QueryEngine."""

    def __init__(
        self,
        engine: QueryEngine,
        *,
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
    ):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.stats = ServerStats()
        self._queue: "queue.Queue[Request | None]" = queue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._running = False

    # -- client API --------------------------------------------------------

    def submit(self, op: str, **payload: Any) -> Future:
        """Enqueue one query; returns a Future with the op's result."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; serving ops: {OPS}")
        if not self._running:
            raise RuntimeError("server is not running (start() it first)")
        req = Request(op, payload)
        self._queue.put(req)
        if not self._running and not req.future.done():
            # lost the race with a concurrent stop(): its drain may have
            # already passed our request, so fail the future ourselves
            try:
                req.future.set_exception(
                    RuntimeError("server stopped before request ran"))
            except Exception:           # drain got there first
                pass
        return req.future

    def start(self) -> "SearchServer":
        self._running = True
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        self._queue.put(None)          # wake the dispatcher
        self._thread.join(timeout=30)
        # fail anything still queued so no client Future hangs forever
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("server stopped before request ran"))

    # -- dispatcher --------------------------------------------------------

    def _drain(self) -> list[Request]:
        """Block for the first request, then greedily drain up to max_batch
        more without waiting longer than max_wait — continuous batching."""
        try:
            first = self._queue.get(timeout=0.1)
        except queue.Empty:
            return []
        if first is None:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait
        while len(batch) < self.max_batch:
            timeout = deadline - time.perf_counter()
            try:
                req = self._queue.get(timeout=max(timeout, 0.0))
            except queue.Empty:
                break
            if req is None:
                break
            batch.append(req)
        return batch

    def _loop(self) -> None:
        while self._running:
            batch = self._drain()
            if not batch:
                continue
            # group by (op, k, eps): only requests whose static/shared
            # parameters agree may share one device dispatch
            groups: dict[tuple, list[Request]] = {}
            for req in batch:
                key = (req.op, req.payload.get("k"),
                       req.payload.get("eps"))
                groups.setdefault(key, []).append(req)
            for reqs in groups.values():
                try:
                    self._dispatch(reqs)
                except Exception as e:  # surface, don't kill the server
                    for r in reqs:
                        if not r.future.done():
                            r.future.set_exception(e)

    def _dispatch(self, reqs: list[Request]) -> None:
        op = reqs[0].op
        eng = self.engine
        if op == "range_search":
            lo = np.stack([r.payload["r_lo"] for r in reqs])
            hi = np.stack([r.payload["r_hi"] for r in reqs])
            out = eng.range_search(lo, hi)
            results = [out[i] for i in range(len(reqs))]
        elif op == "topk_ia":
            lo = np.stack([r.payload["q_lo"] for r in reqs])
            hi = np.stack([r.payload["q_hi"] for r in reqs])
            vals, ids = eng.topk_ia(lo, hi, reqs[0].payload["k"])
            results = [(vals[i], ids[i]) for i in range(len(reqs))]
        elif op == "topk_gbo":
            sigs = np.stack([r.payload["q_sig"] for r in reqs])
            vals, ids = eng.topk_gbo(sigs, reqs[0].payload["k"])
            results = [(vals[i], ids[i]) for i in range(len(reqs))]
        elif op == "topk_hausdorff_approx":
            q_batch = eng.build_queries([r.payload["q"] for r in reqs])
            vals, ids, eps_eff = eng.topk_hausdorff_approx(
                q_batch, reqs[0].payload["k"], reqs[0].payload["eps"]
            )
            results = [
                (vals[i], ids[i], eps_eff[i]) for i in range(len(reqs))
            ]
        elif op == "topk_hausdorff":
            # batched end-to-end: one grouped query-index build AND one
            # engine dispatch for the whole group (shared phase-2 frontier)
            q_batch = eng.build_queries([r.payload["q"] for r in reqs])
            vals, ids, stats = eng.topk_hausdorff(
                q_batch, reqs[0].payload["k"])
            results = [
                (vals[i], ids[i], stats[i]) for i in range(len(reqs))
            ]
        elif op == "range_points":
            ds = np.asarray([r.payload["ds_id"] for r in reqs])
            lo = np.stack([r.payload["r_lo"] for r in reqs])
            hi = np.stack([r.payload["r_hi"] for r in reqs])
            out = eng.range_points(ds, lo, hi)
            results = [out[i] for i in range(len(reqs))]
        elif op == "nnp":
            ds = np.asarray([r.payload["ds_id"] for r in reqs])
            q_batch = eng.build_queries([r.payload["q"] for r in reqs])
            dists, idxs = eng.nnp(ds, q_batch)
            results = [(dists[i], idxs[i]) for i in range(len(reqs))]
        else:  # pragma: no cover - guarded by submit()
            raise ValueError(op)

        now = time.perf_counter()
        self.stats.batches += 1
        self.stats.batch_size_sum += len(reqs)
        for req, res in zip(reqs, results):
            self.stats.requests += 1
            self.stats.latency_sum += now - req.t_submit
            req.future.set_result(res)


# ---------------------------------------------------------------------------
# demo / load driver
# ---------------------------------------------------------------------------


def make_traffic(repo: Repository, datasets, n_requests: int, seed: int = 0):
    """Pre-build a mixed stream of (op, payload) requests covering all
    seven serving ops.  Payload construction (signatures etc.) happens here,
    off the submission path, like a real client would send ready-made
    queries."""
    from repro.core import zorder

    rng = np.random.default_rng(seed)
    n_ds = len(datasets)
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
    out = []
    for i in range(n_requests):
        c = rng.uniform(20, 80, 2).astype(np.float32)
        lo, hi = c - 2.0, c + 2.0
        kind = i % 7
        if kind == 0:
            out.append(("range_search", dict(r_lo=lo, r_hi=hi)))
        elif kind == 1:
            out.append(("topk_ia", dict(q_lo=lo, q_hi=hi, k=5)))
        elif kind == 2:
            q = datasets[int(rng.integers(n_ds))]
            sig = np.asarray(zorder.signature(
                jax.numpy.asarray(q), jax.numpy.ones(len(q), bool),
                repo.space_lo, repo.space_hi, 5))
            out.append(("topk_gbo", dict(q_sig=sig, k=5)))
        elif kind == 3:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_hausdorff_approx", dict(q=q, k=5, eps=eps)))
        elif kind == 4:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_hausdorff", dict(q=q, k=5)))
        elif kind == 5:
            out.append(("range_points", dict(
                ds_id=int(rng.integers(n_ds)), r_lo=lo, r_hi=hi)))
        else:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("nnp", dict(ds_id=int(rng.integers(n_ds)), q=q)))
    return out


def main(argv=None):
    from repro.core.build import build_repository
    from repro.data import synthetic

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--datasets", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--sharded", action="store_true",
                    help="serve from a ShardedQueryEngine with the resident "
                         "repository sharded over a 1-D data mesh spanning "
                         "all local devices")
    args = ap.parse_args(argv)

    lake = synthetic.trajectory_repository(args.datasets, seed=0)
    repo, _ = build_repository(lake, leaf_capacity=16, theta=5)
    if args.sharded:
        from repro.engine.sharded import ShardedQueryEngine
        engine = ShardedQueryEngine(repo)
        print(f"[serve_search] sharded engine: "
              f"{engine.dispatch.n_shards} shard(s) x "
              f"{engine.dispatch.shard_slots} dataset slots on the "
              f"'{engine.dispatch.axis}' axis")
    else:
        engine = QueryEngine(repo)
    server = SearchServer(engine, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms).start()

    # warmup: submit a full-width burst so the big-bucket executables
    # compile off the measured path (per-op batch ~= max_batch/7)
    warm = make_traffic(repo, lake, 7 * args.max_batch, seed=1)
    for f in [server.submit(op, **p) for op, p in warm]:
        f.result(timeout=600)
    server.stats = ServerStats()       # report the measured window only

    traffic = make_traffic(repo, lake, args.requests)
    t0 = time.perf_counter()
    futures = [server.submit(op, **payload) for op, payload in traffic]
    for f in futures:
        f.result(timeout=600)
    dt = time.perf_counter() - t0
    server.stop()

    print(f"[serve_search] {args.requests} mixed requests in {dt*1e3:.1f} ms "
          f"-> {args.requests/dt:.1f} QPS")
    print(f"[serve_search] device batches: {server.stats.batches}, "
          f"mean batch {server.stats.mean_batch:.1f}, "
          f"mean latency {server.stats.mean_latency_ms:.1f} ms")
    print(f"[serve_search] engine dispatches: {engine.stats.dispatches}, "
          f"cache hits/misses: {engine.stats.cache_hits}/"
          f"{engine.stats.cache_misses}")
    return server.stats


if __name__ == "__main__":
    main()
