"""Search serving front-end: request queue + continuous micro-batching.

    PYTHONPATH=src python -m repro.launch.serve_search [--requests 256 ...]
    REPRO_HOST_DEVICES=8 PYTHONPATH=src \
        python -m repro.launch.serve_search --sharded   # data-sharded engine
    REPRO_HOST_DEVICES=8 PYTHONPATH=src \
        python -m repro.launch.serve_search --replicas 2   # 2 x 4 replica mesh

The production shape for the paper's *online* multi-granularity search:
clients submit single queries (mixed types — RangeS / top-k IA / top-k
GBO / ApproHaus / ExactHaus at dataset granularity, RangeP / NNP at point
granularity, plus two-stage dataset→point PIPELINES) into a queue; a
dispatcher thread drains the queue continuously and hands the WHOLE mixed
drain to ``QueryEngine.search`` as one declarative batch.  The engine's
planner does the grouping the server used to do by hand — compatible
requests (same op, same static params) share one device dispatch, cache
hits short-circuit per row, and pipeline stage-1 queries ride the same
groups as standalone queries.  Under load the batch grows toward
`max_batch` on its own — classic continuous batching — so throughput
scales with traffic while the executable cache keeps compile cost
amortized across the bucket ladder.

``submit(op=..., **payload)`` is kept as a thin shim that constructs the
:class:`~repro.engine.query.Query` / :class:`~repro.engine.query.Pipeline`
at submission time; clients holding ready-made spec objects can enqueue
them directly with ``submit_query``.
"""
from __future__ import annotations

import argparse
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any

from repro import hostdev

# before the first jax import: let --sharded shard over N forced host
# devices on CPU-only machines (no-op unless REPRO_HOST_DEVICES is set)
hostdev.apply()

import jax
import numpy as np

from repro.core.repo_index import Repository
from repro.engine import Pipeline, Query, QueryEngine, SearchResult

# ops the submit() shim knows how to wrap into a Query/Pipeline; the
# engine's planner handles the grouping, so ANY mix of these may share one
# queue drain (and pipeline stage-1 rows share dispatches with standalone
# queries of the same op)
OPS = (
    "range_search", "topk_ia", "topk_gbo", "topk_hausdorff_approx",
    "topk_hausdorff", "range_points", "nnp", "pipeline",
)


def _to_query(op: str, payload: dict):
    """The submit() shim: legacy (op, payload) -> declarative spec."""
    if op == "pipeline":
        dataset = payload["dataset"]
        point = payload["point"]
        return Pipeline(
            dataset_stage=(dataset if isinstance(dataset, Query)
                           else _to_query(dataset["op"], dataset)),
            point_stage=(point if isinstance(point, Query)
                         else _to_query(point["op"], point)))
    if op == "range_search":
        return Query(op=op, r_lo=payload["r_lo"], r_hi=payload["r_hi"])
    if op == "topk_ia":
        # legacy payload naming: q_lo/q_hi; pipeline specs may say r_lo
        lo = payload.get("q_lo", payload.get("r_lo"))
        hi = payload.get("q_hi", payload.get("r_hi"))
        return Query(op=op, r_lo=lo, r_hi=hi, k=payload["k"])
    if op == "topk_gbo":
        return Query(op=op, q_sig=payload["q_sig"], k=payload["k"])
    if op == "topk_hausdorff_approx":
        return Query(op=op, q=payload["q"], k=payload["k"],
                     eps=payload["eps"])
    if op == "topk_hausdorff":
        return Query(op=op, q=payload["q"], k=payload["k"])
    if op == "range_points":
        return Query(op=op, ds_id=payload.get("ds_id"),
                     r_lo=payload["r_lo"], r_hi=payload["r_hi"])
    if op == "nnp":
        return Query(op=op, ds_id=payload.get("ds_id"), q=payload["q"])
    raise ValueError(f"unknown op {op!r}; serving ops: {OPS}")


def _legacy_result(res: SearchResult):
    """Shape a SearchResult like the pre-redesign per-op responses, so
    existing clients keep unpacking what they always unpacked.  Pipeline
    responses are new: they hand back the full SearchResult (stage-2
    rows + ``extras['stage1']``)."""
    if res.op == "range_search" or res.op == "range_points":
        return res.mask
    if res.op == "topk_ia" or res.op == "topk_gbo":
        return (res.vals, res.ids)
    if res.op == "topk_hausdorff_approx":
        return (res.vals, res.ids, res.extras["eps_eff"])
    if res.op == "topk_hausdorff":
        return (res.vals, res.ids, res.stats)
    if res.op == "nnp":
        return (res.vals, res.ids)
    return res                              # pipeline: the full result


@dataclass
class Request:
    op: str
    query: Any                              # Query | Pipeline
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class ServerStats:
    requests: int = 0
    batches: int = 0                        # dispatch groups planned
    batch_size_sum: int = 0
    latency_sum: float = 0.0
    latencies: list = field(default_factory=list)   # per-request seconds
    op_ewma: dict = field(default_factory=dict)     # op -> EWMA latency s

    #: same smoothing as EngineStats.EWMA_ALPHA — both feeds estimate
    #: "how long does one more batch of this op take" for the adaptive
    #: straggler window
    EWMA_ALPHA = 0.2

    @property
    def mean_batch(self) -> float:
        return self.batch_size_sum / max(self.batches, 1)

    @property
    def mean_latency_ms(self) -> float:
        return 1e3 * self.latency_sum / max(self.requests, 1)

    def record(self, op: str, seconds: float) -> None:
        """Book one answered request's submit->result latency."""
        self.requests += 1
        self.latency_sum += seconds
        self.latencies.append(seconds)
        prev = self.op_ewma.get(op)
        self.op_ewma[op] = (seconds if prev is None
                            else prev + self.EWMA_ALPHA * (seconds - prev))

    def percentile_ms(self, p: float) -> float:
        """p-th percentile of per-request latency, in ms (0 if empty)."""
        if not self.latencies:
            return 0.0
        return 1e3 * float(np.percentile(np.asarray(self.latencies), p))

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50.0)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99.0)


class SearchServer:
    """Continuous micro-batching dispatcher over a QueryEngine.

    Two batching policies:

    * **adaptive** (default) — queue-depth-driven: the dispatcher
      greedily takes every request ALREADY enqueued (no waiting while
      there is work to batch); when the queue runs dry it waits one
      straggler window, and every arrival renews that budget, so the
      batch keeps filling while traffic flows and ships the moment one
      full window passes with nothing new.  The window is
      ``min(max_wait, 0.5 x EWMA dispatch latency)`` of the ops in the
      partial batch (fed by :meth:`EngineStats.record_latency`): folding
      a straggler into this batch saves about one dispatch's EWMA, so
      waiting longer than a fraction of it costs more latency than it
      saves.  Under saturating load the windows renew until the batch
      fills; at low load a lone request waits at most one window —
      typically far less than the static ``max_wait`` deadline for
      cheap ops.  When the backlog is deeper than ``max_batch`` the
      drain bound itself scales with queue depth (up to
      ``OVERFILL x max_batch``): a deep queue means dispatch overhead
      dominates, so amortising it over a larger drain raises saturated
      throughput without hurting the (already queue-dominated) tail.
    * **static** (``adaptive=False``) — the seed policy: after the first
      request, keep blocking up to a fixed ``max_wait`` deadline while
      the batch fills.  Kept for A/B measurement
      (``bench_engine --serving`` and ``--static-window`` here).
    """

    #: adaptive drains may grow to this multiple of ``max_batch`` when
    #: the queue is already deeper than ``max_batch`` (bounds worst-case
    #: host memory for one drain at OVERFILL x max_batch requests)
    OVERFILL = 4

    def __init__(
        self,
        engine: QueryEngine,
        *,
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        adaptive: bool = True,
    ):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.adaptive = adaptive
        self.stats = ServerStats()
        self._queue: "queue.Queue[Request | None]" = queue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._running = False

    # -- client API --------------------------------------------------------

    def submit(self, op: str, **payload: Any) -> Future:
        """Enqueue one query; returns a Future with the op's result.

        Thin shim: the legacy (op, **payload) call is converted to a
        declarative Query/Pipeline HERE (validation included), then
        enqueued like any other spec."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; serving ops: {OPS}")
        if not self._running:
            raise RuntimeError("server is not running (start() it first)")
        return self.submit_query(_to_query(op, payload), op=op)

    def submit_query(self, query, *, op: str | None = None) -> Future:
        """Enqueue a ready-made Query/Pipeline spec."""
        if not isinstance(query, (Query, Pipeline)):
            raise TypeError(f"submit_query takes Query/Pipeline, "
                            f"got {type(query)!r}")
        if not self._running:
            raise RuntimeError("server is not running (start() it first)")
        if op is None:
            op = "pipeline" if isinstance(query, Pipeline) else query.op
        req = Request(op, query)
        self._queue.put(req)
        if not self._running and not req.future.done():
            # lost the race with a concurrent stop(): its drain may have
            # already passed our request, so fail the future ourselves
            try:
                req.future.set_exception(
                    RuntimeError("server stopped before request ran"))
            except Exception:           # drain got there first
                pass
        return req.future

    def start(self) -> "SearchServer":
        self._running = True
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        self._queue.put(None)          # wake the dispatcher
        self._thread.join(timeout=30)
        # fail anything still queued so no client Future hangs forever
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("server stopped before request ran"))

    # -- dispatcher --------------------------------------------------------

    def _straggler_window(self, batch: list[Request]) -> float:
        """Adaptive wait budget once the queue runs dry: half the EWMA
        dispatch latency of the ops already in the batch (capped by
        max_wait) — the break-even point between folding a straggler
        into this dispatch and shipping without it.  Before any latency
        has been measured, fall back to the static window."""
        ew = self.engine.stats.latency_ewma
        vals = [ew[r.op] for r in batch if r.op in ew]
        if not vals:
            vals = list(ew.values())
        if not vals:
            return self.max_wait
        return min(self.max_wait, 0.5 * max(vals))

    def _drain(self) -> list[Request]:
        """Block for the first request, then fill the batch —
        queue-depth-driven when adaptive (greedy takes, dry-queue
        straggler windows that renew on every arrival, and a drain
        bound that scales to OVERFILL x max_batch under deep backlog),
        fixed max_wait deadline up to max_batch when static (the seed
        policy)."""
        try:
            first = self._queue.get(timeout=0.1)
        except queue.Empty:
            return []
        if first is None:
            return []
        batch = [first]
        if self.adaptive:
            # depth-scaled bound: when the backlog already exceeds
            # max_batch, per-drain overhead (planning plus one engine
            # dispatch per group) dominates per-request work, so fold
            # up to OVERFILL x max_batch queued requests into this
            # drain.  The planner groups compatible rows into shared
            # dispatches and the bucket ladder pads row counts anyway,
            # so the larger drain amortises fixed costs without
            # triggering new compilation.
            limit = self.max_batch
            if self._queue.qsize() > self.max_batch:
                limit = self.OVERFILL * self.max_batch
            waited = False
            while len(batch) < limit:
                try:
                    req = self._queue.get_nowait()
                except queue.Empty:
                    if waited:
                        break
                    waited = True
                    try:
                        req = self._queue.get(
                            timeout=self._straggler_window(batch))
                    except queue.Empty:
                        break
                if req is None:
                    break
                batch.append(req)
                # every arrival renews the straggler budget: the batch
                # keeps growing while traffic flows and ships the moment
                # one full window passes with no arrival (total wait is
                # bounded by max_batch renewals of <= max_wait each)
                waited = False
            return batch
        deadline = time.perf_counter() + self.max_wait
        while len(batch) < self.max_batch:
            timeout = deadline - time.perf_counter()
            try:
                req = self._queue.get(timeout=max(timeout, 0.0))
            except queue.Empty:
                break
            if req is None:
                break
            batch.append(req)
        return batch

    def _loop(self) -> None:
        from repro.engine import plan as plan_lib

        while self._running:
            batch = self._drain()
            if not batch:
                continue
            # ONE declarative engine call for the whole mixed drain: the
            # planner groups compatible rows into shared dispatches and
            # returns per-request results in input order
            try:
                results = self.engine.search([r.query for r in batch])
            except Exception:
                # a poisoned row fails the whole mixed call; isolate by
                # re-running per request so every healthy future still
                # resolves and only the bad rows carry the exception
                # (the executable cache makes the re-runs cheap)
                results = []
                for r in batch:
                    try:
                        results.append(self.engine.search([r.query])[0])
                    except Exception as e:
                        results.append(e)
            now = time.perf_counter()
            # dispatch-group count (stage-1 op groups + pipeline stage-2
            # groups), planned locally (host-only grouping) so a client
            # sharing the engine from another thread can't skew the
            # server's own metric; guarded — the accounting must never be
            # able to kill the dispatcher after results exist
            try:
                self.stats.batches += plan_lib.count_groups(
                    [r.query for r in batch], self.engine.leaf_capacity)
            except Exception:
                self.stats.batches += 1
            self.stats.batch_size_sum += len(batch)
            for req, res in zip(batch, results):
                self.stats.record(req.op, now - req.t_submit)
                if isinstance(res, Exception):
                    if not req.future.done():
                        req.future.set_exception(res)
                else:
                    req.future.set_result(_legacy_result(res))


# ---------------------------------------------------------------------------
# demo / load driver
# ---------------------------------------------------------------------------


def make_traffic(repo: Repository, datasets, n_requests: int, seed: int = 0):
    """Pre-build a mixed stream of (op, payload) requests covering all
    seven serving ops PLUS two pipeline kinds (top-k IA -> RangeP inside
    the winners, and ApproHaus -> NNP inside the winners — the paper's
    dataset->point workflow), so a drain exercises genuinely
    heterogeneous declarative batches.  Payload construction (signatures
    etc.) happens here, off the submission path, like a real client would
    send ready-made queries."""
    from repro.core import zorder

    rng = np.random.default_rng(seed)
    n_ds = len(datasets)
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
    out = []
    for i in range(n_requests):
        c = rng.uniform(20, 80, 2).astype(np.float32)
        lo, hi = c - 2.0, c + 2.0
        kind = i % 9
        if kind == 0:
            out.append(("range_search", dict(r_lo=lo, r_hi=hi)))
        elif kind == 1:
            out.append(("topk_ia", dict(q_lo=lo, q_hi=hi, k=5)))
        elif kind == 2:
            q = datasets[int(rng.integers(n_ds))]
            sig = np.asarray(zorder.signature(
                jax.numpy.asarray(q), jax.numpy.ones(len(q), bool),
                repo.space_lo, repo.space_hi, 5))
            out.append(("topk_gbo", dict(q_sig=sig, k=5)))
        elif kind == 3:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_hausdorff_approx", dict(q=q, k=5, eps=eps)))
        elif kind == 4:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("topk_hausdorff", dict(q=q, k=5)))
        elif kind == 5:
            out.append(("range_points", dict(
                ds_id=int(rng.integers(n_ds)), r_lo=lo, r_hi=hi)))
        elif kind == 6:
            q = datasets[int(rng.integers(n_ds))][:64]
            out.append(("nnp", dict(ds_id=int(rng.integers(n_ds)), q=q)))
        elif kind == 7:
            # dataset->point pipeline: top-3 IA datasets, then RangeP
            # inside each winner (ids never leave the device)
            wide_lo, wide_hi = c - 10.0, c + 10.0
            out.append(("pipeline", dict(
                dataset=dict(op="topk_ia", r_lo=wide_lo, r_hi=wide_hi, k=3),
                point=dict(op="range_points", r_lo=lo, r_hi=hi))))
        else:
            q = datasets[int(rng.integers(n_ds))][:32]
            out.append(("pipeline", dict(
                dataset=dict(op="topk_hausdorff_approx", q=q, k=3, eps=eps),
                point=dict(op="nnp", q=q))))
    return out


def main(argv=None):
    from repro.core.build import build_repository
    from repro.data import synthetic

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--datasets", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--static-window", action="store_true",
                    help="use the fixed max-wait batching window instead "
                         "of the queue-depth-driven adaptive policy")
    ap.add_argument("--sharded", action="store_true",
                    help="serve from a ShardedQueryEngine with the resident "
                         "repository sharded over a 1-D data mesh spanning "
                         "all local devices")
    ap.add_argument("--replicas", type=int, default=0, metavar="R",
                    help="serve from a ReplicatedQueryEngine over an R x D "
                         "(replica x data) mesh: the repository is sharded "
                         "over D devices per group and replicated across R "
                         "groups, each drain's rows split over the groups")
    ap.add_argument("--data-shards", type=int, default=None, metavar="D",
                    help="data-axis extent per replica group (default: all "
                         "remaining local devices / R)")
    args = ap.parse_args(argv)

    lake = synthetic.trajectory_repository(args.datasets, seed=0)
    repo, _ = build_repository(lake, leaf_capacity=16, theta=5)
    if args.replicas:
        from repro.engine.replicated import ReplicatedQueryEngine
        engine = ReplicatedQueryEngine(repo, n_replicas=args.replicas,
                                       n_data=args.data_shards)
        print(f"[serve_search] replicated engine: "
              f"{engine.dispatch.n_replicas} replica group(s) x "
              f"{engine.dispatch.n_shards} data shard(s) "
              f"({engine.dispatch.n_replicas * engine.dispatch.n_shards} "
              f"devices), {engine.dispatch.shard_slots} dataset slots "
              f"per shard")
    elif args.sharded:
        from repro.engine.sharded import ShardedQueryEngine
        engine = ShardedQueryEngine(repo)
        print(f"[serve_search] sharded engine: "
              f"{engine.dispatch.n_shards} shard(s) x "
              f"{engine.dispatch.shard_slots} dataset slots on the "
              f"'{engine.dispatch.axis}' axis")
    else:
        engine = QueryEngine(repo)
    server = SearchServer(engine, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms,
                          adaptive=not args.static_window)

    # warmup: run the measured traffic once, pre-filled BEFORE the
    # dispatcher starts so the warm drains are full-depth and aligned
    # with the measured burst — compiling exactly the bucket shapes AND
    # payload shapes (pipeline queries embed variable-length datasets,
    # which trace per length) the measurement will hit.  The result
    # cache is dropped afterwards so measured dispatches re-execute;
    # only the compiled executables carry over.
    traffic = make_traffic(repo, lake, args.requests)
    warm_reqs = [Request(op, _to_query(op, p)) for op, p in traffic]
    for req in warm_reqs:
        server._queue.put(req)
    server.start()
    for req in warm_reqs:
        req.future.result(timeout=600)
    engine._result_cache.clear()
    server.stats = ServerStats()       # report the measured window only

    h0, m0 = engine.stats.cache_hits, engine.stats.cache_misses
    t0 = time.perf_counter()
    futures = [server.submit(op, **payload) for op, payload in traffic]
    for f in futures:
        f.result(timeout=600)
    dt = time.perf_counter() - t0
    server.stop()

    print(f"[serve_search] {args.requests} mixed requests in {dt*1e3:.1f} ms "
          f"-> {args.requests/dt:.1f} QPS")
    print(f"[serve_search] dispatch groups: {server.stats.batches}, "
          f"mean requests/group {server.stats.mean_batch:.1f}, "
          f"mean latency {server.stats.mean_latency_ms:.1f} ms "
          f"(p50 {server.stats.p50_ms:.1f} / p99 {server.stats.p99_ms:.1f}, "
          f"{'adaptive' if server.adaptive else 'static'} window)")
    print(f"[serve_search] engine dispatches: {engine.stats.dispatches}, "
          f"cache hits/misses: {engine.stats.cache_hits}/"
          f"{engine.stats.cache_misses} "
          f"(measured window: {engine.stats.cache_hits - h0}/"
          f"{engine.stats.cache_misses - m0}), pipelines: "
          f"{engine.stats.pipeline_stage1}")
    return server.stats


if __name__ == "__main__":
    main()
