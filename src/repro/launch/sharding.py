"""Parameter / state / batch shardings from logical rules (DESIGN.md sec. 4).

Strategy (the paper-faithful baseline layout; §Perf hillclimbs deviate):
  * params: TP over 'model' on the head/ffn/vocab dim, FSDP (ZeRO-3) over
    'data' on the other dim; replicated where a dim doesn't divide.
  * optimizer moments mirror the param shardings (int8 codes: flat-sharded).
  * batch: ('pod','data') on the batch dim; KV caches likewise, with the
    time axis sharded over 'model' for the long-context cells.

Everything keys off leaf PATHS, so it works for any of the 10 archs without
per-arch tables.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train.optimizer import QTensor

# (regex on '/'-joined path) -> spec for the LAST ndim dims of the leaf.
# Leading stacked dims (scan repeats) are always replicated (None).
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$",            ("model", "data")),   # (vocab, d)
    (r"head/table$",             ("model", "data")),
    (r"(wq|wk|wv)$",             ("data", "model")),   # (d, heads*hd)
    (r"wo$",                     ("model", "data")),   # (heads*hd, d)
    (r"(wg|wu)$",                ("data", "model")),   # (d, ff) [+E lead]
    (r"wd$",                     ("model", "data")),   # (ff, d) [+E lead]
    (r"router$",                 ("data", None)),
    (r"in_proj$",                ("data", "model")),
    (r"out_proj$",               ("model", "data")),
    (r"conv_w$",                 (None, "model")),
    (r"conv_b$",                 ("model",)),
    (r"(A_log|D|dt_bias)$",      ("model",)),
    (r"(norm_scale|scale|xgate)$", (None,)),
]

_MOE_LEAF = re.compile(r"moe/(wg|wu|wd)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _divisible(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    size = np.prod([mesh.shape[a] for a in
                    (axis if isinstance(axis, tuple) else (axis,))])
    return dim % int(size) == 0


def spec_for_param(path_s: str, shape: tuple, mesh: Mesh,
                   rules: dict | None = None) -> P:
    """PartitionSpec for one param leaf; replicates non-divisible dims."""
    rules = rules or {}
    expert_axis = rules.get("expert")  # None (TP-MoE) or "model" (EP)
    no_fsdp = rules.get("no_fsdp", False)  # serving: params TP-only resident
    for pat, tail in _PARAM_RULES:
        if re.search(pat, path_s):
            tail = list(tail)
            if no_fsdp:
                tail = [None if t == "data" else t for t in tail]
            if _MOE_LEAF.search(path_s):
                if expert_axis == "model":
                    # EP: experts over model; drop model from the tail
                    tail = [None if t == "model" else t for t in tail]
                    tail = [expert_axis] + tail
                else:
                    tail = [None] + tail
            ndim = len(shape)
            lead = [None] * (ndim - len(tail))
            full = lead + tail
            full = full[:ndim]
            # replicate any axis that doesn't divide
            full = [a if _divisible(shape[i], mesh, a) else None
                    for i, a in enumerate(full)]
            return P(*full)
    return P()  # replicate by default (norm scales, scalars)


def param_shardings(params_shape: Any, mesh: Mesh,
                    rules: dict | None = None):
    """NamedShardings matching a params (shape-)pytree."""

    def one(path, leaf):
        ps = _path_str(path)
        if isinstance(leaf, QTensor):
            # int8 states mirror the parent param's sharding: lead dims keep
            # the param spec; the param's last-axis sharding moves to the
            # n_blocks dim (when divisible), the block dim stays local
            parent = spec_for_param(ps, leaf.shape, mesh, rules)
            tail = list(parent) + [None] * (len(leaf.shape) - len(parent))
            nb = leaf.codes.shape[-2]
            last = tail[-1] if _divisible(nb, mesh, tail[-1]) else None
            c = NamedSharding(mesh, P(*tail[:-1], last, None))
            s = NamedSharding(mesh, P(*tail[:-1], last))
            return QTensor(c, s, leaf.shape)
        return NamedSharding(mesh, spec_for_param(ps, leaf.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(
        one, params_shape,
        is_leaf=lambda x: isinstance(x, (QTensor, jax.ShapeDtypeStruct,
                                         jax.Array, np.ndarray)))


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def spec_for_batch_leaf(shape: tuple, mesh: Mesh) -> P:
    """Batch-dim sharding for an input leaf, replicate if non-divisible."""
    ba = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in ba]))
    if shape and shape[0] % size == 0 and shape[0] > 0:
        return P(ba, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_sharding(shape: tuple, mesh: Mesh, *, shard_time: bool) -> P:
    """KV cache (R, B, T, KH, hd) / SSM state (R, B, H, P, N) sharding."""
    ba = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in ba]))
    b_ax = ba if (len(shape) > 1 and shape[1] % bsz == 0) else None
    spec = [None, b_ax] + [None] * (len(shape) - 2)
    if len(shape) == 5:
        # try model axis on: KV time (idx 2, when shard_time) else heads (3)
        m = mesh.shape["model"]
        if shard_time and shape[2] % m == 0 and shape[2] > m:
            spec[2] = "model"
        elif shape[3] % m == 0:
            spec[3] = "model"
    if len(shape) == 4:
        m = mesh.shape["model"]
        if shard_time and shape[2] % m == 0 and shape[2] > m:
            spec[2] = "model"   # int8 KV scale time axis
        elif shape[3] % m == 0:
            spec[3] = "model"   # conv state channels
    return P(*spec)
