"""Cell definitions: (architecture x input shape) -> abstract inputs,
shardings and the step function to lower.

The 40-cell grid (10 archs x {train_4k, prefill_32k, decode_32k,
long_500k}); long_500k lowers only for sub-quadratic archs (mamba2, jamba)
per the assignment — the other 8 record a documented skip.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import sharding as sh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models import sharding_rules
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts

# shape_name -> (kind, global_batch, seq_len)
SHAPES: dict[str, tuple[str, int, int]] = {
    "train_4k":    ("train",   256, 4_096),
    "prefill_32k": ("prefill",  32, 32_768),
    "decode_32k":  ("decode",  128, 32_768),
    "long_500k":   ("decode",    1, 524_288),
}

SKIP_REASON = ("full-attention arch: 512k-token decode requires a "
               "sub-quadratic mechanism per the assignment; skipped "
               "(see DESIGN.md sec. 5)")


def runnable(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.sub_quadratic
    return True


def all_cells(include_skips: bool = False):
    """Yield (arch, shape_name) for the 40-cell grid (paper-native config is
    extra and not part of the assigned grid)."""
    for arch in configs.ARCH_IDS:
        if arch == "spadas_trajlm":
            continue
        cfg = configs.get(arch)
        for shape_name in SHAPES:
            if runnable(cfg, shape_name) or include_skips:
                yield arch, shape_name


def arch_rules(cfg: ModelConfig, kind: str) -> dict:
    """Logical-rule overrides for a given (arch, step kind)."""
    rules = {}
    if cfg.n_experts and cfg.n_experts % 16 == 0:
        rules["expert"] = "model"      # EP when experts divide the TP axis
    if kind in ("prefill", "decode"):
        rules["kvseq"] = "model"       # shard cache time on long contexts
        rules["no_fsdp"] = True        # serving keeps params TP-resident
                                       # (§Perf iteration 7: per-step ZeRO-3
                                       # weight gathers are pure overhead
                                       # when there is no optimizer)
    return rules


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def _batch_specs(cfg: ModelConfig, B: int, S: int, *, with_labels: bool):
    batch: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embed_input:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.vision_tokens:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch


def _batch_shardings(batch, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, sh.spec_for_batch_leaf(s.shape, mesh)),
        batch)


@dataclasses.dataclass
class LoweringPlan:
    """Everything dryrun.py needs for one cell."""
    name: str
    step_fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    donate_argnums: tuple = ()


def _param_dtype(cfg: ModelConfig):
    # giant archs: bf16 params + int8 moments (DESIGN.md sec. 4)
    return jnp.bfloat16 if cfg.param_count() > 60e9 else jnp.float32


def _opt_cfg(cfg: ModelConfig) -> opt_lib.OptConfig:
    int8 = cfg.param_count() > 60e9
    return opt_lib.OptConfig(state_dtype="int8" if int8 else "fp32")


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg,
                              dtype=_param_dtype(cfg)))


def make_plan(cfg: ModelConfig, shape_name: str, mesh: Mesh,
              *, compress: bool = False, microbatch: int = 0,
              rules_override: dict | None = None,
              constrain_grads: bool = False) -> LoweringPlan:
    kind, B, S = SHAPES[shape_name]
    rules = arch_rules(cfg, kind)
    if rules_override:
        rules.update(rules_override)
    sharding_rules.set_rules(**{k: rules.get(k) for k in
                                ("expert", "kvseq")})
    sharding_rules.set_mesh(mesh)

    params_abs = abstract_params(cfg)
    p_shard = sh.param_shardings(params_abs, mesh, rules)

    if kind == "train":
        opt_cfg = _opt_cfg(cfg)
        state_abs = jax.eval_shape(
            lambda: ts.init_train_state(
                jax.random.PRNGKey(0), cfg, opt_cfg,
                param_dtype=_param_dtype(cfg), compress=compress))
        o_shard = ts.TrainState(
            params=p_shard,
            opt=opt_lib.OptState(
                m=sh.param_shardings(state_abs.opt.m, mesh, rules),
                v=sh.param_shardings(state_abs.opt.v, mesh, rules),
                count=NamedSharding(mesh, P()),
            ),
            err=(sh.param_shardings(state_abs.err, mesh, rules)
                 if compress else None),
            step=NamedSharding(mesh, P()),
        )
        batch = _batch_specs(cfg, B, S, with_labels=True)
        b_shard = _batch_shardings(batch, mesh)
        step = ts.make_train_step(
            cfg, opt_cfg, compress=compress, microbatch=microbatch,
            param_shardings=p_shard if constrain_grads else None)
        return LoweringPlan(
            name=f"{cfg.name}/{shape_name}",
            step_fn=step,
            abstract_args=(state_abs, batch),
            in_shardings=(o_shard, b_shard),
            donate_argnums=(0,),
        )

    if kind == "prefill":
        batch = _batch_specs(cfg, B, S, with_labels=False)
        b_shard = _batch_shardings(batch, mesh)

        def prefill_fn(params, batch):
            return M.prefill(params, cfg, batch, max_len=S)

        return LoweringPlan(
            name=f"{cfg.name}/{shape_name}",
            step_fn=prefill_fn,
            abstract_args=(params_abs, batch),
            in_shardings=(p_shard, b_shard),
        )

    # decode
    caches_abs = M.cache_specs(cfg, B, S)
    c_shard = jax.tree.map(
        lambda s: NamedSharding(
            mesh, sh.cache_sharding(s.shape, mesh,
                                    shard_time=rules.get("kvseq") == "model")),
        caches_abs)
    if cfg.embed_input:
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    else:
        tok_abs = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
    tok_shard = NamedSharding(mesh, sh.spec_for_batch_leaf(
        (B, 1), mesh))
    len_abs = jax.ShapeDtypeStruct((), jnp.int32)
    len_shard = NamedSharding(mesh, P())
    args = [params_abs, tok_abs, caches_abs, len_abs]
    shards = [p_shard, tok_shard, c_shard, len_shard]

    if cfg.vision_tokens:
        ctx_abs = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.d_model),
                                       jnp.bfloat16)
        args.append(ctx_abs)
        shards.append(NamedSharding(
            mesh, sh.spec_for_batch_leaf(ctx_abs.shape, mesh)))

        def decode_fn(params, tokens, caches, cache_len, ctx):
            return M.decode_step(params, cfg, tokens, caches, cache_len,
                                 ctx=ctx)
    else:
        def decode_fn(params, tokens, caches, cache_len):
            return M.decode_step(params, cfg, tokens, caches, cache_len)

    return LoweringPlan(
        name=f"{cfg.name}/{shape_name}",
        step_fn=decode_fn,
        abstract_args=tuple(args),
        in_shardings=tuple(shards),
        donate_argnums=(2,),
    )
