"""Batched serving driver: prefill + decode loop with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch spadas_trajlm \
        --reduced --requests 8 --prompt-len 64 --gen 32

Demonstrates the serve path end-to-end on CPU with a reduced config; the
full configs lower the identical step functions on the production meshes
(launch/dryrun.py prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="spadas_trajlm")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, P, G = args.requests, args.prompt_len, args.gen
    max_len = P + G

    batch = {}
    if cfg.embed_input:
        batch["tokens"] = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(key, (B, P, cfg.d_model),
                                            jnp.bfloat16)
    ctx = None
    if cfg.vision_tokens:
        ctx = jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model),
                                jnp.bfloat16)
        batch["image_embeds"] = ctx

    prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b, max_len=max_len))
    decode = jax.jit(
        lambda p, t, c, n: M.decode_step(p, cfg, t, c, n, ctx=ctx))

    t0 = time.time()
    logits, caches, cur = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        step_in = tok if cfg.embed_input else jax.random.normal(
            key, (B, 1, cfg.d_model), jnp.bfloat16)
        logits, caches = decode(params, step_in, caches, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] {B} requests  prefill({P} tok) {t_prefill*1e3:.1f} ms   "
          f"decode {G-1} steps {t_decode*1e3:.1f} ms "
          f"({t_decode/(G-1)*1e3:.2f} ms/tok incl. dispatch)")
    print(f"[serve] sample generation (req 0): {seqs[0][:16].tolist()}")
    return seqs


if __name__ == "__main__":
    main()
