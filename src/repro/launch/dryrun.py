import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (spec deliverable e).

Lowers + compiles every (architecture x input shape) cell on the single-pod
(16,16) 'data,model' mesh AND the multi-pod (2,16,16) 'pod,data,model' mesh,
then records per-device memory analysis, HLO cost analysis and the parsed
collective schedule for the roofline (EXPERIMENTS.md sec. Dry-run/Roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def parse_collectives(hlo_text: str) -> dict:
    """Collective schedule of a compiled (post-SPMD) per-device module.

    For each op records result-shape bytes, the replica-group size g, and
    ring-model bytes MOVED per device:
      all-reduce          2 * S * (g-1)/g
      all-gather          S_out * (g-1)/g      (device receives the rest)
      reduce-scatter      S_out * (g-1)        (ring reduce of full input)
      all-to-all          S * (g-1)/g
      collective-permute  S
    """
    out = {op: {"bytes": 0, "moved_bytes": 0.0, "count": 0}
           for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)",
                     rhs)
        if not m:
            continue
        shape_txt, opname = m.group(1), m.group(2)
        for op in COLLECTIVE_OPS:
            if opname == op or opname == op + "-start":
                size = _shape_bytes(shape_txt)
                g = _group_size(s)
                if op == "all-reduce":
                    moved = 2.0 * size * (g - 1) / g
                elif op == "all-gather":
                    moved = size * (g - 1) / g
                elif op == "reduce-scatter":
                    moved = size * (g - 1)
                elif op == "all-to-all":
                    moved = size * (g - 1) / g
                else:
                    moved = float(size)
                out[op]["bytes"] += size
                out[op]["moved_bytes"] += moved
                out[op]["count"] += 1
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             *, test: bool = False, plan_kw: dict | None = None,
             tag: str = "") -> dict:
    from repro import configs
    from repro.launch import mesh as mesh_lib
    from repro.launch import specs

    cfg = configs.get_reduced(arch) if test else configs.get(arch)
    kind, B, S = specs.SHAPES[shape_name]
    if test:  # shrink shapes for CI
        B, S = max(8, B // 32), min(S, 512)
        specs_shapes = dict(specs.SHAPES)
        specs_shapes[shape_name] = (kind, B, S)
        specs.SHAPES = specs_shapes

    if not specs.runnable(cfg, shape_name):
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "skipped", "reason": specs.SKIP_REASON,
        }
        _dump(out_dir, arch, shape_name, mesh_kind, rec, tag)
        return rec

    make = mesh_lib.make_test_mesh if test else mesh_lib.make_production_mesh
    mesh = make(multi_pod=(mesh_kind == "multi"))

    t0 = time.time()
    plan_kw = dict(plan_kw or {})
    overrides = plan_kw.pop("cfg_overrides", None)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    plan = specs.make_plan(cfg, shape_name, mesh, **plan_kw)
    with mesh:
        jitted = jax.jit(
            plan.step_fn,
            in_shardings=plan.in_shardings,
            donate_argnums=plan.donate_argnums,
        )
        lowered = jitted.lower(*plan.abstract_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)                      # proves it fits (spec step 3)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "status": "ok",
        "cell_kind": kind, "batch": B, "seq": S,
        "n_devices": int(mesh.size),
        "model_params": int(cfg.param_count()),
        "model_params_active": int(cfg.active_param_count()),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", -1)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", -1)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", -1)),
        },
        "collectives": colls,
        "collective_bytes_total": sum(v["bytes"] for v in colls.values()),
        "collective_moved_bytes_total": sum(
            v["moved_bytes"] for v in colls.values()),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    _dump(out_dir, arch, shape_name, mesh_kind, rec, tag)
    return rec


def probe_cell(arch: str, shape_name: str, out_dir: Path,
               *, test: bool = False, plan_kw: dict | None = None,
               tag: str = "probe") -> dict:
    """Scan-trip cost correction probes (see benchmarks/roofline.py).

    XLA cost_analysis counts a `scan` body ONCE regardless of trip count,
    so per-device FLOPs/bytes of the layer stack are under-reported by ~R.
    We lower the SAME cell with the stack UNROLLED at R=1 and R=2 repeats;
    the marginal cost (R2 - R1) is the true per-repeat cost and the cell's
    corrected totals extrapolate linearly:  C(R) = C1 + (R-1) * (C2 - C1).
    """
    import dataclasses

    from repro import configs
    from repro.launch import mesh as mesh_lib
    from repro.launch import specs

    cfg0 = configs.get_reduced(arch) if test else configs.get(arch)
    if test:
        kind, B, S = specs.SHAPES[shape_name]
        specs.SHAPES = {**specs.SHAPES,
                        shape_name: (kind, max(8, B // 32), min(S, 512))}
    if not specs.runnable(cfg0, shape_name):
        rec = {"arch": arch, "shape": shape_name, "status": "skipped",
               "reason": specs.SKIP_REASON}
        _dump(out_dir, arch, shape_name, "single", rec, tag)
        return rec

    make = mesh_lib.make_test_mesh if test else mesh_lib.make_production_mesh
    plan_kw = dict(plan_kw or {})
    overrides = plan_kw.pop("cfg_overrides", None)
    if overrides:
        cfg0 = dataclasses.replace(cfg0, **overrides)
    out = {}
    for R in (1, 2):
        cfg = dataclasses.replace(
            cfg0, n_layers=R * len(cfg0.block_pattern), scan_layers=False)
        mesh = make(multi_pod=False)
        plan = specs.make_plan(cfg, shape_name, mesh, **plan_kw)
        with mesh:
            compiled = jax.jit(
                plan.step_fn, in_shardings=plan.in_shardings,
                donate_argnums=plan.donate_argnums,
            ).lower(*plan.abstract_args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        colls = parse_collectives(compiled.as_text())
        out[f"r{R}"] = {
            "flops": float(cost.get("flops", -1.0)),
            "bytes": float(cost.get("bytes accessed", -1.0)),
            "coll_moved": sum(v["moved_bytes"] for v in colls.values()),
        }
        print(f"[probe] {arch}/{shape_name} R={R}: {out[f'r{R}']}",
              flush=True)

    R_full = cfg0.n_repeats
    marg = {k: max(out["r2"][k] - out["r1"][k], 0.0)
            for k in ("flops", "bytes", "coll_moved")}
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "n_repeats": R_full,
        "probe": out,
        "corrected": {
            k: out["r1"][k] + (R_full - 1) * marg[k]
            for k in ("flops", "bytes", "coll_moved")
        },
    }
    _dump(out_dir, arch, shape_name, "single", rec, tag)
    return rec


def _dump(out_dir: Path, arch, shape, mesh_kind, rec, tag=""):
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out_dir / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"[dryrun] wrote {path}", flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--test", action="store_true",
                    help="reduced configs + 8-device mesh (CI)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="scan-trip cost probes (single mesh only)")
    ap.add_argument("--tag", default="",
                    help="variant tag appended to output filenames")
    ap.add_argument("--opt", default="",
                    help="comma list of plan opts: constrain_grads,"
                         "compress,microbatch=N,kvseq=none,expert=model")
    args = ap.parse_args(argv)

    plan_kw: dict = {}
    rules_override: dict = {}
    for item in [s for s in args.opt.split(",") if s]:
        if item == "constrain_grads":
            plan_kw["constrain_grads"] = True
        elif item == "compress":
            plan_kw["compress"] = True
        elif item.startswith("microbatch="):
            plan_kw["microbatch"] = int(item.split("=")[1])
        elif item.startswith("kvseq="):
            v = item.split("=")[1]
            rules_override["kvseq"] = None if v == "none" else v
        elif item.startswith("expert="):
            v = item.split("=")[1]
            rules_override["expert"] = None if v == "none" else v
        elif item == "kvint8":
            plan_kw["cfg_overrides"] = {"kv_cache_dtype": "int8"}
        else:
            raise SystemExit(f"unknown --opt item {item}")
    if rules_override:
        plan_kw["rules_override"] = rules_override

    from repro.launch import specs
    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        cells = list(specs.all_cells(include_skips=True))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    failures = []
    if args.probe:
        ptag = f"probe__{args.tag}" if args.tag else "probe"
        for arch, shape in cells:
            f = out_dir / f"{arch}__{shape}__single__{ptag}.json"
            if args.skip_existing and f.exists():
                continue
            print(f"=== probe {arch} / {shape} ===", flush=True)
            try:
                probe_cell(arch, shape, out_dir, test=args.test,
                           plan_kw=plan_kw, tag=ptag)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, "probe", repr(e)))
        if failures:
            print(f"[dryrun] {len(failures)} PROBE FAILURES: {failures}",
                  flush=True)
            sys.exit(1)
        print("[dryrun] all probes ok", flush=True)
        return

    for arch, shape in cells:
        for mk in meshes:
            suffix = f"__{args.tag}" if args.tag else ""
            f = out_dir / f"{arch}__{shape}__{mk}{suffix}.json"
            if args.skip_existing and f.exists():
                st = json.loads(f.read_text()).get("status")
                if st in ("ok", "skipped"):
                    print(f"[dryrun] skip existing {f}", flush=True)
                    continue
            print(f"=== {arch} / {shape} / {mk} ===", flush=True)
            try:
                rec = run_cell(arch, shape, mk, out_dir, test=args.test,
                               plan_kw=plan_kw, tag=args.tag)
                print(f"[dryrun] {rec['status']}", flush=True)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mk, repr(e)))
                _dump(out_dir, arch, shape, mk,
                      {"arch": arch, "shape": shape, "mesh": mk,
                       "status": "error", "error": repr(e)}, args.tag)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:", flush=True)
        for f in failures:
            print("   ", f, flush=True)
        sys.exit(1)
    print("[dryrun] all cells ok", flush=True)


if __name__ == "__main__":
    main()
