"""Production mesh construction (spec: MULTI-POD DRY-RUN step 1).

`make_production_mesh` is a FUNCTION so importing this module never touches
jax device state.  Single pod: (data=16, model=16) = 256 chips (TPU v5e
pod); multi-pod: (pod=2, data=16, model=16) = 512 chips with the leading
axis crossing the DCN/ICI pod boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI on 8 host devices (same axis names)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(n_replicas: int = 1, n_data: int | None = None):
    """(replica, data) mesh for the search serving stack.

    Thin alias over :func:`repro.engine.replicated.replica_mesh` so launch
    scripts can build serving meshes without importing engine internals;
    ``n_data=None`` spreads the data axis over the remaining local devices.
    """
    from repro.engine.replicated import replica_mesh

    return replica_mesh(n_replicas, n_data)
