"""Deterministic, shardable, RESUMABLE token pipeline.

Trajectories are tokenized as Morton cell sequences (zorder.py) — the
paper-native way to turn spatial data into LM training data — plus a
synthetic-corpus mode for the generic archs.  The iterator state is two
integers (epoch, cursor) checkpointed with the train state, so restarts
(including elastic restarts on a different data-shard count) resume exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core import zorder

BOS = 0
EOS = 1
SPECIALS = 64


@dataclasses.dataclass
class PipelineState:
    epoch: int = 0
    cursor: int = 0

    def as_dict(self):
        return {"epoch": self.epoch, "cursor": self.cursor}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["epoch"]), int(d["cursor"]))


def tokenize_trajectory(pts: np.ndarray, lo, hi, theta: int) -> np.ndarray:
    """Trajectory -> BOS + Morton cell ids (+SPECIALS offset) + EOS."""
    import jax.numpy as jnp
    ids = np.asarray(zorder.cell_ids(jnp.asarray(pts), jnp.asarray(lo),
                                     jnp.asarray(hi), theta))
    # collapse runs (vehicle lingering in one cell)
    keep = np.ones(len(ids), bool)
    keep[1:] = ids[1:] != ids[:-1]
    ids = ids[keep] + SPECIALS
    return np.concatenate([[BOS], ids, [EOS]]).astype(np.int32)


class TokenPipeline:
    """Packs documents into fixed-length (tokens, labels) batches.

    Deterministic given (docs, seq_len, batch, seed); `state` makes it
    resumable; `shard(i, n)` restricts to a host shard for multi-host input
    feeding (each host feeds its slice of the global batch).
    """

    def __init__(self, docs: list[np.ndarray], seq_len: int, batch: int,
                 *, seed: int = 0, state: PipelineState | None = None):
        self.docs = docs
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.state = state or PipelineState()
        self._stream = self._make_stream()

    def _order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(len(self.docs))

    def _make_stream(self) -> Iterator[np.ndarray]:
        """Infinite token stream, starting at the checkpointed cursor."""
        while True:
            order = self._order(self.state.epoch)
            while self.state.cursor < len(order):
                doc = self.docs[order[self.state.cursor]]
                self.state.cursor += 1
                yield doc
            self.state.epoch += 1
            self.state.cursor = 0

    def next_batch(self) -> dict:
        need = self.batch * (self.seq_len + 1)
        buf = np.empty((0,), np.int32)
        while buf.size < need:
            buf = np.concatenate([buf, next(self._stream)])
        buf = buf[:need].reshape(self.batch, self.seq_len + 1)
        return {"tokens": buf[:, :-1].copy(),
                "labels": buf[:, 1:].copy()}


def synthetic_corpus(n_docs: int, vocab: int, *, seed: int = 0,
                     doc_len=(64, 512)) -> list[np.ndarray]:
    """Zipf-ish synthetic documents for the non-spatial archs."""
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(*doc_len))
        toks = rng.zipf(1.3, n) % (vocab - SPECIALS) + SPECIALS
        docs.append(np.concatenate([[BOS], toks, [EOS]]).astype(np.int32))
    return docs
