"""Seeded synthetic spatial data repositories.

Mimics the paper's six repositories (Table I) at laptop scale: clustered
POI-like sets (MultiOpen), taxi-trajectory-like random walks (T-drive /
Porto / Chicago), and higher-dimensional variants (Argoverse 3d,
Chicago 11d).  Deterministic per seed — the benchmark harness and tests
regenerate identical repositories.
"""
from __future__ import annotations

import numpy as np


def poi_repository(n_datasets: int, *, seed: int = 0, d: int = 2,
                   n_points=(50, 800), outlier_frac: float = 0.01,
                   space: float = 100.0):
    """Gaussian-cluster datasets (MultiOpen-like) + GPS-failure outliers."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_datasets):
        n = int(rng.integers(*n_points))
        k = int(rng.integers(1, 4))
        centers = rng.uniform(0, space, (k, d))
        scales = rng.uniform(0.3, 3.0, k)
        idx = rng.integers(0, k, n)
        pts = centers[idx] + rng.normal(size=(n, d)) * scales[idx, None]
        n_out = int(np.ceil(n * outlier_frac)) if rng.random() < 0.5 else 0
        if n_out:
            # paper Sec. I: failed-GPS points pinned at [0, 0] or far away
            bad = np.zeros((n_out, d))
            if rng.random() < 0.5:
                bad = rng.uniform(3 * space, 5 * space, (n_out, d))
            pts = np.concatenate([pts, bad])
        out.append(pts.astype(np.float32))
    return out


def trajectory_repository(n_datasets: int, *, seed: int = 0,
                          n_points=(100, 1000), space: float = 100.0,
                          step: float = 0.5, d: int = 2):
    """Random-walk trajectories (T-drive / Porto-like)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_datasets):
        n = int(rng.integers(*n_points))
        start = rng.uniform(0, space, d)
        steps = rng.normal(scale=step, size=(n, d))
        drift = rng.normal(scale=step * 0.2, size=d)
        pts = start + np.cumsum(steps + drift, axis=0)
        out.append(np.clip(pts, 0, space).astype(np.float32))
    return out


def highdim_repository(n_datasets: int, *, seed: int = 0, d: int = 11,
                       n_points=(50, 500), space: float = 100.0):
    """Chicago-like: 2 spatial dims + (d-2) attribute dims."""
    rng = np.random.default_rng(seed)
    base = poi_repository(n_datasets, seed=seed, d=2, n_points=n_points,
                          space=space, outlier_frac=0.0)
    out = []
    for pts in base:
        attrs = rng.normal(size=(pts.shape[0], d - 2)).astype(np.float32)
        out.append(np.concatenate([pts, attrs], axis=1))
    return out


REPOSITORIES = {
    "multiopen": lambda m, seed=0: poi_repository(m, seed=seed),
    "tdrive": lambda m, seed=1: trajectory_repository(m, seed=seed),
    "porto": lambda m, seed=2: trajectory_repository(
        m, seed=seed, n_points=(60, 400)),
    "argoverse": lambda m, seed=3: highdim_repository(m, seed=seed, d=3),
    "chicago": lambda m, seed=4: highdim_repository(m, seed=seed, d=11),
    "shapenet": lambda m, seed=5: poi_repository(
        m, seed=seed, d=3, outlier_frac=0.0),
}
