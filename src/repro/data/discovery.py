"""Spadas-driven data discovery feeding the training pipeline.

This is where the paper's system becomes a first-class feature of the
training framework: given a data lake (repository of spatial datasets) and
an exemplar, the curator

  1. builds the unified index (outlier removal included),
  2. runs top-k exemplar search (Hausdorff / GBO) to select training shards,
  3. DEDUPLICATES the selection with pairwise approximate Hausdorff
     (2-eps guarantee — near-duplicate shards poison LM training),
  4. tokenizes the survivors into the TokenPipeline.
"""
from __future__ import annotations

import numpy as np

from repro.core import search, zorder
from repro.core.build import build_query_index, build_repository
from repro.data import tokens as tok


def curate(
    datasets: list[np.ndarray],
    exemplar: np.ndarray,
    *,
    k: int = 32,
    theta: int = 6,
    metric: str = "hausdorff",
    dedup_eps_cells: float = 1.0,
    leaf_capacity: int = 16,
):
    """Select k exemplar-similar datasets, then drop near-duplicates.

    Returns (selected dataset indices, info dict)."""
    repo, info = build_repository(datasets, leaf_capacity=leaf_capacity,
                                  theta=theta)
    q_idx, q_sig = build_query_index(
        exemplar, leaf_capacity=leaf_capacity, theta=theta,
        space_lo=repo.space_lo, space_hi=repo.space_hi)

    if metric == "hausdorff":
        vals, ids, stats = search.topk_hausdorff(repo, q_idx, k)
        info["search_stats"] = stats._asdict()
    elif metric == "gbo":
        vals, ids = search.topk_gbo(repo, q_sig, k)
    else:
        raise ValueError(metric)
    ids = [int(i) for i in np.asarray(ids) if int(i) < len(datasets)]

    # near-duplicate removal with the 2-eps approximate Hausdorff
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, theta))
    eps *= dedup_eps_cells
    kept: list[int] = []
    import jax
    for i in ids:
        dup = False
        di = jax.tree.map(lambda x: x[i], repo.ds_index)
        for j in kept:
            dj = jax.tree.map(lambda x: x[j], repo.ds_index)
            h_ij = float(search.hausdorff_pair_approx(di, dj, eps))
            h_ji = float(search.hausdorff_pair_approx(dj, di, eps))
            if max(h_ij, h_ji) <= 4 * eps:   # sym-Hausdorff near-dup
                dup = True
                break
        if not dup:
            kept.append(i)
    info["selected"] = kept
    info["deduped_away"] = len(ids) - len(kept)
    return kept, repo, info


def pipeline_from_selection(
    datasets: list[np.ndarray], selected: list[int], repo,
    *, theta: int = 6, seq_len: int = 256, batch: int = 8, seed: int = 0,
) -> tok.TokenPipeline:
    docs = [
        tok.tokenize_trajectory(datasets[i], repo.space_lo, repo.space_hi,
                                theta)
        for i in selected
    ]
    return tok.TokenPipeline(docs, seq_len, batch, seed=seed)
