"""End-to-end unified index construction (paper Alg. 1).

`build_repository` is the public entry point: raw point sets in, a fully
populated :class:`Repository` out — bottom-level balanced ball trees,
parameter-free outlier removal, z-order signatures, upper-level tree.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core import outliers as outliers_lib
from repro.core import repo_index as repo_lib
from repro.core import zorder
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository

Array = jax.Array


def pad_batch(datasets: Sequence[np.ndarray], leaf_capacity: int,
              depth: int | None = None) -> tuple[Array, Array, int]:
    """Pad a ragged list of (n_i, d) arrays into (B, n_pad, d) + valid."""
    d = datasets[0].shape[1]
    n_max = max(int(x.shape[0]) for x in datasets)
    if depth is None:
        depth = index_lib.depth_for(n_max, leaf_capacity)
    n_pad = leaf_capacity * (1 << depth)
    B = len(datasets)
    pts = np.zeros((B, n_pad, d), np.float32)
    val = np.zeros((B, n_pad), bool)
    for i, x in enumerate(datasets):
        n = x.shape[0]
        pts[i, :n] = x
        val[i, :n] = True
    return jnp.asarray(pts), jnp.asarray(val), depth


def build_repository(
    datasets: Sequence[np.ndarray],
    *,
    leaf_capacity: int = 16,
    repo_leaf_capacity: int | None = None,
    theta: int = 5,
    remove_outliers: bool = True,
) -> tuple[Repository, dict]:
    """Construct the unified index over a repository of raw point sets.

    Returns (repository, info) where info carries the outlier threshold and
    shape bookkeeping used by benchmarks.
    """
    if repo_leaf_capacity is None:
        repo_leaf_capacity = leaf_capacity
    pts, val, depth_b = pad_batch(datasets, leaf_capacity)
    B = pts.shape[0]

    idx = index_lib.build_index_batch(pts, val, depth_b)

    r_prime = None
    if remove_outliers:
        idx, r_prime = outliers_lib.remove_outliers(idx)

    # global space bounds (for the Def. 4 grid) from live points
    root_lo = idx.box_lo[:, 0, :2]
    root_hi = idx.box_hi[:, 0, :2]
    space_lo = jnp.min(root_lo, axis=0)
    space_hi = jnp.max(root_hi, axis=0)

    # z-order signatures (Def. 5) per dataset
    sig_fn = jax.vmap(
        lambda p, v: zorder.signature(p, v, space_lo, space_hi, theta)
    )
    ds_sigs = sig_fn(idx.points, idx.valid)

    # pad the repository to B_pad slots
    depth_u = repo_lib.depth_for_repo(B, repo_leaf_capacity)
    B_pad = repo_leaf_capacity * (1 << depth_u)
    d = pts.shape[-1]
    W = ds_sigs.shape[-1]

    def pad_to(x, fill=0):
        pad = [(0, B_pad - B)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pad, constant_values=fill)

    idx = DatasetIndex(*[pad_to(f) for f in idx])
    ds_sigs = pad_to(ds_sigs)
    ds_valid = jnp.zeros((B_pad,), bool).at[:B].set(True)

    centers = idx.centers[:, 0, :]
    radii = idx.radii[:, 0]
    lo = jnp.where(ds_valid[:, None], idx.box_lo[:, 0, :], jnp.inf)
    hi = jnp.where(ds_valid[:, None], idx.box_hi[:, 0, :], -jnp.inf)

    repo = repo_lib.build_repo_index(
        centers, radii, lo, hi, ds_sigs, ds_valid, depth_u
    )

    repository = Repository(
        ds_index=idx,
        ds_sigs=ds_sigs,
        ds_valid=ds_valid,
        repo=repo,
        space_lo=space_lo,
        space_hi=space_hi,
    )
    info = {
        "bottom_depth": depth_b,
        "upper_depth": depth_u,
        "n_datasets": B,
        "n_slots": B_pad,
        "outlier_threshold": r_prime,
        "theta": theta,
        "leaf_capacity": leaf_capacity,
    }
    return repository, info


def build_query_index(
    points: np.ndarray, *, leaf_capacity: int = 16, theta: int = 5,
    space_lo=None, space_hi=None,
) -> tuple[DatasetIndex, Array | None]:
    """Index a single query dataset Q (no outlier removal: Q is the user's
    exemplar, paper Section VI treats it as-is)."""
    pts, valid, depth = index_lib.pad_points(jnp.asarray(points, jnp.float32),
                                             leaf_capacity)
    q_idx = index_lib.build_index(pts, valid, depth)
    q_sig = None
    if space_lo is not None:
        q_sig = zorder.signature(q_idx.points, q_idx.valid,
                                 space_lo, space_hi, theta)
    return q_idx, q_sig
