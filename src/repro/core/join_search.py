"""Joinable dataset search: grid-cell overlap / coverage over the repository.

The resemblance ops (Hausdorff / IA / GBO) rank repository datasets by how
*similar* they are to the query; the companion joinable-search formulation
(arXiv 2311.13383) ranks them by how well they *join* with it on a shared
spatial grid:

  overlap(Q, D)  = |cells(Q) ∩ cells(D)|      distinct grid cells occupied
                                              by both datasets
  coverage(Q, D) = |{p ∈ Q : cell(p) ∈ cells(D)}|
                                              query points landing in cells
                                              D occupies

Both are exact **integers**, which buys the bit-identity bar for free: any
schedule (local / sharded / replicated, kernel or reference popcount path)
produces the same counts, so prune decisions and final rankings agree
everywhere without a float guard.

Join resolution vs stored resolution
------------------------------------
Scores are defined on a *fine* grid at ``theta_f = theta_c + FINE_DELTA``
where ``theta_c`` is the resolution of the resident coarse signatures
(derived from their word count, so it tracks whatever the repository was
built with).  Each coarse cell tiles into ``R2 = 4**FINE_DELTA`` fine
cells.  Fine signatures are never stored — they are built on the fly from
resident points, which is exactly what makes the bound phase matter.

Bounds (the Eq.-4 shape, adapted to set counts)
-----------------------------------------------
From the resident coarse signature of a slot D we get sound upper bounds
without touching D's points:

  UB_overlap(Q, D)  = min(R2 · |coarse(Q) ∩ coarse(D)|, |fine(Q)|)
      every common fine cell lies inside a common coarse cell, and each
      coarse cell contains at most R2 fine cells;
  UB_coverage(Q, D) = Σ_c hist_c(Q)[c] · occ(D)[c]
      (# query points in D-occupied *coarse* cells — every covered point's
      fine cell sits inside a D-occupied coarse cell).

The same bounds evaluated on the upper tree's OR-union node signatures
bound every descendant slot (unions only grow popcounts), giving the
multi-level frontier accounting reported as ``nodes_evaluated``; the
per-slot bound is uniformly tighter, so it is the one that drives the
actual pruning.

Refine (shared-order chunked loop)
----------------------------------
Exact scoring processes slots in ONE shared order — descending
max-over-the-batch UB — in chunks: each chunk's fine signatures are built
once from resident points and scored against the whole query batch as a
dense (B, chunk) popcount block (the set-intersect kernel path).  Each
query maintains τ_b = k-th largest exact score seen so far (globally
reduced when sharded); a slot is pruned iff UB < τ, and the loop stops
when no query's remaining suffix-max UB reaches its τ.

Soundness: τ is the k-th largest of an evaluated *subset*, hence ≤ the
true k-th value, so a pruned slot (score ≤ UB < τ) is strictly below the
k-th and can never enter the top-k even under smallest-index tie-breaks;
conversely every true top-k member has UB ≥ score ≥ τ at all times and is
always evaluated.  Results are therefore schedule-independent; only the
``exact_evaluations`` counter (and the pruned fraction derived from it)
depends on chunking/sharding, same contract as ExactHaus.

Coverage rides the popcount kernel via **bit-plane decomposition**: the
per-cell point-count histogram of Q is sliced into P = ceil(log2(n+1))
bit planes packed like signatures, and

  coverage = Σ_p 2^p · |plane_p(Q) ∩ occ(D)|

so one (B·P, S) set-intersect matrix answers the whole batch.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, zorder
from repro.core.repo_index import Repository
from repro.core.search import SearchStats
from repro.kernels import ops

#: fine grid refinement below the stored coarse resolution:
#: theta_f = theta_c + FINE_DELTA, R2 = 4**FINE_DELTA fine cells per coarse
FINE_DELTA = 2

MODES = ("overlap", "coverage")


def theta_of_words(n_words: int) -> int:
    """Grid resolution theta whose signature packs into `n_words` uint32."""
    return int(math.log2(n_words * zorder.WORD_BITS)) // 2


def join_thetas(repo: Repository) -> tuple[int, int]:
    """(coarse, fine) grid resolutions for joinable scoring on `repo`."""
    theta_c = theta_of_words(repo.ds_sigs.shape[-1])
    return theta_c, theta_c + FINE_DELTA


def num_planes(n_points: int) -> int:
    """Bit planes needed for per-cell counts of an n-point histogram."""
    return max(1, int(n_points).bit_length())


def hist_planes(points, valid, lo, hi, theta: int, n_planes: int):
    """Per-cell point-count histogram packed as bit planes.

    Returns (n_planes, W) uint32 where word-bit (p, c) is bit p of the
    number of valid points quantized into grid cell c — i.e. plane_p of
    the histogram, packed exactly like a z-order signature so popcount
    machinery applies unchanged.
    """
    n_cells = zorder.num_cells(theta)
    w = zorder.num_words(theta)
    ids = zorder.cell_ids(points, lo, hi, theta)
    ids = jnp.where(valid, ids, n_cells)        # park padding in overflow
    hist = jnp.zeros((n_cells + 1,), jnp.int32).at[ids].add(1)[:n_cells]
    bits = (hist[None, :] >> jnp.arange(n_planes, dtype=jnp.int32)[:, None]) & 1
    bits = bits.astype(jnp.uint32).reshape(n_planes, w, zorder.WORD_BITS)
    shifts = jnp.arange(zorder.WORD_BITS, dtype=jnp.uint32)
    return jax.lax.reduce(bits << shifts[None, None, :], np.uint32(0),
                          jax.lax.bitwise_or, (2,))


def _plane_dot(planes, sigs):
    """Σ_p 2^p · popcount(plane_p ∧ sig) — pure-jnp small-matrix form.

    planes (B, P, W) vs sigs (N, W) -> (B, N) int32.  Used for the upper
    tree's per-level node bounds, where N is tiny; the (B, S) slot-matrix
    passes go through :func:`repro.kernels.ops.plane_weighted_intersect`
    instead so they ride the set-intersect kernel routing.
    """
    cnt = jax.lax.population_count(
        planes[:, :, None, :] & sigs[None, None, :, :])
    cnt = cnt.astype(jnp.int32).sum(axis=-1)                   # (B, P, N)
    weights = jnp.left_shift(jnp.int32(1),
                             jnp.arange(planes.shape[1], dtype=jnp.int32))
    return (cnt * weights[None, :, None]).sum(axis=1)


def query_features(q_pts, q_val, lo, hi, theta_c: int, theta_f: int,
                   mode: str):
    """Per-query grid features: coarse/fine signatures (+ planes for
    coverage).  Returns a dict of batched arrays."""
    sig_c = jax.vmap(lambda p, v: zorder.signature(p, v, lo, hi, theta_c))
    sig_f = jax.vmap(lambda p, v: zorder.signature(p, v, lo, hi, theta_f))
    feats = {"csig": sig_c(q_pts, q_val), "fsig": sig_f(q_pts, q_val)}
    feats["fcnt"] = zorder.sig_count(feats["fsig"]).astype(jnp.int32)
    if mode == "coverage":
        n_p = num_planes(q_pts.shape[-2])
        feats["cplanes"] = jax.vmap(
            lambda p, v: hist_planes(p, v, lo, hi, theta_c, n_p))(q_pts, q_val)
        feats["fplanes"] = jax.vmap(
            lambda p, v: hist_planes(p, v, lo, hi, theta_f, n_p))(q_pts, q_val)
    return feats


def _slot_bounds(repo, feats, mode: str, r2: int):
    """Per-slot upper bounds from resident coarse signatures: (B, S) int32
    with -1 in invalid (padding / deleted / shard-pad) slots."""
    if mode == "overlap":
        ub = ops.set_intersect_counts(feats["csig"], repo.ds_sigs) * r2
        ub = jnp.minimum(ub, feats["fcnt"][:, None])
    else:
        ub = ops.plane_weighted_intersect(feats["cplanes"], repo.ds_sigs)
    return jnp.where(repo.ds_valid[None, :], ub, -1)


def _node_frontier(repo, feats, tau, mode: str, r2: int):
    """Eq.-4-style multi-level accounting: per-query count of upper-tree
    nodes a bound-driven frontier descent at threshold τ would expand.
    The upper tree is replicated on every shard, so this is collective-free
    and identical across dispatchers."""
    up = repo.repo
    floor = jnp.maximum(tau, 0)[:, None]
    active = jnp.ones((tau.shape[0], 1), bool)
    nodes = jnp.zeros(tau.shape, jnp.int32)
    for level in range(up.depth + 1):
        sl = up.level_slice(level)
        sg = up.sigs[sl]
        if mode == "overlap":
            ubn = zorder.sig_intersect_count(
                feats["csig"][:, None, :], sg[None, :, :]) * r2
            ubn = jnp.minimum(ubn, feats["fcnt"][:, None])
        else:
            ubn = _plane_dot(feats["cplanes"], sg)
        live = active & (ubn >= floor) & (up.counts[sl] > 0)[None, :]
        nodes = nodes + live.sum(axis=-1).astype(jnp.int32)
        if level < up.depth:
            active = jnp.repeat(live, 2, axis=1)
    return nodes


def slot_fine_sigs(points, valid, lo, hi, theta_f: int):
    """Fine signatures for a batch of resident slot point sets."""
    return jax.vmap(
        lambda p, v: zorder.signature(p, v, lo, hi, theta_f))(points, valid)


def topk_join_scores(repo, q_pts, q_val, k: int, mode: str, chunk: int,
                     *, axis=None, n_slots_total=None):
    """Bound phase + shared-order chunked exact refine over the (local
    slice of the) repository.

    Returns ``(exact, nodes, cand_after, evaluated)``:
      exact       (B, S) int32 — exact join score, or -1 where the slot is
                  invalid or was pruned by the bounds (pruned slots are
                  provably outside every query's top-k, see module doc);
      nodes       (B,) multi-level frontier accounting at τ_final;
      cand_after  (B,) slots whose UB survives τ_final (globally summed
                  when `axis` is set);
      evaluated   (B,) exact evaluations actually performed (global).

    With ``axis`` set the caller runs this inside shard_map over the slot
    axis; τ and the continue flag are reduced collectively so every shard
    runs the same number of iterations.
    """
    assert mode in MODES, mode
    lo, hi = repo.space_lo, repo.space_hi
    theta_c, theta_f = join_thetas(repo)
    r2 = 1 << (2 * FINE_DELTA)
    B = q_pts.shape[0]
    S = repo.n_slots
    feats = query_features(q_pts, q_val, lo, hi, theta_c, theta_f, mode)

    ub = _slot_bounds(repo, feats, mode, r2)                   # (B, S)

    # one shared processing order for the whole batch (descending
    # max-over-queries UB): each chunk's fine signatures are then built
    # ONCE from resident points and scored against every query
    order = jnp.argsort(-jnp.max(ub, axis=0), stable=True)
    n_chunks = max(1, -(-S // chunk))
    s_pad = n_chunks * chunk
    order_p = jnp.pad(order, (0, s_pad - S))
    ub_sorted = jnp.where((jnp.arange(s_pad) < S)[None, :],
                          jnp.take(ub, order_p, axis=1), -1)
    chunk_max = ub_sorted.reshape(B, n_chunks, chunk).max(axis=-1)
    # suffix max over chunks: the best UB any later slot can offer
    suff = jnp.flip(jax.lax.cummax(jnp.flip(chunk_max, axis=-1), axis=1),
                    axis=-1)                                   # (B, n_chunks)

    ds_pts, ds_val = repo.ds_index.points, repo.ds_index.valid
    k_eff = min(k, S)

    def tau_update(exact, tau_c):
        fin = exact >= 0
        if axis is None:
            kth = jax.lax.top_k(exact, k_eff)[0][..., k_eff - 1]
            n_fin = fin.sum(axis=-1)
        else:
            kth = -distributed.global_kth_smallest(-exact, k, axis)
            n_fin = jax.lax.psum(fin.sum(axis=-1).astype(jnp.int32), axis)
        # only a FULL top-k of true scores may raise τ (k-th largest of an
        # evaluated subset ≤ true k-th value, so pruning stays sound);
        # with fewer than k evaluated the -1 fill would leak in
        return jnp.maximum(tau_c, jnp.where(n_fin >= k, kth, -1))

    def need(pos, tau_c):
        sm = jax.lax.dynamic_slice_in_dim(
            suff, jnp.minimum(pos, n_chunks - 1), 1, axis=1)[:, 0]
        # valid slots always have UB >= 0, so flooring τ at 0 both skips
        # invalid-only suffixes and keeps every unpruned valid slot
        return (pos < n_chunks) & (sm >= jnp.maximum(tau_c, 0))

    def reduce_any(g):
        flag = jnp.any(g)
        if axis is None:
            return flag
        return jax.lax.psum(flag.astype(jnp.int32), axis) > 0

    def body(carry):
        _, pos, exact, tau_c, evaluated = carry
        nb = need(pos, tau_c)                                  # (B,)
        go = jnp.any(nb)
        idx = pos * chunk + jnp.arange(chunk)
        ids = jnp.take(order_p, idx, mode="clip")
        sigs = slot_fine_sigs(ds_pts[ids], ds_val[ids], lo, hi, theta_f)
        if mode == "overlap":
            sc = ops.set_intersect_counts(feats["fsig"], sigs)
        else:
            sc = ops.plane_weighted_intersect(feats["fplanes"], sigs)
        live = ((idx < S) & jnp.take(repo.ds_valid, ids, mode="clip")
                )[None, :] & nb[:, None] & go
        sc = jnp.where(live, sc, -1)
        exact = exact.at[:, ids].max(sc)       # clipped dup ids carry -1
        evaluated = evaluated + live.sum(axis=-1).astype(jnp.int32)
        pos = jnp.where(go, pos + 1, pos)
        tau_c = tau_update(exact, tau_c)
        return (reduce_any(need(pos, tau_c)), pos, exact, tau_c, evaluated)

    tau0 = jnp.full((B,), -1, jnp.int32)
    init = (reduce_any(need(jnp.int32(0), tau0)), jnp.int32(0),
            jnp.full((B, S), -1, jnp.int32), tau0,
            jnp.zeros((B,), jnp.int32))
    if axis is not None:
        # same XLA CPU hazard as ExactHaus phase 2: without the barrier the
        # loop-entry computation fuses across the shard_map boundary and
        # miscompiles at some shard counts
        init = jax.lax.optimization_barrier(init)
    _, _, exact, tau_f, evaluated = jax.lax.while_loop(
        lambda c: c[0], body, init)

    cand = ((ub >= jnp.maximum(tau_f, 0)[:, None]) & (ub >= 0)
            ).sum(axis=-1).astype(jnp.int32)
    if axis is not None:
        cand = jax.lax.psum(cand, axis)
        evaluated = jax.lax.psum(evaluated, axis)
    nodes = _node_frontier(repo, feats, tau_f, mode, r2)
    return exact, nodes, cand, evaluated


def pair_scores(repo, d_points, d_valid, q_pts, q_val, mode: str):
    """Row-wise exact join score between query row t and slot points row t.

    Used by the dataset→dataset pipeline stage: stage-1 winner slots are
    gathered on device and re-scored against the pipeline's own query set.
    Returns (T,) int32 (≥ 0; the caller masks sentinel rows)."""
    assert mode in MODES, mode
    lo, hi = repo.space_lo, repo.space_hi
    _, theta_f = join_thetas(repo)
    d_sigs = slot_fine_sigs(d_points, d_valid, lo, hi, theta_f)
    if mode == "overlap":
        q_sigs = jax.vmap(
            lambda p, v: zorder.signature(p, v, lo, hi, theta_f))(q_pts, q_val)
        return zorder.sig_intersect_count(q_sigs, d_sigs)
    n_p = num_planes(q_pts.shape[-2])
    planes = jax.vmap(
        lambda p, v: hist_planes(p, v, lo, hi, theta_f, n_p))(q_pts, q_val)
    cnt = jax.lax.population_count(planes & d_sigs[:, None, :])
    cnt = cnt.astype(jnp.int32).sum(axis=-1)                   # (T, P)
    weights = jnp.left_shift(jnp.int32(1), jnp.arange(n_p, dtype=jnp.int32))
    return (cnt * weights[None, :]).sum(axis=-1)


# ---------------------------------------------------------------------------
# host oracle
# ---------------------------------------------------------------------------


def _host_cells(points, valid, lo, hi, theta: int):
    """Fine-grid cell id per valid point (host numpy array)."""
    ids = np.asarray(zorder.cell_ids(jnp.asarray(points), lo, hi, theta))
    return ids[np.asarray(valid)]


def topk_join_host(repo: Repository, pointsets, k: int, mode: str):
    """Brute-force joinable top-k oracle over the resident repository.

    Scores every valid slot with plain Python set arithmetic on the shared
    grid assignment, ranks descending with ties toward the smaller slot id
    (the `lax.top_k` rule), and sentinels rows past the valid supply.
    Returns (vals (B, k), ids (B, k)) int32 numpy arrays.
    """
    assert mode in MODES, mode
    lo, hi = repo.space_lo, repo.space_hi
    _, theta_f = join_thetas(repo)
    d_pts = np.asarray(repo.ds_index.points)
    d_val = np.asarray(repo.ds_index.valid)
    slot_valid = np.asarray(repo.ds_valid)
    S = d_pts.shape[0]
    d_cells = [set(_host_cells(d_pts[s], d_val[s], lo, hi, theta_f).tolist())
               if slot_valid[s] else set() for s in range(S)]

    vals = np.full((len(pointsets), k), -1, np.int32)
    ids = np.full((len(pointsets), k), -1, np.int32)
    for b, q in enumerate(pointsets):
        q = np.asarray(q, np.float32)
        qc = _host_cells(q, np.ones(len(q), bool), lo, hi, theta_f)
        q_cells = set(qc.tolist())
        scores = np.full((S,), -1, np.int64)
        for s in range(S):
            if not slot_valid[s]:
                continue
            if mode == "overlap":
                scores[s] = len(q_cells & d_cells[s])
            else:
                scores[s] = sum(int(c) in d_cells[s] for c in qc.tolist())
        top = np.argsort(-scores, kind="stable")[:k]
        t = len(top)
        vals[b, :t] = scores[top]
        ids[b, :t] = np.where(vals[b, :t] < 0, -1, top)
    return vals, ids


def join_stats_host(n_valid: int, evaluated, nodes, cand):
    """Fold device counters into per-query SearchStats rows (the ExactHaus
    shape: pruned fraction = share of valid slots never exact-scored)."""
    out = []
    for e, n, c in zip(np.asarray(evaluated), np.asarray(nodes),
                       np.asarray(cand)):
        out.append(SearchStats(
            nodes_evaluated=int(n),
            candidates_after_bounds=int(c),
            exact_evaluations=int(e),
            pruned_fraction=float(1.0 - int(e) / max(n_valid, 1)),
        ))
    return out
