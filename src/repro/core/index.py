"""Bottom-level unified index (paper Section V-A), TPU-native form.

The paper builds, per dataset, a binary tree by recursively splitting on the
widest dimension (Alg. 1 `SplitSpace`).  Pointer trees do not jit, so we
build a LEFT-BALANCED tree over a permutation of the points (DESIGN.md
sec. 2):

  * points are padded to ``n_pad = f * 2**depth`` with a validity mask;
  * the build is level-synchronous: at level ``l`` the permutation is viewed
    as ``(2**l, n_pad >> l)`` segments, each segment picks its widest
    dimension (same criterion as the paper) and is partitioned by the median
    of that coordinate (balanced) via one segmented argsort;
  * after ``depth`` levels every leaf is a CONTIGUOUS slab of ``f`` slots,
    and node ``j`` of level ``l`` covers slab ``[j * (n_pad >> l), ...)``.

Node statistics (ball center/radius Def. 14, MBR) are computed for every
node of every level with segmented reductions and stored flat in level-major
order: node (l, j) lives at ``2**l - 1 + j``.

Everything is vmap-able over a leading batch of datasets, which is how the
repository pads + batches datasets of different cardinalities.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import geometry

Array = jax.Array


class DatasetIndex(NamedTuple):
    """Flat balanced ball tree over one (or a batch of) dataset(s).

    With a batch dim B (absent when built for a single dataset):
      points   (B, n_pad, d)   points permuted into tree order
      valid    (B, n_pad)      slot validity (padding and removed outliers)
      centers  (B, n_nodes, d) ball centers, level-major
      radii    (B, n_nodes)    ball radii
      box_lo   (B, n_nodes, d) node MBRs
      box_hi   (B, n_nodes, d)
      counts   (B, n_nodes)    live points under each node
    ``n_nodes = 2**(depth+1) - 1``; leaves are the last 2**depth entries.
    """

    points: Array
    valid: Array
    centers: Array
    radii: Array
    box_lo: Array
    box_hi: Array
    counts: Array

    @property
    def depth(self) -> int:
        return int(math.log2(self.centers.shape[-2] + 1)) - 1

    @property
    def n_leaves(self) -> int:
        return 1 << self.depth

    @property
    def leaf_size(self) -> int:
        return self.points.shape[-2] // self.n_leaves

    def level_slice(self, level: int) -> slice:
        start = (1 << level) - 1
        return slice(start, start + (1 << level))

    def root_center(self) -> Array:
        return self.centers[..., 0, :]

    def root_radius(self) -> Array:
        return self.radii[..., 0]

    def root_box(self) -> tuple[Array, Array]:
        return self.box_lo[..., 0, :], self.box_hi[..., 0, :]


def depth_for(n: int, leaf_capacity: int) -> int:
    """Tree depth so that leaves hold <= leaf_capacity points."""
    return max(0, math.ceil(math.log2(max(1, n) / leaf_capacity)))


def pad_points(points: Array, leaf_capacity: int, depth: int | None = None):
    """Pad (n, d) points to (f * 2**depth, d) plus a validity mask."""
    n, d = points.shape
    if depth is None:
        depth = depth_for(n, leaf_capacity)
    n_pad = leaf_capacity * (1 << depth)
    if n_pad < n:
        raise ValueError(f"n_pad {n_pad} < n {n}")
    pts = jnp.zeros((n_pad, d), points.dtype).at[:n].set(points)
    valid = jnp.zeros((n_pad,), bool).at[:n].set(True)
    return pts, valid, depth


# ---------------------------------------------------------------------------
# level-synchronous balanced build
# ---------------------------------------------------------------------------


def _split_level(points: Array, valid: Array, perm: Array, level: int) -> Array:
    """One level of the build: partition every segment on its widest dim.

    points (n_pad, d), valid (n_pad,), perm (n_pad,) current ordering.
    Returns the refined permutation.  Invalid slots sort to segment ends so
    padding accumulates in the rightmost leaves.
    """
    n_pad, d = points.shape
    seg = n_pad >> level
    p = points[perm].reshape(1 << level, seg, d)
    v = valid[perm].reshape(1 << level, seg)

    big = jnp.array(jnp.inf, points.dtype)
    lo = jnp.min(jnp.where(v[..., None], p, big), axis=1)          # (2^l, d)
    hi = jnp.max(jnp.where(v[..., None], p, -big), axis=1)
    width = jnp.where(jnp.isfinite(lo) & jnp.isfinite(hi), hi - lo, -big)
    d_split = jnp.argmax(width, axis=-1)                            # (2^l,)

    keys = jnp.take_along_axis(p, d_split[:, None, None], axis=-1)[..., 0]
    keys = jnp.where(v, keys, big)                                  # pad last
    order = jnp.argsort(keys, axis=-1)                              # (2^l, seg)
    return jnp.take_along_axis(perm.reshape(1 << level, seg), order, axis=-1).reshape(-1)


def _node_stats(points: Array, valid: Array, depth: int):
    """Ball + box stats for every node of every level (points in tree order)."""
    n_pad, d = points.shape
    centers, radii, blos, bhis, counts = [], [], [], [], []
    big = jnp.array(jnp.inf, points.dtype)
    for level in range(depth + 1):
        seg = n_pad >> level
        p = points.reshape(1 << level, seg, d)
        v = valid.reshape(1 << level, seg)
        w = v.astype(points.dtype)
        cnt = w.sum(axis=1)
        o = (p * w[..., None]).sum(axis=1) / jnp.maximum(cnt, 1.0)[:, None]
        d2 = jnp.sum((p - o[:, None, :]) ** 2, axis=-1)
        r = jnp.sqrt(jnp.max(jnp.where(v, d2, 0.0), axis=1))
        lo = jnp.min(jnp.where(v[..., None], p, big), axis=1)
        hi = jnp.max(jnp.where(v[..., None], p, -big), axis=1)
        # empty nodes: neutralize so bound math prunes them naturally
        empty = cnt == 0
        o = jnp.where(empty[:, None], 0.0, o)
        r = jnp.where(empty, 0.0, r)
        lo = jnp.where(empty[:, None], big, lo)
        hi = jnp.where(empty[:, None], -big, hi)
        centers.append(o)
        radii.append(r)
        blos.append(lo)
        bhis.append(hi)
        counts.append(cnt.astype(jnp.int32))
    return (
        jnp.concatenate(centers, axis=0),
        jnp.concatenate(radii, axis=0),
        jnp.concatenate(blos, axis=0),
        jnp.concatenate(bhis, axis=0),
        jnp.concatenate(counts, axis=0),
    )


def build_index(points: Array, valid: Array, depth: int) -> DatasetIndex:
    """Build the balanced ball tree for one padded dataset (jit-friendly).

    points (n_pad, d) with n_pad = f * 2**depth, valid (n_pad,).
    """
    n_pad = points.shape[0]
    perm = jnp.argsort(~valid)  # stable: valid slots first
    for level in range(depth):
        perm = _split_level(points, valid, perm, level)
    pts = points[perm]
    val = valid[perm]
    centers, radii, lo, hi, counts = _node_stats(pts, val, depth)
    return DatasetIndex(pts, val, centers, radii, lo, hi, counts)


def build_index_batch(points: Array, valid: Array, depth: int) -> DatasetIndex:
    """vmap of build_index over a leading batch of equally padded datasets."""
    return jax.vmap(lambda p, v: build_index(p, v, depth))(points, valid)


def recompute_stats(idx: DatasetIndex) -> DatasetIndex:
    """Re-derive all node stats from (points, valid) — used after outlier
    removal (paper `RefineBottomUp`) so every ball/box re-tightens."""

    def one(pts, val):
        depth = int(math.log2(idx.centers.shape[-2] + 1)) - 1
        return _node_stats(pts, val, depth)

    if idx.points.ndim == 3:
        centers, radii, lo, hi, counts = jax.vmap(one)(idx.points, idx.valid)
    else:
        centers, radii, lo, hi, counts = one(idx.points, idx.valid)
    return DatasetIndex(idx.points, idx.valid, centers, radii, lo, hi, counts)


def leaf_radii(idx: DatasetIndex) -> Array:
    """Radii of all leaf nodes (the paper's phi array feedstock)."""
    depth = idx.depth
    sl = idx.level_slice(depth)
    return idx.radii[..., sl]


def leaf_counts(idx: DatasetIndex) -> Array:
    depth = idx.depth
    sl = idx.level_slice(depth)
    return idx.counts[..., sl]
