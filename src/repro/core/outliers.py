"""Parameter-free outlier removal (paper Alg. 1 `OutlierRemoval`, Eq. 3).

The paper's observation: leaf balls that contain outliers have anomalously
large radii.  It sorts all leaf radii descending, finds the knee of the
sorted curve with a Kneedle-style gap statistic (Eq. 3) and uses the knee
radius ``r'`` as threshold.  Points farther than ``r'`` from their leaf
pivot are dropped and the tree is re-tightened bottom-up.

TPU form: the gap statistic is already a dense computation; the bottom-up
refinement becomes "clear validity bits, recompute all node stats" which is
exactly `RefineBottomUp` without pointer surgery.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import index as index_lib
from repro.core.index import DatasetIndex

Array = jax.Array


def kneedle_threshold(radii: Array, valid: Array | None = None) -> Array:
    """Paper Eq. 3 over a descending-sorted radius array.

    radii: (m,) leaf radii (any order), valid: optional mask for padded /
    empty leaves.  Returns the scalar threshold r'.
    """
    if valid is None:
        valid = jnp.ones(radii.shape, bool)
    # sort descending; invalid leaves sink to the end with radius 0
    r = jnp.where(valid, radii, 0.0)
    phi = -jnp.sort(-r)
    m = jnp.maximum(valid.sum(), 2)
    first = phi[0]
    last_idx = jnp.clip(m - 1, 0, phi.shape[0] - 1)
    last = phi[last_idx]
    i = jnp.arange(phi.shape[0], dtype=phi.dtype)
    # Eq. 3: g_i = phi[0] - i * (phi[0]-phi[-1]) / |phi| - phi[i]
    gap = first - i * (first - last) / jnp.maximum(m.astype(phi.dtype), 1.0) - phi
    gap = jnp.where(i < m, gap, -jnp.inf)
    gap = gap.at[0].set(-jnp.inf)  # knee is interior
    pos = jnp.argmax(gap)
    # paper line 41: r' = phi[pos - 1]
    return phi[jnp.maximum(pos - 1, 0)]


def remove_outliers(idx: DatasetIndex, r_prime: Array | None = None) -> tuple[DatasetIndex, Array]:
    """Drop points farther than r' from their leaf center; re-tighten stats.

    Works on a single index or a batch (leading B dim).  The threshold is
    derived from the distribution of ALL leaf radii across the batch (the
    paper pools leaf radii across the repository into one sorted array phi).

    Returns (refined index, r_prime).
    """
    leaf_r = index_lib.leaf_radii(idx).reshape(-1)
    leaf_c = index_lib.leaf_counts(idx).reshape(-1)
    if r_prime is None:
        r_prime = kneedle_threshold(leaf_r, leaf_c > 0)

    depth = idx.depth
    f = idx.leaf_size

    def leaf_centers_for(pts_shape_centers):
        sl = idx.level_slice(depth)
        return pts_shape_centers[..., sl, :]

    centers_leaf = leaf_centers_for(idx.centers)           # (..., 2^depth, d)
    # distance of every point to its leaf center
    pts = idx.points
    if pts.ndim == 3:
        B, n_pad, d = pts.shape
        pl = pts.reshape(B, -1, f, d)
        cl = centers_leaf.reshape(B, -1, 1, d)
        d2 = jnp.sum((pl - cl) ** 2, axis=-1).reshape(B, n_pad)
        leaf_rad = index_lib.leaf_radii(idx)               # (B, 2^depth)
        wide = jnp.repeat(leaf_rad, f, axis=-1)            # (B, n_pad)
    else:
        n_pad, d = pts.shape
        pl = pts.reshape(-1, f, d)
        cl = centers_leaf.reshape(-1, 1, d)
        d2 = jnp.sum((pl - cl) ** 2, axis=-1).reshape(n_pad)
        leaf_rad = index_lib.leaf_radii(idx)
        wide = jnp.repeat(leaf_rad, f, axis=-1)
    # paper: only leaves with radius > r' are refined; inside them drop
    # points with ||o, p|| > r'
    drop = (wide > r_prime) & (jnp.sqrt(d2) > r_prime)
    new_valid = idx.valid & ~drop
    refined = index_lib.recompute_stats(idx._replace(valid=new_valid))
    return refined, r_prime
