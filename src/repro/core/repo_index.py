"""Upper-level repository index (paper Section V-B).

Organizes the dataset ROOT nodes of a repository into the same balanced
ball tree used at the bottom level (DESIGN.md sec. 2).  Each upper node
stores the Def. 16 tuple: ball (o, r) bounding every POINT beneath it, the
merged MBR, the z-order signature union of its children, and a live count.

The repository is padded to ``B_pad = f_up * 2**depth_up`` dataset slots so
the whole structure is static-shape; `order` maps tree slots back to the
caller's dataset ids.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry
from repro.core import index as index_lib
from repro.core.index import DatasetIndex

Array = jax.Array


class RepoIndex(NamedTuple):
    order: Array      # (B_pad,) dataset slot -> tree position inverse map:
                      # tree slot j holds original dataset id order[j]
    ds_valid: Array   # (B_pad,) in tree order
    centers: Array    # (n_nodes, d)
    radii: Array      # (n_nodes,)
    box_lo: Array     # (n_nodes, d)
    box_hi: Array     # (n_nodes, d)
    sigs: Array       # (n_nodes, W) uint32
    counts: Array     # (n_nodes,) datasets under node

    @property
    def depth(self) -> int:
        return int(math.log2(self.centers.shape[-2] + 1)) - 1

    def level_slice(self, level: int) -> slice:
        start = (1 << level) - 1
        return slice(start, start + (1 << level))


def _or_reduce(x: Array, axis: int) -> Array:
    """Bitwise-OR reduction (no jnp ufunc.reduce in jax)."""
    return jax.lax.reduce(x, np.uint32(0), jax.lax.bitwise_or, (axis,))


def depth_for_repo(n_datasets: int, f_up: int) -> int:
    return index_lib.depth_for(n_datasets, f_up)


def build_repo_index(
    ds_centers: Array,
    ds_radii: Array,
    ds_lo: Array,
    ds_hi: Array,
    ds_sigs: Array,
    ds_valid: Array,
    depth: int,
) -> RepoIndex:
    """Build the upper tree over B_pad dataset root nodes.

    All inputs are in ORIGINAL dataset-slot order; the returned index is in
    tree order with `order` giving the permutation.
    """
    B_pad, d = ds_centers.shape
    perm = jnp.argsort(~ds_valid)
    for level in range(depth):
        perm = index_lib._split_level(ds_centers, ds_valid, perm, level)

    c = ds_centers[perm]
    r = ds_radii[perm]
    lo = ds_lo[perm]
    hi = ds_hi[perm]
    sg = ds_sigs[perm]
    v = ds_valid[perm]

    centers, radii, blos, bhis, sigs, counts = [], [], [], [], [], []
    big = jnp.array(jnp.inf, c.dtype)
    for level in range(depth + 1):
        seg = B_pad >> level
        cs = c.reshape(1 << level, seg, d)
        rs = r.reshape(1 << level, seg)
        los = lo.reshape(1 << level, seg, d)
        his = hi.reshape(1 << level, seg, d)
        sgs = sg.reshape(1 << level, seg, -1)
        vs = v.reshape(1 << level, seg)
        w = vs.astype(c.dtype)
        cnt = w.sum(axis=1)
        o = (cs * w[..., None]).sum(axis=1) / jnp.maximum(cnt, 1.0)[:, None]
        # ball must bound every point beneath: r = max(|o - o_i| + r_i)
        di = jnp.sqrt(jnp.sum((cs - o[:, None, :]) ** 2, axis=-1)) + rs
        rr = jnp.max(jnp.where(vs, di, 0.0), axis=1)
        l2 = jnp.min(jnp.where(vs[..., None], los, big), axis=1)
        h2 = jnp.max(jnp.where(vs[..., None], his, -big), axis=1)
        ss = _or_reduce(jnp.where(vs[..., None], sgs, jnp.uint32(0)), 1)
        empty = cnt == 0
        o = jnp.where(empty[:, None], 0.0, o)
        rr = jnp.where(empty, 0.0, rr)
        l2 = jnp.where(empty[:, None], big, l2)
        h2 = jnp.where(empty[:, None], -big, h2)
        centers.append(o)
        radii.append(rr)
        blos.append(l2)
        bhis.append(h2)
        sigs.append(ss)
        counts.append(cnt.astype(jnp.int32))

    return RepoIndex(
        order=perm,
        ds_valid=v,
        centers=jnp.concatenate(centers, axis=0),
        radii=jnp.concatenate(radii, axis=0),
        box_lo=jnp.concatenate(blos, axis=0),
        box_hi=jnp.concatenate(bhis, axis=0),
        sigs=jnp.concatenate(sigs, axis=0),
        counts=jnp.concatenate(counts, axis=0),
    )


class Repository(NamedTuple):
    """The full unified index: batched bottom-level trees + upper tree.

    Dataset arrays (`ds_index`, `ds_sigs`, per-dataset roots) are stored in
    ORIGINAL slot order; `repo.order` maps upper-tree slots to dataset slots.
    """

    ds_index: DatasetIndex   # batched over B_pad (original order)
    ds_sigs: Array           # (B_pad, W)
    ds_valid: Array          # (B_pad,) dataset-slot validity
    repo: RepoIndex
    space_lo: Array          # (2,) global grid bounds for z-order
    space_hi: Array          # (2,)

    @property
    def n_slots(self) -> int:
        return self.ds_sigs.shape[0]

    def roots(self):
        """Per-dataset root stats in original order."""
        return (
            self.ds_index.centers[:, 0, :],
            self.ds_index.radii[:, 0],
            self.ds_index.box_lo[:, 0, :],
            self.ds_index.box_hi[:, 0, :],
        )
