"""Distributed Spadas (DESIGN.md sec. 4): how the paper's search scales out.

Two parallel dimensions, matching the production mesh axes:

  * repository sharding over the ``data`` (and ``pod``) axis — each shard
    owns a slice of dataset slots, runs the identical batched bound pass,
    and the global top-k is an O(k) all-gather merge;
  * point sharding over the ``model`` axis for giant pairwise ops — the
    ring Hausdorff/NNP: Q rows stay resident, D shards rotate around the
    axis via collective_permute, each hop updating the running per-row min
    (the same communication shape as ring attention, so compute/comm
    overlap is native).

Every function here is written with `jax.shard_map` so the collective
schedule is explicit and shows up in the dry-run HLO for the roofline.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import geometry
from repro.kernels import ops

Array = jax.Array
BIG = 3.4e38


def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """jax.shard_map across jax versions (new API vs jax.experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def _pvary(x, axis):
    """jax.lax.pvary appeared with the vma checker; older jax is a no-op."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis)
    return x


# ---------------------------------------------------------------------------
# repository-sharded bound pass + top-k merge
# ---------------------------------------------------------------------------


def global_kth_smallest(x: Array, k: int, axis: str) -> Array:
    """kth-smallest over a vector sharded on ``axis`` — O(k) per shard.

    Inside shard_map only.  Each shard contributes its min(k, shard_slots)
    smallest entries; the union of those lists always contains the global k
    smallest (at most k - 1 values can precede any of them, globally or
    per shard), so sorting the all-gathered S * min(k, shard) candidates
    and indexing position k - 1 (clamped) selects exactly the element
    `jnp.sort(global_x)[min(k - 1, n - 1)]` would — the VALUE is the same
    float bit pattern because no arithmetic touches it, only selection.
    This is the tau reduction of sharded ExactHaus (phases 0/1 and the
    per-chunk phase-2 tightening) and mirrors the loc_ub gather in
    :func:`sharded_topk_bounds`.
    """
    k_loc = min(k, x.shape[-1])
    small = -jax.lax.top_k(-x, k_loc)[0]          # ascending k_loc smallest
    small = jax.lax.all_gather(small, axis, axis=small.ndim - 1, tiled=True)
    return jnp.sort(small)[..., min(k - 1, small.shape[-1] - 1)]


def sharded_topk_bounds(
    mesh: Mesh,
    axis: str | tuple[str, ...],
    q_center: Array,
    q_radius: Array,
    ds_centers: Array,
    ds_radii: Array,
    ds_valid: Array,
    k: int,
):
    """Phase-0 ExactHaus bound pass, repository sharded over ``axis``.

    ds_* are (B, ...) arrays sharded on their leading dim.  Returns global
    (tau, lb, ub): tau = kth-smallest UB across ALL shards (the batch-prune
    threshold), lb/ub the per-slot bounds (still sharded).
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)

    def local(qc, qr, dc, dr, dv):
        cd = jnp.sqrt(jnp.sum((dc - qc[None, :]) ** 2, axis=-1))
        lb = jnp.maximum(cd - dr, 0.0)
        ub = jnp.sqrt(cd * cd + dr * dr) + qr
        lb = jnp.where(dv, lb, BIG)
        ub = jnp.where(dv, ub, BIG)
        # local k smallest upper bounds -> O(k) gather instead of O(B)
        loc_ub = -jax.lax.top_k(-ub, k)[0]
        all_ub = loc_ub
        for ax in axes:
            all_ub = jax.lax.all_gather(all_ub, ax, tiled=True)
        tau = jnp.sort(all_ub)[k - 1]
        return tau, lb, ub

    spec_b = P(axes)
    spec_bd = P(axes, None)
    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(), spec_bd, spec_b, spec_b),
        out_specs=(P(), spec_b, spec_b),
        check_vma=False,  # tau is replicated by the all_gather merge
    )(q_center, q_radius, ds_centers, ds_radii, ds_valid)


# ---------------------------------------------------------------------------
# ring Hausdorff over the model axis
# ---------------------------------------------------------------------------


def ring_hausdorff(
    mesh: Mesh,
    axis: str,
    q: Array,
    q_valid: Array,
    d: Array,
    d_valid: Array,
    *,
    use_kernel: bool = False,
):
    """Directed Hausdorff H(Q -> D) with BOTH point sets sharded on ``axis``.

    Q rows stay put; D shards rotate around the ring.  Per-hop compute is
    the streaming min kernel on the local (Q-shard x D-shard) tile, so the
    collective_permute of the next D shard overlaps with it.  Ends with an
    all-reduce max over the axis.
    """
    n_dev = mesh.shape[axis]

    def local(q_s, qv_s, d_s, dv_s):
        def hop(i, carry):
            mins, d_cur, dv_cur = carry
            d2 = geometry.sq_dist_matrix(q_s, d_cur)
            d2 = jnp.where(dv_cur[None, :], d2, BIG)
            mins = jnp.minimum(mins, jnp.min(d2, axis=1))
            perm = [(j, (j + 1) % n_dev) for j in range(n_dev)]
            d_nxt = jax.lax.ppermute(d_cur, axis, perm)
            dv_nxt = jax.lax.ppermute(dv_cur, axis, perm)
            return mins, d_nxt, dv_nxt

        mins0 = _pvary(jnp.full((q_s.shape[0],), BIG, jnp.float32), axis)
        mins, _, _ = jax.lax.fori_loop(0, n_dev, hop, (mins0, d_s, dv_s))
        nn = jnp.sqrt(jnp.minimum(mins, BIG))
        local_h = jnp.max(jnp.where(qv_s, nn, -BIG))
        return jax.lax.pmax(local_h, axis)

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(axis, None), P(axis)),
        out_specs=P(),
    )(q, q_valid, d, d_valid)


def ring_nn_distance(
    mesh: Mesh,
    axis: str,
    q: Array,
    q_valid: Array,
    d: Array,
    d_valid: Array,
):
    """Ring NNP: per-Q-row global NN distance + index, both sets sharded."""
    n_dev = mesh.shape[axis]
    shard_d = d.shape[0] // n_dev

    def local(q_s, qv_s, d_s, dv_s):
        my = jax.lax.axis_index(axis)

        def hop(i, carry):
            mins, args, d_cur, dv_cur = carry
            owner = (my + i) % n_dev  # whose shard we currently hold
            d2 = geometry.sq_dist_matrix(q_s, d_cur)
            d2 = jnp.where(dv_cur[None, :], d2, BIG)
            tmin = jnp.min(d2, axis=1)
            targ = jnp.argmin(d2, axis=1).astype(jnp.int32) + owner * shard_d
            better = tmin < mins
            mins = jnp.where(better, tmin, mins)
            args = jnp.where(better, targ, args)
            perm = [(j, (j + 1) % n_dev) for j in range(n_dev)]
            d_nxt = jax.lax.ppermute(d_cur, axis, perm)
            dv_nxt = jax.lax.ppermute(dv_cur, axis, perm)
            return mins, args, d_nxt, dv_nxt

        mins0 = _pvary(jnp.full((q_s.shape[0],), BIG, jnp.float32), axis)
        args0 = _pvary(jnp.full((q_s.shape[0],), -1, jnp.int32), axis)
        mins, args, _, _ = jax.lax.fori_loop(
            0, n_dev, hop, (mins0, args0, d_s, dv_s)
        )
        dist = jnp.sqrt(jnp.minimum(mins, BIG))
        dist = jnp.where(qv_s, dist, 0.0)
        args = jnp.where(qv_s, args, -1)
        return dist, args

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(axis, None), P(axis)),
        out_specs=(P(axis), P(axis)),
    )(q, q_valid, d, d_valid)


# ---------------------------------------------------------------------------
# sharded GBO (bitset popcount) over the data axis
# ---------------------------------------------------------------------------


def sharded_topk_gbo(
    mesh: Mesh,
    axis: str | tuple[str, ...],
    q_sig: Array,
    ds_sigs: Array,
    ds_valid: Array,
    k: int,
):
    """Top-k GBO with signatures sharded over the repository axis.

    Local popcount(AND) + local top-k, then an O(k) all-gather merge of
    (value, global id) pairs."""
    axes = (axis,) if isinstance(axis, str) else tuple(axis)

    def local(qs, sg, dv):
        counts = jax.lax.population_count(qs[None, :] & sg).astype(jnp.int32)
        counts = counts.sum(axis=-1)
        counts = jnp.where(dv, counts, -1)
        shard = sg.shape[0]
        vals, ids = jax.lax.top_k(counts, k)
        idx = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        gids = ids + idx * shard
        for ax in axes:
            vals = jax.lax.all_gather(vals, ax, tiled=True)
            gids = jax.lax.all_gather(gids, ax, tiled=True)
        top, pos = jax.lax.top_k(vals, k)
        return top, gids[pos]

    spec = P(axes)
    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axes, None), spec),
        out_specs=(P(), P()),
        check_vma=False,  # top-k is replicated by the all_gather merge
    )(q_sig, ds_sigs, ds_valid)
