"""Point-granularity search (paper Section VI-B): RangeP and NNP.

RangeP (Def. 11): all points of a chosen dataset inside a query rectangle.
NNP (Def. 12):    the nearest neighbor in D for every point of Q — the
                  paper reuses the Hausdorff traversal state; our TPU form
                  reuses the same Eq. 4 leaf-frontier pruning mask, then the
                  streaming NN kernel runs only over surviving leaf slabs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import geometry
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository
from repro.kernels import ops

Array = jax.Array
BIG = 3.4e38


class PointStats(NamedTuple):
    nodes_evaluated: int
    leaves_scanned: int
    pruned_fraction: float


def range_points_core(d_idx: DatasetIndex, r_lo: Array, r_hi: Array):
    """Pure-jax RangeP: (take mask, scanned-leaf mask).  vmap-able over a
    leading query/dataset batch — the engine's single-dispatch path."""
    depth = d_idx.depth
    sl = d_idx.level_slice(depth)
    leaf_lo = d_idx.box_lo[sl]
    leaf_hi = d_idx.box_hi[sl]
    overlap = geometry.box_overlaps(leaf_lo, leaf_hi, r_lo, r_hi)
    contained = jnp.all((leaf_lo >= r_lo) & (leaf_hi <= r_hi), axis=-1)
    live = overlap & (d_idx.counts[sl] > 0)

    f = d_idx.leaf_size
    pts = d_idx.points
    inside = geometry.box_contains(r_lo, r_hi, pts)
    leaf_of = jnp.arange(pts.shape[0]) // f
    take = jnp.where(
        contained[leaf_of], True, inside
    ) & live[leaf_of] & d_idx.valid
    return take, live & ~contained


def range_points(d_idx: DatasetIndex, r_lo: Array, r_hi: Array):
    """Mask of points of D inside [r_lo, r_hi] + traversal stats.

    The tree prunes leaf slabs whose box misses R; fully-contained leaves
    are accepted wholesale (the paper's three-way node classification);
    only boundary leaves need the per-point test.
    """
    take, scanned = range_points_core(d_idx, r_lo, r_hi)
    n_leaves = scanned.shape[0]
    stats = PointStats(
        nodes_evaluated=n_leaves,
        leaves_scanned=int(scanned.sum()),
        pruned_fraction=float(1.0 - scanned.sum() / max(n_leaves, 1)),
    )
    return take, stats


def nnp(q_idx: DatasetIndex, d_idx: DatasetIndex):
    """NN in D for every valid point of Q: (dists (nq,), idx (nq,))."""
    return ops.nn_distance(q_idx.points, d_idx.points,
                           q_idx.valid, d_idx.valid)


def nnp_pruned_core(q_idx: DatasetIndex, d_idx: DatasetIndex):
    """Pure-jax tree-pruned NNP: (dists, idx, pair_live).  vmap-able over a
    leading batch of (query, dataset) pairs."""
    lq, ld = q_idx.depth, d_idx.depth
    slq = q_idx.level_slice(lq)
    sld = d_idx.level_slice(ld)
    oq, rq = q_idx.centers[slq], q_idx.radii[slq]
    od, rd = d_idx.centers[sld], d_idx.radii[sld]
    cq = q_idx.counts[slq]
    cd = d_idx.counts[sld]

    lb, ub = ops.bound_matrices(oq, rq, od, rd, use_kernel=False)
    d_ok = cd > 0
    ub = jnp.where(d_ok[None, :], ub, BIG)
    row_ub = jnp.min(ub, axis=1)
    # per-POINT-safe lower bound: Eq. 4's lb bounds the max-min (Hausdorff);
    # a q point at the leaf boundary can be r_q closer, so the sound prune
    # uses cd - r_q - r_d (drop leaf j only if NO point pair can beat the
    # leaf's worst-case NN bound row_ub)
    cdm = geometry.pairwise_center_dist(oq, od)
    plb = jnp.maximum(cdm - rq[:, None] - rd[None, :], 0.0)
    plb = jnp.where(d_ok[None, :], plb, BIG)
    pair_live = (plb <= row_ub[:, None]) & d_ok[None, :] & (cq > 0)[:, None]

    fq = q_idx.leaf_size
    fd = d_idx.leaf_size
    dim = q_idx.points.shape[-1]
    qp = q_idx.points.reshape(-1, fq, dim)
    qv = q_idx.valid.reshape(-1, fq)
    dp = d_idx.points.reshape(-1, fd, dim)
    dv = d_idx.valid.reshape(-1, fd)
    base = jnp.arange(dp.shape[0]) * fd

    def per_qleaf(qp_i, qv_i, live_row):
        def leaf_scan(dp_j, dv_j, live, b):
            # exact broadcast-subtract form (leaf tiles are small; the
            # |x|^2-2xy+|y|^2 form loses ~1e-3 to cancellation)
            diff = qp_i[:, None, :] - dp_j[None, :, :]
            d2 = jnp.sum(diff * diff, axis=-1)
            d2 = jnp.where(dv_j[None, :] & live, d2, BIG)
            return jnp.min(d2, axis=1), jnp.argmin(d2, axis=1) + b

        mins, args = jax.vmap(leaf_scan)(dp, dv, live_row, base)
        best_leaf = jnp.argmin(mins, axis=0)                   # (fq,)
        d2 = jnp.take_along_axis(mins, best_leaf[None, :], axis=0)[0]
        ix = jnp.take_along_axis(args, best_leaf[None, :], axis=0)[0]
        dist = jnp.sqrt(jnp.minimum(d2, BIG))
        dist = jnp.where(qv_i, dist, 0.0)
        ix = jnp.where(qv_i, ix, -1)
        return dist, ix

    dists, idxs = jax.vmap(per_qleaf)(qp, qv, pair_live)
    return (
        dists.reshape(-1), idxs.reshape(-1).astype(jnp.int32), pair_live
    )


def nnp_pruned(q_idx: DatasetIndex, d_idx: DatasetIndex):
    """Tree-pruned NNP: per-Q-leaf, only D-leaves whose Eq. 4 lower bound
    beats the leaf's best upper bound are scanned (same mask the Hausdorff
    traversal builds — 'reuse the queues' in the paper's phrasing).

    Returns (dists, idx, PointStats).  Exactness asserted in tests.
    """
    dists, idxs, pair_live = nnp_pruned_core(q_idx, d_idx)
    stats = PointStats(
        nodes_evaluated=int(pair_live.shape[0] * pair_live.shape[1]),
        leaves_scanned=int(pair_live.sum()),
        pruned_fraction=float(1.0 - pair_live.sum() / pair_live.size),
    )
    return dists, idxs, stats
