"""Search layer (paper Section VI): dataset-granularity operations.

Implements, over the unified index:
  * RangeS          (Def. 9)  — range-based dataset search
  * top-k IA        (Def. 6)  — intersecting-area exemplar search
  * top-k GBO       (Def. 7)  — grid-overlap exemplar search
  * top-k Hausdorff (Def. 8)  — exact (fast bound estimation, Eq. 4 +
                                 branch-and-bound in batch) and approximate
                                 (Lemma 1, error <= 2*eps)

TPU adaptation (DESIGN.md sec. 2): branch-and-bound becomes
  phase 0   dense Eq. 4 bound pass over ALL dataset roots (one kernel call —
            the paper's "pruning in batch" as a literal batched op),
  phase 1   level-synchronous frontier refinement of surviving candidates
            (bound matrices between Q's level-l nodes and each candidate's
            level-l nodes, masked),
  phase 2   exact Hausdorff on the shortlist, chunked in ascending-lower-
            bound order with monotone threshold tightening — sound and
            exact.

The pruning-in-batch theme extends across QUERIES: `_hausdorff_bound_
phases` and `_phase2_exact_loop` natively operate on a (B, ...) query
batch — phases 0/1 compute every query's bound matrices in one vmapped
pass and phase 2 is a single `lax.while_loop` over a shared (query,
candidate-chunk) work frontier with per-query taus — so B concurrent
ExactHaus queries cost ONE device dispatch (`_topk_hausdorff_device_
batched`, the engine hot path).  Single-query inputs are auto-promoted to
a batch of one; `topk_hausdorff_host` keeps the seed host-chunked loop as
the bit-identity oracle.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, geometry, zorder
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository
from repro.kernels import ops

Array = jax.Array
BIG = 3.4e38

# ExactHaus prune guard: XLA codegen for different slot extents (the
# sharded engines slice the slot axis, so each shard count compiles its
# own kernel shapes) can drift the Eq. 4 BOUND values by a few ulps
# (FMA/vectorization reassociation), and a strict ``LB <= tau`` at an
# exact tie would then prune a true top-k member under one mesh shape and
# keep it under another.  Comparing against ``tau * TAU_GUARD`` admits
# candidates within ~100 ulps of the threshold; that is bit-safe by the
# superset rule (an extra EXACT evaluation is > H_k and cannot enter the
# top-k — exact values are computed on fixed chunk shapes, so they carry
# no shape drift) and makes the prune DECISIONS, hence the returned
# values/ids, stable across shard shapes.  A single f32 multiply so the
# device (XLA) and host-oracle (numpy) pipelines compute the guard
# bit-identically — no add that a compiler could fuse into an FMA.
TAU_GUARD = np.float32(1.0 + 1e-5)


class SearchStats(NamedTuple):
    nodes_evaluated: int
    candidates_after_bounds: int
    exact_evaluations: int
    pruned_fraction: float


# ---------------------------------------------------------------------------
# RangeS (Def. 9)
# ---------------------------------------------------------------------------


def range_search(repo: Repository, r_lo: Array, r_hi: Array):
    """All datasets whose MBR overlaps [r_lo, r_hi].

    Level-synchronous traversal of the upper tree; pruned subtrees are
    masked out, so the per-level overlap test only "counts" for live nodes.
    Returns (mask over ORIGINAL dataset slots, SearchStats).
    """
    mask, live_nodes, nodes_evaluated = _range_search_core(repo, r_lo, r_hi)
    live = int(live_nodes)
    stats = SearchStats(
        nodes_evaluated,
        int(mask.sum()),
        0,
        1.0 - live / max(nodes_evaluated, 1),
    )
    return mask, stats


def _range_search_core(repo: Repository, r_lo: Array, r_hi: Array):
    """Pure-jax RangeS traversal: (mask, live_nodes, total_nodes).

    `total_nodes` is a static python int (tree lanes touched); `live_nodes`
    counts lanes still active at each level — the nodes a pointer-chasing
    traversal would actually visit — as a device scalar so the batched
    engine path stays sync-free.
    """
    up = repo.repo
    depth = up.depth
    active = jnp.ones((1,), bool)
    nodes_evaluated = 0
    live_nodes = jnp.zeros((), jnp.int32)
    for level in range(depth + 1):
        sl = up.level_slice(level)
        lo = up.box_lo[sl]
        hi = up.box_hi[sl]
        hit = geometry.box_overlaps(lo, hi, r_lo, r_hi) & (up.counts[sl] > 0)
        active = active & hit
        nodes_evaluated += int(active.shape[0])  # static count of lanes
        live_nodes = live_nodes + active.sum().astype(jnp.int32)
        if level < depth:
            active = jnp.repeat(active, 2)
    # leaf segments -> dataset slots (tree order), then test each dataset MBR
    f_up = up.ds_valid.shape[0] // (1 << depth)
    ds_active_tree = jnp.repeat(active, f_up)
    _, _, lo_r, hi_r = repo.roots()
    lo_t = lo_r[up.order]
    hi_t = hi_r[up.order]
    hit_ds = geometry.box_overlaps(lo_t, hi_t, r_lo, r_hi)
    mask_tree = ds_active_tree & hit_ds & up.ds_valid
    mask = jnp.zeros_like(mask_tree).at[up.order].set(mask_tree)
    return mask, live_nodes, nodes_evaluated


# ---------------------------------------------------------------------------
# top-k IA (Def. 6)
# ---------------------------------------------------------------------------


def topk_ia(repo: Repository, q_lo: Array, q_hi: Array, k: int):
    """Top-k datasets by intersecting area with Q's MBR.

    IA is O(1) per dataset given the root MBRs, so the TPU-native form is a
    single dense vectorized evaluation + top_k (DESIGN.md: for IA the batch
    evaluation IS the pruning).
    """
    _, _, lo, hi = repo.roots()
    ia = geometry.intersect_area(lo, hi, q_lo, q_hi)
    ia = jnp.where(repo.ds_valid, ia, -1.0)
    vals, ids = jax.lax.top_k(ia, k)
    # k can exceed the number of valid datasets: padded slots surface with
    # the -1 sentinel score; mask their ids so callers never see a padded id
    ids = jnp.where(vals < 0, -1, ids)
    return vals, ids


# ---------------------------------------------------------------------------
# top-k GBO (Def. 7)
# ---------------------------------------------------------------------------


def topk_gbo(repo: Repository, q_sig: Array, k: int):
    """Top-k datasets by z-order signature overlap, dense bitset kernel."""
    counts = ops.set_intersect_counts(q_sig[None, :], repo.ds_sigs)[0]
    counts = jnp.where(repo.ds_valid, counts, -1)
    vals, ids = jax.lax.top_k(counts, k)
    ids = jnp.where(vals < 0, -1, ids)  # padded slots: sentinel id
    return vals, ids


def gbo_frontier_stats(repo: Repository, q_sig: Array, k: int) -> SearchStats:
    """Node-evaluation accounting for the tree-pruned GBO traversal.

    The upper node signature is the union of its children (Def. 16), so
    popcount(q AND node) upper-bounds every descendant's GBO; nodes whose UB
    falls below the running kth-best exact value are pruned.  Results match
    `topk_gbo` (asserted in tests); this function reports how much of the
    tree the bound-based pruning visits.
    """
    up = repo.repo
    depth = up.depth
    q = np.asarray(q_sig)
    sigs = np.asarray(up.sigs)
    counts_nodes = np.asarray(up.counts)
    exact = np.asarray(
        ops.set_intersect_counts(q_sig[None, :], repo.ds_sigs)[0]
    )
    exact = np.where(np.asarray(repo.ds_valid), exact, -1)
    kth = np.sort(exact)[-k] if exact.size >= k else -1

    def popcnt(x):
        return np.unpackbits(x.view(np.uint8)).sum()

    visited = 0
    frontier = [0]
    survivors = 0
    while frontier:
        node = frontier.pop()
        visited += 1
        if counts_nodes[node] == 0:
            continue
        ub = popcnt(q & sigs[node])
        if ub < kth:
            continue
        level = int(math.floor(math.log2(node + 1)))
        if level == depth:
            survivors += 1
            continue
        frontier.extend((2 * node + 1, 2 * node + 2))
    total = len(sigs)
    return SearchStats(visited, survivors, 0, 1.0 - visited / max(total, 1))


# ---------------------------------------------------------------------------
# Hausdorff machinery
# ---------------------------------------------------------------------------


def _level_arrays(idx: DatasetIndex, level: int):
    sl = idx.level_slice(level)
    return (
        idx.centers[..., sl, :],
        idx.radii[..., sl],
        idx.counts[..., sl],
    )


def frontier_bounds(q_idx: DatasetIndex, ds_index: DatasetIndex, level_q: int,
                    level_d: int):
    """Per-dataset (LB, UB) on H(Q -> D_i) from level-l node frontiers.

    q_idx: single-dataset index; ds_index: batched (B, ...) indexes.
    LB_i = max_q min_d lb(q, d), UB_i = max_q min_d ub(q, d) (DESIGN.md),
    with empty nodes masked.  Returns (LB (B,), UB (B,)).
    """
    oq, rq, cq = _level_arrays(q_idx, level_q)          # (nq, d), (nq,), (nq,)
    od, rd, cd = _level_arrays(ds_index, level_d)       # (B, nd, d), ...

    def one(od_i, rd_i, cd_i):
        lb, ub = ops.bound_matrices(oq, rq, od_i, rd_i, use_kernel=False)
        d_ok = cd_i > 0
        lb = jnp.where(d_ok[None, :], lb, BIG)
        ub = jnp.where(d_ok[None, :], ub, BIG)
        row_lb = jnp.min(lb, axis=1)
        row_ub = jnp.min(ub, axis=1)
        q_ok = cq > 0
        LB = jnp.max(jnp.where(q_ok, row_lb, -BIG))
        UB = jnp.max(jnp.where(q_ok, row_ub, -BIG))
        return LB, UB

    return jax.vmap(one)(od, rd, cd)


def _frontier_bound_all_levels(q_idx: DatasetIndex, ds_index: DatasetIndex,
                               max_level: int):
    """All-levels fused bound pass: every (query, slot) pair's per-level
    (LB, UB) frontier scalars for levels 0..max_level in ONE kernel op.

    q_idx is a (B, ...) query batch, ds_index the (S, ...) corpus.  Slices
    the contiguous node range covering levels 0..max_level out of both
    trees and hands it to `ops.bound_grid`, which computes the dense Eq. 4
    bound tensors once and reduces each level's static node slice —
    replacing max_level+1 separate `vmap(frontier_bounds)` passes with one
    dispatch.  Returns (LB, UB), each (max_level+1, B, S), matching
    `vmap(frontier_bounds)(q_idx, ds_index, l, l)` per level up to XLA's
    shape-dependent FMA contraction (~1 ulp; benchmarks/bench_engine.py
    asserts the tolerance).  Bit-stability of ExactHaus itself does not
    ride on that: the host oracle, the local batched pipeline, and the
    sharded pipeline ALL consume this one fused pass, so their results
    stay mutually bit-identical (the equivalence suites assert it).
    """
    n_nodes = q_idx.level_slice(max_level).stop
    levels = tuple((q_idx.level_slice(l).start, q_idx.level_slice(l).stop)
                   for l in range(max_level + 1))
    oq = q_idx.centers[..., :n_nodes, :]
    rq = q_idx.radii[..., :n_nodes]
    cq = q_idx.counts[..., :n_nodes]
    od = ds_index.centers[..., :n_nodes, :]
    rd = ds_index.radii[..., :n_nodes]
    cd = ds_index.counts[..., :n_nodes]
    return ops.bound_grid(oq, rq, cq > 0, od, rd, cd > 0, levels=levels)


def _kth_smallest(x: Array, k: int) -> Array:
    """kth-smallest along the LAST axis (selection only: the returned float
    bit pattern is an element of x, identical to jnp.sort(x)[..., k-1])."""
    kk = min(k, x.shape[-1])
    return -jax.lax.top_k(-x, kk)[0][..., kk - 1]


def _as_query_batch(q_idx: DatasetIndex):
    """Promote a single-query index to a (1, ...) batch; returns
    (batched index, was_single)."""
    if q_idx.points.ndim == 2:
        return jax.tree.map(lambda x: x[None], q_idx), True
    return q_idx, False


def _hausdorff_bound_phases(
    repo: Repository,
    q_idx: DatasetIndex,
    k: int,
    refine_levels: int,
    *,
    axis: str | None = None,
    n_slots_total: int | None = None,
):
    """Phases 0+1 of ExactHaus for a (B, ...) QUERY BATCH, pure jax.

    ``q_idx`` may carry a leading query-batch axis or be a single query
    (auto-promoted to a batch of one and squeezed on return).  Phases 0/1
    compute the Eq. 4 bound matrices for ALL B queries AND all tree levels
    in one fused `ops.bound_grid` dispatch (replacing the per-level
    `vmap(frontier_bounds)` composition; host oracle, local batched, and
    sharded pipelines all share this pass, so their results stay mutually
    bit-identical) and each query carries its own tau.

    Shard-mappable over a slot slice: with ``axis=None`` (the single-device
    pipeline) `repo` spans every dataset slot and all reductions are local.
    Inside shard_map (``axis`` a mesh axis name) `repo` is the LOCAL shard
    slice; per-slot bounds are computed by the identical arithmetic on the
    identical rows (slicing the slot axis changes no values) and only the
    two repository-global reductions become collectives — each query's tau
    (the kth-smallest upper bound, via the O(k)
    :func:`~repro.core.distributed.global_kth_smallest` gather, batched
    over queries) and the candidate counters (psum).  ``n_slots_total``
    pins the phase-0 node count to the unsharded slot count so stats match
    the local pipeline exactly even when shard padding widens the local
    slice.

    Returns (LB (B, S), tau (B,), cand (B, S), nodes_evaluated (B,),
    cand_after_bounds (B,)); LB/cand cover this slice's slots, the
    counters are device vectors (global when sharded) so the whole
    pipeline can live under one jit.  Single-query inputs get the same
    tuple with the query axis squeezed.
    """
    q_idx, single = _as_query_batch(q_idx)
    S = repo.n_slots
    valid = repo.ds_valid

    def kth_ub(ub):
        if axis is None:
            return _kth_smallest(ub, k)
        return distributed.global_kth_smallest(ub, k, axis)

    def count(mask):
        s = mask.sum(axis=-1).astype(jnp.int32)
        return s if axis is None else jax.lax.psum(s, axis)

    # ---- fused bound pass: every level's (B, S) frontier scalars in ONE
    # kernel dispatch (ops.bound_grid), instead of one vmap(frontier_bounds)
    # composition per level; phases 0/1 below consume per-level slices.
    # Bound values never depend on cand/tau, so hoisting the computation
    # changes no results — the old code already evaluated bounds densely
    # for all (B, S) at every level.
    max_level = min(q_idx.depth, repo.ds_index.depth, refine_levels)
    LB_lvls, UB_lvls = _frontier_bound_all_levels(q_idx, repo.ds_index,
                                                  max_level)

    # ---- phase 0: dense root-granularity Eq. 4 bound pass -----------------
    LB, UB = LB_lvls[0], UB_lvls[0]                      # (B, S) each
    LB = jnp.where(valid[None, :], LB, BIG)
    UB = jnp.where(valid[None, :], UB, BIG)
    tau = kth_ub(UB)
    cand = LB <= (tau * TAU_GUARD)[:, None]
    if axis is not None and n_slots_total is not None:
        # shard padding widened the slot range: keep those slots out of
        # cand so the counters match the unsharded pipeline even when
        # tau == BIG (k past the valid count makes EVERY slot a candidate)
        gid = jax.lax.axis_index(axis) * S + jnp.arange(S)
        cand = cand & (gid < n_slots_total)[None, :]
    nodes_evaluated = jnp.full(
        (LB.shape[0],),
        S if n_slots_total is None else n_slots_total, jnp.int32)

    # ---- phase 1: level-synchronous refinement ----------------------------
    for level in range(1, max_level + 1):
        LB_l, UB_l = LB_lvls[level], UB_lvls[level]
        # refinement can only tighten; keep the monotone envelope
        LB = jnp.where(cand, jnp.maximum(LB, LB_l), LB)
        UB = jnp.where(cand, jnp.minimum(UB, UB_l), UB)
        tau = kth_ub(jnp.where(valid[None, :], UB, BIG))
        cand = cand & (LB <= (tau * TAU_GUARD)[:, None])
        nodes_evaluated = nodes_evaluated + count(cand) * (1 << level)

    out = (LB, tau, cand, nodes_evaluated, count(cand))
    if single:
        out = tuple(x[0] for x in out)
    return out


def _phase2_exact_loop(
    LB: Array,
    cand: Array,
    tau: Array,
    q_idx: DatasetIndex,
    ds_index: DatasetIndex,
    k: int,
    chunk: int,
    *,
    axis: str | None = None,
):
    """Phase 2 of ExactHaus: chunked exact refinement under a tightening
    threshold, over this slice's dataset slots.

    Operates on a (B, ...) QUERY BATCH (single queries are auto-promoted
    and squeezed): ONE `lax.while_loop` over a shared (query,
    candidate-chunk) work frontier.  Per iteration it evaluates one
    ascending-lower-bound chunk for EVERY query that still has work (one
    fused `ops.directed_hausdorff_grid` call for the whole (B, chunk)
    pair grid), tightens each query's tau on device, and the loop
    condition is "any query has work" — so B queries cost one while_loop
    instead of B.  A query with no work idles: its chunk lanes are masked,
    its position does not advance, and its tau re-derivation is
    idempotent, so each query's (vals, tau, evaluated) trajectory is
    EXACTLY the trajectory of its solo loop run in lockstep.

    ``axis=None`` reproduces the seed host loop exactly per query: a scan
    over that query's GLOBAL ascending-lower-bound candidate order,
    evaluating `chunk` candidates per iteration and re-deriving tau from
    the k smallest finite exacts after each chunk.

    Inside shard_map (``axis`` set) each shard scans its OWN ascending-LB
    candidate order per query and tau is all-reduced after every chunk
    (the same O(k) gather as the bound phases, batched over queries), so
    every shard prunes with the global per-query threshold.  The while
    cond must be collective-free and replicated, so the per-query continue
    flags (any shard still has work for query b, psum > 0) are computed at
    the end of the body and carried.  A shard's stop test is re-evaluated
    every iteration, NOT latched: tau is non-increasing once k finite
    exacts exist, but the single handoff from the bound-phase tau to the
    kth exact can RAISE it (the k smallest-UB datasets need not be the
    first evaluated), and an idle shard whose head LB dips back under the
    raised tau simply resumes — the soundness argument below never relies
    on stops being permanent.

    Exactness under ANY schedule: each query's tau is always >= its true
    kth-smallest Hausdorff H_k (it is derived from the k smallest of a
    SUBSET of exact values, or from the sound phase-0/1 upper bounds
    before k exacts exist), so a skipped candidate has LB > tau >= H_k and
    hence H >= LB > H_k — strictly outside the top-k, ties included.
    Every candidate with H <= H_k therefore gets evaluated under every
    chunk schedule, and the final full-slot top_k (ties toward the
    smallest slot id) returns bit-identical values and ids; only WHICH
    extra candidates beyond H_k get evaluated — the `evaluated` counter —
    depends on the schedule.  (The same argument makes evaluating a
    SUPERSET of any sound schedule's candidates bit-safe: an extra exact
    value is > H_k and never enters the top-k.)

    Returns (exact_vals (B, S) over this slice's slots, evaluated (B,)),
    `evaluated` being the global count when sharded; single-query inputs
    get the query axis squeezed.
    """
    single = LB.ndim == 1
    if single:
        LB, cand, tau = LB[None], cand[None], tau[None]
    q_idx, _ = _as_query_batch(q_idx)
    B, S = LB.shape
    lb_masked = jnp.where(cand, LB, BIG)
    order = jnp.argsort(lb_masked, axis=-1)   # stable: LB ties keep slots
    lb_sorted = jnp.take_along_axis(lb_masked, order, axis=-1)
    n_pad = ((S + chunk - 1) // chunk) * chunk
    # pad ids with 0 (masked out by the BIG lb pad; .at[].min makes the
    # duplicate-id write a no-op)
    order_p = jnp.pad(order, ((0, 0), (0, n_pad - S)))
    lb_p = jnp.pad(lb_sorted, ((0, 0), (0, n_pad - S)), constant_values=BIG)

    q_pts, q_val = q_idx.points, q_idx.valid
    d_pts_all, d_val_all = ds_index.points, ds_index.valid

    def has_work(pos, tau_c):
        # seed stopping rule per query: candidates remain, head not pruned
        # (tau guarded so ulp-level bound drift across shard shapes cannot
        # flip the decision — see TAU_GUARD)
        lb0 = jnp.take_along_axis(lb_p, pos[:, None], axis=1,
                                  mode="clip")[:, 0]
        return (pos < S) & (lb0 < BIG / 2) & (lb0 <= tau_c * TAU_GUARD)

    def reduce_any(go):
        if axis is None:
            return go
        return jax.lax.psum(go.astype(jnp.int32), axis) > 0

    def cond(carry):
        return jnp.any(carry[0])

    def body(carry):
        _, pos, vals, tau_c, evaluated = carry
        go = has_work(pos, tau_c)         # this shard's chunks still count
        idx = pos[:, None] + jnp.arange(chunk, dtype=pos.dtype)[None, :]
        ids = jnp.take_along_axis(order_p, idx, axis=1, mode="clip")
        lbs = jnp.take_along_axis(lb_p, idx, axis=1, mode="clip")
        live = (lbs < BIG / 2) & go[:, None]
        hs = ops.directed_hausdorff_grid(
            q_pts, d_pts_all[ids], q_val, d_val_all[ids]
        )
        vals = jax.vmap(lambda v, i, h: v.at[i].min(h))(
            vals, ids, jnp.where(live, hs, BIG))
        evaluated = evaluated + live.sum(axis=-1).astype(jnp.int32)
        pos = jnp.where(go, pos + chunk, pos)
        # monotone per-query threshold tightening from the k finite exacts
        finite = vals < BIG / 2
        if axis is None:
            kth = _kth_smallest(jnp.where(finite, vals, BIG), k)
            n_fin = finite.sum(axis=-1)
        else:
            kth = distributed.global_kth_smallest(
                jnp.where(finite, vals, BIG), k, axis)
            n_fin = jax.lax.psum(finite.sum(axis=-1).astype(jnp.int32),
                                 axis)
        tau_c = jnp.where(n_fin >= k, kth, tau_c)
        return (reduce_any(has_work(pos, tau_c)), pos, vals, tau_c,
                evaluated)

    init = (
        reduce_any(has_work(jnp.zeros((B,), jnp.int32), tau)),
        jnp.zeros((B,), jnp.int32),
        jnp.full((B, S), BIG, jnp.float32),
        tau.astype(jnp.float32),
        jnp.zeros((B,), jnp.int32),
    )
    if axis is not None:
        # XLA CPU miscompiles this loop's ENTRY at some shard counts
        # (observed at 2 shards): fusing the psum'd init continue-flag into
        # the loop-entry computation leaves shards disagreeing about the
        # first iteration, which desynchronises the in-body collectives and
        # silently drops a shard's chunk evaluations.  Pinning the init
        # carry behind an optimization_barrier keeps the flag a plain
        # all-reduced value every shard reads identically.  Values are
        # unchanged — the barrier only blocks the bad fusion.
        init = jax.lax.optimization_barrier(init)
    _, _, exact_vals, _, evaluated = jax.lax.while_loop(cond, body, init)
    if axis is not None:
        evaluated = jax.lax.psum(evaluated, axis)
    if single:
        return exact_vals[0], evaluated[0]
    return exact_vals, evaluated


@functools.partial(
    jax.jit, static_argnames=("k", "refine_levels", "chunk")
)
def _topk_hausdorff_device_batched(
    repo: Repository,
    q_batch: DatasetIndex,
    k: int,
    refine_levels: int,
    chunk: int,
):
    """Batched ExactHaus, entirely on device: B queries, ONE dispatch.

    Phases 0/1 compute every query's Eq. 4 bound matrices in one vmapped
    pass; phase 2 is a SINGLE `lax.while_loop` over the shared (query,
    candidate-chunk) work frontier with per-query tau tightening — the
    same evaluation order, stopping rule, and arithmetic per query as the
    seed host loop (`topk_hausdorff_host`), so per-query results are
    bit-identical; the B per-query dispatches are gone.  Both phases are
    the shard-mappable helpers (`_hausdorff_bound_phases` /
    `_phase2_exact_loop`) in their ``axis=None`` form; the sharded engine
    runs the same helpers per shard with collective tau reductions.

    Returns (vals (B, k), ids (B, k), nodes (B,), cand_after (B,),
    evaluated (B,)).
    """
    valid = repo.ds_valid
    LB, tau, cand, nodes_evaluated, cand_after = _hausdorff_bound_phases(
        repo, q_batch, k, refine_levels
    )
    exact_vals, evaluated = _phase2_exact_loop(
        LB, cand, tau, q_batch, repo.ds_index, k, chunk
    )
    vals = jnp.where(valid[None, :], exact_vals, BIG)
    top_vals, top_ids = jax.lax.top_k(-vals, k)
    return -top_vals, top_ids, nodes_evaluated, cand_after, evaluated


def _topk_hausdorff_device(
    repo: Repository,
    q_idx: DatasetIndex,
    k: int,
    refine_levels: int,
    chunk: int,
):
    """Single-query ExactHaus on device: the batched pipeline at B = 1."""
    q_batch, _ = _as_query_batch(q_idx)
    vals, ids, nodes, cand_after, evaluated = _topk_hausdorff_device_batched(
        repo, q_batch, k=k, refine_levels=refine_levels, chunk=chunk
    )
    return vals[0], ids[0], nodes[0], cand_after[0], evaluated[0]


def topk_hausdorff(
    repo: Repository,
    q_idx: DatasetIndex,
    k: int,
    *,
    refine_levels: int = 3,
    chunk: int = 32,
):
    """ExactHaus: top-k datasets by directed Hausdorff H(Q -> D).

    Single device dispatch (see `_topk_hausdorff_device`); results are
    bit-identical to the seed host-chunked loop `topk_hausdorff_host`.
    Returns (values (k,), ids (k,), SearchStats).
    """
    vals, ids, nodes, cand_after, evaluated = _topk_hausdorff_device(
        repo, q_idx, k, refine_levels, chunk
    )
    n_valid = max(int(repo.ds_valid.sum()), 1)
    stats = SearchStats(
        int(nodes), int(cand_after), int(evaluated),
        1.0 - int(evaluated) / n_valid,
    )
    return vals, ids, stats


def topk_hausdorff_host(
    repo: Repository,
    q_idx: DatasetIndex,
    k: int,
    *,
    refine_levels: int = 3,
    chunk: int = 32,
):
    """Seed ExactHaus with the host-chunked phase 2 (reference semantics).

    Kept verbatim as the oracle for the device pipeline's bit-equivalence
    tests; one device->host sync per candidate chunk.
    Returns (values (k,), ids (k,), SearchStats).
    """
    B = repo.n_slots
    valid = repo.ds_valid
    LB, tau, cand, nodes_dev, _ = _hausdorff_bound_phases(
        repo, q_idx, k, refine_levels
    )
    nodes_evaluated = int(nodes_dev)
    cand_after_bounds = int(cand.sum())

    # ---- phase 2: exact evaluation, ascending-LB host loop ----------------
    lb_np = np.asarray(jnp.where(cand, LB, BIG))
    # stable, matching the device pipeline's jnp.argsort: LB ties (common —
    # Eq. 4 clamps lb to 0 under ball overlap) must evaluate in the same
    # order for the bit-identity contract to hold
    order = np.argsort(lb_np, kind="stable")
    exact_vals = np.full((B,), np.float32(BIG))
    tau_f = float(tau)
    evaluated = 0

    q_pts, q_val = q_idx.points, q_idx.valid
    d_pts_all, d_val_all = repo.ds_index.points, repo.ds_index.valid

    eval_chunk = jax.jit(
        jax.vmap(
            lambda dp, dv: ops.directed_hausdorff(q_pts, dp, q_val, dv),
        )
    )

    pos = 0
    while pos < B:
        ids = order[pos : pos + chunk]
        ids = ids[lb_np[ids] < BIG / 2]
        if ids.size == 0:
            break
        if lb_np[ids[0]] > np.float32(tau_f) * TAU_GUARD:
            break  # everything remaining is pruned (guarded; see TAU_GUARD)
        pad = np.zeros((chunk,), np.int64)
        pad[: ids.size] = ids
        hs = np.asarray(eval_chunk(d_pts_all[pad], d_val_all[pad]))
        exact_vals[ids] = hs[: ids.size]
        evaluated += int(ids.size)
        finite = exact_vals[exact_vals < BIG / 2]
        if finite.size >= k:
            tau_f = float(np.sort(finite)[k - 1])
        pos += chunk

    # final ranking: exact values where evaluated; everything else pruned
    vals = jnp.asarray(exact_vals)
    vals = jnp.where(valid, vals, BIG)
    top_vals, top_ids = jax.lax.top_k(-vals, k)
    stats = SearchStats(
        nodes_evaluated,
        cand_after_bounds,
        evaluated,
        1.0 - evaluated / max(int(valid.sum()), 1),
    )
    return -top_vals, top_ids, stats


def approx_level(idx: DatasetIndex, eps: float) -> int:
    """Smallest level where every live node radius < eps (host helper;
    falls back to the leaf level — Lemma 1's guarantee then uses the leaf
    radius, which the caller can check)."""
    radii = np.asarray(idx.radii)
    counts = np.asarray(idx.counts)
    depth = idx.depth
    for level in range(depth + 1):
        sl = idx.level_slice(level)
        r = radii[..., sl]
        c = counts[..., sl]
        if np.all(np.where(c > 0, r, 0.0) < eps):
            return level
    return depth


def topk_hausdorff_approx(
    repo: Repository, q_idx: DatasetIndex, k: int, eps: float
):
    """ApproHaus (Lemma 1): error <= 2*eps top-k by center-distance frontier.

    Descends both trees to the first level where all node radii < eps and
    scores each dataset with max_q min_d ||o_q, o_d|| — exactly the paper's
    termination rule, level-synchronously.
    """
    lq = approx_level(q_idx, eps)
    ld = approx_level(repo.ds_index, eps)

    oq, rq, cq = _level_arrays(q_idx, lq)
    od, rd, cd = _level_arrays(repo.ds_index, ld)

    def one(od_i, cd_i):
        # exact-form distance: bit-stable under jit, so the engine's
        # batched variant reproduces this op exactly
        cdm = geometry.pairwise_dist_exact(oq, od_i)
        cdm = jnp.where((cd_i > 0)[None, :], cdm, BIG)
        row = jnp.min(cdm, axis=1)
        return jnp.max(jnp.where(cq > 0, row, -BIG))

    vals = jax.vmap(one)(od, cd)
    vals = jnp.where(repo.ds_valid, vals, BIG)
    top_vals, top_ids = jax.lax.top_k(-vals, k)
    # effective guarantee: Lemma 1 needs stopping radii < eps; when a tree
    # bottoms out first the leaf radius takes over (reported to the caller)
    r_q = float(np.max(np.where(np.asarray(cq) > 0, np.asarray(rq), 0.0)))
    r_d = float(np.max(np.where(np.asarray(cd) > 0, np.asarray(rd), 0.0)))
    eps_eff = max(eps, r_q, r_d)
    return -top_vals, top_ids, (lq, ld, eps_eff)


# ---------------------------------------------------------------------------
# pairwise Hausdorff (paper Figs. 15/19 operating mode)
# ---------------------------------------------------------------------------


def hausdorff_pair_exact(q_idx: DatasetIndex, d_idx: DatasetIndex):
    """ExactHaus between two indexed datasets with leaf-level batch pruning.

    Computes Eq. 4 bound matrices at the leaf frontier, derives the pruning
    masks (row skip + pair skip, DESIGN.md sec. 2), then evaluates the exact
    masked max-min with the streaming kernel semantics.  Returns
    (H, pruned_pair_fraction).
    """
    lq, ld = q_idx.depth, d_idx.depth
    oq, rq, cq = _level_arrays(q_idx, lq)
    od, rd, cd = _level_arrays(d_idx, ld)
    lb, ub = ops.bound_matrices(oq, rq, od, rd, use_kernel=False)
    d_ok = cd > 0
    q_ok = cq > 0
    lb = jnp.where(d_ok[None, :], lb, BIG)
    ub = jnp.where(d_ok[None, :], ub, BIG)
    row_ub = jnp.min(ub, axis=1)                      # per q-leaf
    row_lb = jnp.min(lb, axis=1)
    glb = jnp.max(jnp.where(q_ok, row_lb, -BIG))      # global lower bound
    row_live = q_ok & (row_ub >= glb)                 # rows that can set max
    pair_live = row_live[:, None] & (lb <= row_ub[:, None]) & d_ok[None, :]

    fq = q_idx.leaf_size
    fd = d_idx.leaf_size
    qp = q_idx.points.reshape(-1, fq, q_idx.points.shape[-1])
    dp = d_idx.points.reshape(-1, fd, d_idx.points.shape[-1])
    qv = q_idx.valid.reshape(-1, fq)
    dv = d_idx.valid.reshape(-1, fd)

    def row_eval(qp_i, qv_i, live_row):
        # min over live d-leaves of point-level distances
        def leaf_min(dp_j, dv_j, live):
            diff = qp_i[:, None, :] - dp_j[None, :, :]
            d2 = jnp.sum(diff * diff, axis=-1)
            d2 = jnp.where(dv_j[None, :], d2, BIG)
            m = jnp.min(d2, axis=1)
            return jnp.where(live, m, BIG)

        mins = jax.vmap(leaf_min)(dp, dv, live_row)    # (n_dleaf, fq)
        nn = jnp.sqrt(jnp.minimum(jnp.min(mins, axis=0), BIG))
        nn = jnp.where(qv_i, nn, -BIG)
        return jnp.max(nn)

    row_vals = jax.vmap(row_eval)(qp, qv, pair_live)
    h = jnp.max(jnp.where(row_live, row_vals, -BIG))
    h = jnp.maximum(h, glb)  # skipped rows are bounded by glb
    total_pairs = pair_live.size
    pruned = 1.0 - jnp.sum(pair_live) / total_pairs
    return h, pruned


def hausdorff_pair_approx(q_idx: DatasetIndex, d_idx: DatasetIndex, eps: float):
    """ApproHaus between two datasets; |result - exact| <= 2*eps (Lemma 1)."""
    lq = approx_level(q_idx, eps)
    ld = approx_level(d_idx, eps)
    oq, _, cq = _level_arrays(q_idx, lq)
    od, _, cd = _level_arrays(d_idx, ld)
    cdm = geometry.pairwise_center_dist(oq, od)
    cdm = jnp.where((cd > 0)[None, :], cdm, BIG)
    row = jnp.min(cdm, axis=1)
    return jnp.max(jnp.where(cq > 0, row, -BIG))
