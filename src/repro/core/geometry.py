"""Geometric primitives shared by the whole Spadas core.

Everything here is pure jnp, shape-polymorphic over a trailing coordinate
dimension ``d`` and fully jit/vmap-compatible.  The ball-based Hausdorff
bounds are Eq. 4 of the paper; the box algebra backs IA (Def. 6), RangeS
(Def. 9) and RangeP (Def. 11).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# distances
# ---------------------------------------------------------------------------


def sq_dist_matrix(x: Array, y: Array) -> Array:
    """Pairwise squared Euclidean distances.

    x: (n, d), y: (m, d) -> (n, m).  Uses the |x|^2 - 2xy + |y|^2 form so the
    inner product hits the MXU; clamps tiny negatives from cancellation.
    """
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xn + yn - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def dist_matrix(x: Array, y: Array) -> Array:
    return jnp.sqrt(sq_dist_matrix(x, y))


def pairwise_center_dist(cx: Array, cy: Array) -> Array:
    """Distance matrix between two sets of ball centers (n, d) x (m, d)."""
    return dist_matrix(cx, cy)


def pairwise_dist_exact(x: Array, y: Array) -> Array:
    """Pairwise distances via broadcast-subtract (n, d) x (m, d) -> (n, m).

    No |x|^2 - 2xy + |y|^2 cancellation and no dot-general, so jitted and
    eager callers produce bit-identical values — used where the engine's
    batched path must reproduce the seed op exactly.  O(n*m*d) memory;
    reserve for node-frontier sizes, not raw point sets.
    """
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


# ---------------------------------------------------------------------------
# Eq. 4 — fast ball bounds on the directed Hausdorff distance
# ---------------------------------------------------------------------------


def ball_bounds(center_dist: Array, r_q: Array, r_d: Array) -> tuple[Array, Array]:
    """Paper Eq. 4: bounds on H(q-ball -> d-ball) from ONE center distance.

    center_dist: (..., nq, nd) distances between node centers,
    r_q: (..., nq) query-node radii, r_d: (..., nd) dataset-node radii.

    Returns (lb, ub), each (..., nq, nd):
      lb = max(||o1,o2|| - r2, 0)
      ub = sqrt(||o1,o2||^2 + r2^2) + r1
    """
    r_q = r_q[..., :, None]
    r_d = r_d[..., None, :]
    lb = jnp.maximum(center_dist - r_d, 0.0)
    ub = jnp.sqrt(center_dist * center_dist + r_d * r_d) + r_q
    return lb, ub


def ball_bounds_from_centers(
    o_q: Array, r_q: Array, o_d: Array, r_d: Array
) -> tuple[Array, Array]:
    """Convenience: Eq. 4 bounds straight from centers (nq,d)/(nd,d)."""
    return ball_bounds(pairwise_center_dist(o_q, o_d), r_q, r_d)


# ---------------------------------------------------------------------------
# boxes (MBRs)
# ---------------------------------------------------------------------------


def box_of(points: Array, valid: Array | None = None) -> tuple[Array, Array]:
    """MBR of a point set (n, d) (optionally masked) -> (lo, hi) each (d,)."""
    if valid is None:
        return points.min(axis=0), points.max(axis=0)
    big = jnp.array(jnp.inf, points.dtype)
    lo = jnp.min(jnp.where(valid[:, None], points, big), axis=0)
    hi = jnp.max(jnp.where(valid[:, None], points, -big), axis=0)
    return lo, hi


def box_overlaps(lo_a: Array, hi_a: Array, lo_b: Array, hi_b: Array) -> Array:
    """Boolean: do boxes overlap?  Broadcasts over leading dims."""
    return jnp.all((lo_a <= hi_b) & (lo_b <= hi_a), axis=-1)


def intersect_area(lo_a: Array, hi_a: Array, lo_b: Array, hi_b: Array) -> Array:
    """Def. 6 IA: product over dims of overlap length (0 if disjoint).

    Broadcasts; computed over the FIRST TWO dims only when d > 2, matching
    the paper's use of latitude/longitude for the area term (extensions to
    d > 2 multiply all overlap lengths; we follow the paper and use the
    leading two spatial dims, which is also what the benchmarks vary).
    """
    l = jnp.minimum(hi_a, hi_b) - jnp.maximum(lo_a, lo_b)
    l = jnp.maximum(l, 0.0)
    return l[..., 0] * l[..., 1]


def box_contains(lo: Array, hi: Array, p: Array) -> Array:
    """Boolean: points p (..., d) inside box [lo, hi]."""
    return jnp.all((p >= lo) & (p <= hi), axis=-1)


def ball_stats(points: Array, valid: Array | None = None) -> tuple[Array, Array]:
    """Paper Def. 14 node stats: center = masked mean, radius = max dist."""
    if valid is None:
        o = points.mean(axis=0)
        r = jnp.sqrt(jnp.max(jnp.sum((points - o) ** 2, axis=-1)))
        return o, r
    w = valid.astype(points.dtype)
    cnt = jnp.maximum(w.sum(), 1.0)
    o = (points * w[:, None]).sum(axis=0) / cnt
    d2 = jnp.sum((points - o) ** 2, axis=-1)
    r = jnp.sqrt(jnp.max(jnp.where(valid, d2, 0.0)))
    return o, r
