"""z-order signatures (Defs. 4/5/7) as fixed-width bitsets.

TPU adaptation (DESIGN.md sec. 2): the paper stores a sorted variable-length
integer set per dataset; we store a fixed-width bitset over the 4^theta grid
cells so that
  * GBO (Def. 7)  = popcount(AND)            (one VPU op per word)
  * node signature union (Def. 16) = OR
Both are static-shape and vectorize over the whole repository.

Cell ids use the standard Morton interleave of the two leading spatial
coordinates quantized to 2^theta bins each, exactly as Def. 4 prescribes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

WORD_BITS = 32


def num_cells(theta: int) -> int:
    return 1 << (2 * theta)


def num_words(theta: int) -> int:
    return max(1, num_cells(theta) // WORD_BITS)


def _part1by1(x: Array) -> Array:
    """Spread the low 16 bits of x so there is a 0 between each bit."""
    x = x.astype(jnp.uint32) & jnp.uint32(0x0000FFFF)
    x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
    x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & jnp.uint32(0x33333333)
    x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def morton2(ix: Array, iy: Array) -> Array:
    """Interleave two <=16-bit integer grids into a Morton code (uint32)."""
    return _part1by1(ix) | (_part1by1(iy) << 1)


def quantize(points: Array, lo: Array, hi: Array, theta: int) -> Array:
    """Map points (..., d>=2) into integer grid coords on [lo, hi] (2,)."""
    span = jnp.maximum(hi - lo, 1e-30)
    nbins = (1 << theta) - 1
    g = (points[..., :2] - lo) / span * (nbins + 1)
    g = jnp.clip(g.astype(jnp.int32), 0, nbins)
    return g


def cell_ids(points: Array, lo: Array, hi: Array, theta: int) -> Array:
    """Morton cell id per point (Def. 4), in [0, 4^theta)."""
    g = quantize(points, lo, hi, theta)
    return morton2(g[..., 0], g[..., 1]).astype(jnp.int32)


def signature(points: Array, valid: Array, lo: Array, hi: Array, theta: int) -> Array:
    """z-order signature (Def. 5) as a (W,) uint32 bitset.

    points: (n, d), valid: (n,) bool.  Invalid points contribute nothing.
    """
    n_cells = num_cells(theta)
    ids = cell_ids(points, lo, hi, theta)
    ids = jnp.where(valid, ids, n_cells)  # park invalid in an overflow cell
    occ = jnp.zeros((n_cells + 1,), jnp.uint32).at[ids].max(jnp.uint32(1))
    occ = occ[:n_cells]
    w = num_words(theta)
    occ = occ.reshape(w, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.bitwise_or.reduce(occ << shifts, axis=1) if hasattr(
        jnp.bitwise_or, "reduce"
    ) else (occ << shifts).sum(axis=1).astype(jnp.uint32)


def sig_union(a: Array, b: Array) -> Array:
    return a | b


def sig_intersect_count(a: Array, b: Array) -> Array:
    """GBO (Def. 7): |z(A) AND z(B)| via popcount.  Broadcasts over leading
    dims; reduces the trailing word axis."""
    return jax.lax.population_count(a & b).astype(jnp.int32).sum(axis=-1)


def sig_count(a: Array) -> Array:
    return jax.lax.population_count(a).astype(jnp.int32).sum(axis=-1)


def default_epsilon(lo: Array, hi: Array, theta: int) -> Array:
    """Paper Eq. 8: cell width of the x-extent at resolution theta."""
    return (hi[0] - lo[0]) / (1 << theta)
