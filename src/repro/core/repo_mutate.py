"""Incremental repository mutation under a PINNED geometry.

`build_repository` (Alg. 1) derives four repository-global quantities from
the whole dataset collection: the bottom tree depth (max cardinality), the
Def. 4 grid bounds (union of root MBRs), the pooled Eq. 3 outlier
threshold r', and the padded slot count B_pad.  A live repository cannot
re-derive them per mutation without rebuilding everything, so this module
pins them once as a :class:`RepoGeometry` and reuses the EXACT cold-build
code path per slot:

  * :func:`init_live` — the cold build (same op order as Alg. 1)
    restructured to also emit its geometry;
  * :func:`build_row` — THE canonical per-dataset pipeline: pad ->
    ``build_index_batch`` -> ``remove_outliers`` (pinned r') -> z-order
    signature (pinned bounds), always as a BATCH-OF-1 through a set of
    shared, cached jitted stage executables.  Batch-of-1 everywhere is a
    correctness decision, not a convenience: XLA:CPU's reduction
    vectorization is batch-width dependent (a (7, ...) vmapped tree build
    can differ from a (1, ...) build by 1 ulp in a node radius), so the
    only way a live batch-of-1 ingest can be bit-identical to a cold
    rebuild is for the cold rebuild to use the SAME batch-of-1
    executables — which :func:`init_live` and :func:`build_frozen` do;
  * :func:`update_slots` — the functional MULTI-slot repository update
    (ingest / delete / replace are all one batched scatter + ONE
    upper-tree rebuild for N coalesced mutations; a DELETED slot is
    ZEROED entirely, matching the cold builder's ``pad_to(..., 0)``
    padding exactly), with :func:`update_slot` as the N=1 form;
  * :func:`build_frozen` — the bit-identity ORACLE: a cold,
    slot-preserving build from ``{slot j -> dataset_j | None}`` under the
    same geometry, against which any live mutation sequence must agree.

Capacity is tiered like the engine's bucket ladder: the slot count starts
at the cold ``B_pad`` (plus optional headroom) and doubles via
:meth:`RepoGeometry.grown` + :func:`grow_slots` when ingest outruns it.
The bottom point capacity is pinned at init — an oversize ingest is a
``ValueError``, never a silent geometry change (re-deriving the depth
would shift every tree in the repository).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import lru_cache
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core import outliers as outliers_lib
from repro.core import repo_index as repo_lib
from repro.core import zorder
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository

Array = jax.Array


@dataclass(frozen=True)
class RepoGeometry:
    """The cold-build quantities a live repository pins at creation.

    ``space_lo``/``space_hi`` and ``r_prime`` are stored as exact Python
    floats of the builder's float32 values (float32 -> float64 -> float32
    round-trips exactly), so re-materializing them reproduces the cold
    build's arithmetic bit for bit.
    """

    leaf_capacity: int          # bottom-tree leaf fanout f
    bottom_depth: int           # pinned bottom tree depth
    repo_leaf_capacity: int     # upper-tree fanout f_up
    upper_depth: int            # current slot tier: n_slots = f_up * 2**d_u
    theta: int                  # z-order grid resolution
    space_lo: tuple             # (d',) pinned Def. 4 grid bounds
    space_hi: tuple
    r_prime: float | None       # pinned Eq. 3 threshold; None = no removal
    dim: int = 2

    @property
    def point_capacity(self) -> int:
        return self.leaf_capacity * (1 << self.bottom_depth)

    @property
    def n_slots(self) -> int:
        return self.repo_leaf_capacity * (1 << self.upper_depth)

    @property
    def sig_words(self) -> int:
        return zorder.num_words(self.theta)

    def grown(self) -> "RepoGeometry":
        """The next capacity tier: slot count doubles, everything else
        pinned (existing slots keep their trees and signatures)."""
        return replace(self, upper_depth=self.upper_depth + 1)

    def space_bounds(self):
        return (jnp.asarray(self.space_lo, jnp.float32),
                jnp.asarray(self.space_hi, jnp.float32))


def _floats(x) -> tuple:
    return tuple(float(v) for v in np.asarray(x, np.float32).reshape(-1))


# -- the canonical batch-of-1 row pipeline --------------------------------
#
# Three cached jitted stages shared by EVERY row build in the process
# (live ingest, init_live, the frozen oracle).  Sharing the executables —
# same shapes, same program — is what makes bit-identity unconditional:
# same-shape XLA programs are deterministic, while re-deriving "the same"
# computation at a different batch width is not (see module docstring).

@lru_cache(maxsize=None)
def _stage_build(depth: int):
    return jax.jit(
        lambda pts, val: index_lib.build_index_batch(pts, val, depth))


@lru_cache(maxsize=None)
def _stage_outliers():
    # r' is a traced OPERAND (not a baked constant): init_live probes it
    # and every pinned geometry reuses the one executable per shape
    return jax.jit(
        lambda idx, r: outliers_lib.remove_outliers(idx, r_prime=r)[0])


@lru_cache(maxsize=None)
def _stage_sig(theta: int, space_lo: tuple, space_hi: tuple):
    lo = jnp.asarray(space_lo, jnp.float32)
    hi = jnp.asarray(space_hi, jnp.float32)
    return jax.jit(jax.vmap(
        lambda p, v: zorder.signature(p, v, lo, hi, theta)))


def pad_one(points: np.ndarray, geom: RepoGeometry):
    """Host-pad one dataset to the pinned (1, point_capacity, dim) layout
    (zeros beyond the real points, exactly like `pad_batch`)."""
    n = int(points.shape[0])
    if n > geom.point_capacity:
        raise ValueError(
            f"dataset with {n} points exceeds the pinned point capacity "
            f"{geom.point_capacity} (leaf_capacity={geom.leaf_capacity}, "
            f"bottom_depth={geom.bottom_depth}); build the live "
            f"repository with a larger point_capacity")
    pts = np.zeros((1, geom.point_capacity, geom.dim), np.float32)
    val = np.zeros((1, geom.point_capacity), bool)
    pts[0, :n] = points
    val[0, :n] = True
    return pts, val


def build_row(points: np.ndarray, geom: RepoGeometry):
    """THE canonical row build: one dataset -> (batch-of-1 DatasetIndex,
    sigs (1, W)) through the shared stage executables under the pinned
    geometry."""
    pts, val = pad_one(np.asarray(points, np.float32), geom)
    idx = _stage_build(geom.bottom_depth)(jnp.asarray(pts),
                                          jnp.asarray(val))
    if geom.r_prime is not None:
        idx = _stage_outliers()(idx, jnp.float32(geom.r_prime))
    sigs = _stage_sig(geom.theta, geom.space_lo,
                      geom.space_hi)(idx.points, idx.valid)
    return idx, sigs


def build_rows(datasets: Sequence[np.ndarray], geom: RepoGeometry):
    """Batch-of-1 :func:`build_row` per dataset, stacked to
    (DatasetIndex batched over len(datasets), sigs (B, W))."""
    rows = [build_row(ds, geom) for ds in datasets]
    idx = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                       *[r[0] for r in rows])
    sigs = jnp.concatenate([r[1] for r in rows], axis=0)
    return idx, sigs


def zero_slot_row(geom: RepoGeometry):
    """The all-zero slot row a DELETED slot must hold — bit-identical to
    the cold builder's ``pad_to(..., 0)`` padding for never-filled slots
    (NOT an empty built tree, whose node boxes would carry +-inf)."""
    n_pad, d = geom.point_capacity, geom.dim
    n_nodes = (1 << (geom.bottom_depth + 1)) - 1
    row = DatasetIndex(
        points=jnp.zeros((n_pad, d), jnp.float32),
        valid=jnp.zeros((n_pad,), bool),
        centers=jnp.zeros((n_nodes, d), jnp.float32),
        radii=jnp.zeros((n_nodes,), jnp.float32),
        box_lo=jnp.zeros((n_nodes, d), jnp.float32),
        box_hi=jnp.zeros((n_nodes, d), jnp.float32),
        counts=jnp.zeros((n_nodes,), jnp.int32),
    )
    return row, jnp.zeros((geom.sig_words,), jnp.uint32)


def upper_from_roots(centers: Array, radii: Array, lo: Array, hi: Array,
                     sigs: Array, valid: Array,
                     upper_depth: int) -> repo_lib.RepoIndex:
    """The Section V-B upper tree from per-slot ROOT summaries — the same
    inf-mask + ``build_repo_index`` sequence as the cold builder, shared
    by the cold oracle, the local updater, and the shard_map updater
    (which all-gathers just these roots, not the slot bodies)."""
    lo = jnp.where(valid[:, None], lo, jnp.inf)
    hi = jnp.where(valid[:, None], hi, -jnp.inf)
    return repo_lib.build_repo_index(centers, radii, lo, hi, sigs, valid,
                                     upper_depth)


def upper_index(ds_index: DatasetIndex, ds_sigs: Array, ds_valid: Array,
                upper_depth: int) -> repo_lib.RepoIndex:
    """:func:`upper_from_roots` fed from full slot arrays."""
    return upper_from_roots(ds_index.centers[:, 0, :],
                            ds_index.radii[:, 0],
                            ds_index.box_lo[:, 0, :],
                            ds_index.box_hi[:, 0, :],
                            ds_sigs, ds_valid, upper_depth)


@lru_cache(maxsize=None)
def _stage_upper(upper_depth: int):
    """THE upper-tree executable for a given depth.  Bit-identity demands
    one executable, not one program: the same reduction compiled inside a
    shard_map body (or fused into a wider jit) can round a node radius one
    ulp differently at some slot counts.  Every path — the cold oracle,
    the live updaters, tier growth — must call this exact jitted stage on
    single-device root summaries (root extraction is pure slicing, so the
    inputs agree bitwise by construction)."""
    return jax.jit(lambda c, r, lo, hi, s, v: upper_from_roots(
        c, r, lo, hi, s, v, upper_depth))


def upper_tree(ds_index: DatasetIndex, ds_sigs: Array, ds_valid: Array,
               geom: RepoGeometry) -> repo_lib.RepoIndex:
    """Upper tree over the LOGICAL ``geom.n_slots`` slots (shard padding
    beyond them never enters the tree), through the shared
    :func:`_stage_upper` executable."""
    B_pad = geom.n_slots
    return _stage_upper(geom.upper_depth)(
        ds_index.centers[:B_pad, 0, :], ds_index.radii[:B_pad, 0],
        ds_index.box_lo[:B_pad, 0, :], ds_index.box_hi[:B_pad, 0, :],
        ds_sigs[:B_pad], ds_valid[:B_pad])


def assemble(ds_index: DatasetIndex, ds_sigs: Array, ds_valid: Array,
             geom: RepoGeometry) -> Repository:
    """Repository from full slot arrays: rebuild the upper tree (shared
    stage, logical slots only) and attach the pinned space bounds."""
    repo = upper_tree(ds_index, ds_sigs, ds_valid, geom)
    lo, hi = geom.space_bounds()
    return Repository(ds_index=ds_index, ds_sigs=ds_sigs,
                      ds_valid=ds_valid, repo=repo,
                      space_lo=lo, space_hi=hi)


def _scatter_rows(rows: DatasetIndex, sigs: Array, slots, geom: RepoGeometry,
                  n_physical: int | None = None):
    """Zero-initialized slot arrays with `rows` scattered at `slots`.

    ``n_physical`` (>= geom.n_slots) pads the slot axis further for
    shard-count alignment — the same zero padding `shard_repository`
    applies."""
    B = n_physical if n_physical is not None else geom.n_slots
    zero_row, zero_sig = zero_slot_row(geom)
    js = jnp.asarray(np.asarray(slots, np.int32))
    ds_index = jax.tree.map(
        lambda z, r: jnp.broadcast_to(z, (B,) + z.shape).at[js].set(r),
        zero_row, rows)
    ds_sigs = jnp.zeros((B, geom.sig_words), jnp.uint32).at[js].set(sigs)
    ds_valid = jnp.zeros((B,), bool).at[js].set(True)
    return ds_index, ds_sigs, ds_valid


def build_frozen(slot_datasets: Sequence, geom: RepoGeometry,
                 n_physical: int | None = None) -> Repository:
    """The bit-identity ORACLE: a cold, slot-preserving build.

    ``slot_datasets[j]`` is the dataset resident in slot j, or None for a
    hole (never-filled or deleted — both are all-zero rows).  After ANY
    mutation sequence, the live repository must equal
    ``build_frozen(current slot contents, geometry)`` bit for bit, and so
    must every op run against it.
    """
    if len(slot_datasets) > geom.n_slots:
        raise ValueError(f"{len(slot_datasets)} slots > capacity "
                         f"{geom.n_slots}")
    filled = [(j, ds) for j, ds in enumerate(slot_datasets)
              if ds is not None]
    if not filled:
        zero_row, _ = zero_slot_row(geom)
        B = n_physical if n_physical is not None else geom.n_slots
        ds_index = jax.tree.map(
            lambda z: jnp.broadcast_to(z, (B,) + z.shape) + 0, zero_row)
        ds_sigs = jnp.zeros((B, geom.sig_words), jnp.uint32)
        ds_valid = jnp.zeros((B,), bool)
        return assemble(ds_index, ds_sigs, ds_valid, geom)
    rows, sigs = build_rows([ds for _, ds in filled], geom)
    ds_index, ds_sigs, ds_valid = _scatter_rows(
        rows, sigs, [j for j, _ in filled], geom, n_physical)
    return assemble(ds_index, ds_sigs, ds_valid, geom)


def init_live(
    datasets: Sequence[np.ndarray],
    *,
    leaf_capacity: int = 16,
    repo_leaf_capacity: int | None = None,
    theta: int = 5,
    remove_outliers: bool = True,
    point_capacity: int | None = None,
    slot_headroom: int = 0,
) -> tuple[Repository, RepoGeometry]:
    """The cold build (Alg. 1's op order), restructured to PIN its
    geometry and to run every per-dataset stage through the canonical
    BATCH-OF-1 executables — so the initial repository is bit-identical
    to :func:`build_frozen` of the same datasets, and every later
    incremental row equals what this build would have produced.

    The repository-global quantities keep their cold derivations: the
    bottom depth from the largest dataset, r' from the POOLED leaf radii
    of all bottom trees (Eq. 3), the grid bounds from the union of the
    refined root MBRs.  ``point_capacity`` reserves bottom-tree headroom
    for future ingests of larger datasets; ``slot_headroom`` adds that
    many doublings of slot capacity up front.
    """
    if repo_leaf_capacity is None:
        repo_leaf_capacity = leaf_capacity
    n_max = max(int(x.shape[0]) for x in datasets)
    depth_b = index_lib.depth_for(n_max, leaf_capacity)
    if point_capacity is not None:
        if point_capacity < n_max:
            raise ValueError(f"point_capacity {point_capacity} < largest "
                             f"initial dataset ({n_max} points)")
        depth_b = max(depth_b,
                      index_lib.depth_for(point_capacity, leaf_capacity))
    B = len(datasets)
    # geometry skeleton: enough for pad_one/_stage_build (bottom layout);
    # bounds / r' / upper depth are filled in below once derived
    geom = RepoGeometry(
        leaf_capacity=leaf_capacity,
        bottom_depth=depth_b,
        repo_leaf_capacity=repo_leaf_capacity,
        upper_depth=0,
        theta=theta,
        space_lo=(),
        space_hi=(),
        r_prime=None,
    )
    build = _stage_build(depth_b)
    built = []
    for ds in datasets:
        pts, val = pad_one(np.asarray(ds, np.float32), geom)
        built.append(build(jnp.asarray(pts), jnp.asarray(val)))

    r_prime = None
    if remove_outliers:
        # Eq. 3 over the POOLED leaf radii of every bottom tree — same
        # pooling as the cold builder, values from the canonical rows.
        # Round-trip through float32 BEFORE refining so init uses the
        # exact operand every later pinned-r' ingest will use.
        leaf_r = jnp.concatenate(
            [index_lib.leaf_radii(b).reshape(-1) for b in built])
        leaf_c = jnp.concatenate(
            [index_lib.leaf_counts(b).reshape(-1) for b in built])
        r_prime = float(np.float32(
            outliers_lib.kneedle_threshold(leaf_r, leaf_c > 0)))
        refine = _stage_outliers()
        built = [refine(b, jnp.float32(r_prime)) for b in built]

    space_lo = jnp.min(jnp.concatenate(
        [b.box_lo[:, 0, :2] for b in built]), axis=0)
    space_hi = jnp.max(jnp.concatenate(
        [b.box_hi[:, 0, :2] for b in built]), axis=0)

    depth_u = repo_lib.depth_for_repo(B, repo_leaf_capacity) + slot_headroom
    geom = replace(geom,
                   upper_depth=depth_u,
                   space_lo=_floats(space_lo),
                   space_hi=_floats(space_hi),
                   r_prime=r_prime)

    sig = _stage_sig(geom.theta, geom.space_lo, geom.space_hi)
    sigs = jnp.concatenate([sig(b.points, b.valid) for b in built], axis=0)
    idx = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *built)
    ds_index, ds_sigs, ds_valid = _scatter_rows(idx, sigs, np.arange(B),
                                                geom)
    return assemble(ds_index, ds_sigs, ds_valid, geom), geom


def scatter_slots(repo: Repository, slots: Array, rows: DatasetIndex,
                  sigs: Array, valids: Array):
    """Slot arrays with the (N, ...) batched ``rows``/``sigs``/``valids``
    scattered at ``slots`` — the shared write kernel of every batched
    publish.  Scatter is pure data movement (no reductions), so writing N
    rows in one dispatch is bitwise equal to N sequential single-row
    scatters as long as ``slots`` carries no conflicting duplicates
    (callers dedup last-write-wins; padding a group by REPEATING its last
    (slot, row) entry is safe — duplicate indices with bitwise-identical
    update values give the same result under any XLA application order).
    """
    ds_index = jax.tree.map(lambda a, r: a.at[slots].set(r),
                            repo.ds_index, rows)
    ds_sigs = repo.ds_sigs.at[slots].set(sigs)
    ds_valid = repo.ds_valid.at[slots].set(valids)
    return ds_index, ds_sigs, ds_valid


def update_slots(repo: Repository, slots: Array, rows: DatasetIndex,
                 sigs: Array, valids: Array, *, geom: RepoGeometry
                 ) -> Repository:
    """Functional MULTI-slot update: one scatter dispatch and ONE
    upper-tree rebuild for N mutations (ingest / replace / delete mixed
    freely — a delete is a zero row with ``valids[i]=False``), instead of
    N of each.  This is the device side of a COALESCED publish: a run of
    consecutive mutations with no intervening queries lands as a single
    batched write, and the (tiny) upper tree is rebuilt once from the
    refreshed roots.  Slots, rows, and validity are DYNAMIC operands, so
    one jitted executable per group size serves every mutation mix on
    every slot of the current tier.

    NOT donated: the previous repository's buffers stay intact, so an
    in-flight query keeps computing against the consistent pre-mutation
    snapshot while future queries see the new one — the repository is
    never torn.
    """
    ds_index, ds_sigs, ds_valid = scatter_slots(repo, slots, rows, sigs,
                                                valids)
    return assemble(ds_index, ds_sigs, ds_valid, geom)


def update_slot(repo: Repository, slot: Array, row: DatasetIndex,
                sig: Array, valid: Array, *, geom: RepoGeometry
                ) -> Repository:
    """Single-slot :func:`update_slots` (kept for callers holding an
    unbatched row; the batched form is the publish path)."""
    return update_slots(
        repo, jnp.asarray(slot)[None],
        jax.tree.map(lambda x: x[None], row), sig[None],
        jnp.asarray(valid)[None], geom=geom)


def pad_slots(repo: Repository, n_physical: int):
    """The slot arrays zero-padded to ``n_physical`` rows (the grown
    tier's shard-aligned physical count) — a device-side pad preserving
    the global slot order; no host re-upload, no tree."""
    cur = repo.ds_sigs.shape[0]
    if n_physical < cur:
        raise ValueError(f"grow target {n_physical} < current {cur} slots")

    def pad(x):
        z = jnp.zeros((n_physical - cur,) + x.shape[1:], x.dtype)
        return jnp.concatenate([x, z], axis=0)

    return (jax.tree.map(pad, repo.ds_index), pad(repo.ds_sigs),
            pad(repo.ds_valid))


def grow_slots(repo: Repository, geom: RepoGeometry,
               n_physical: int | None = None) -> Repository:
    """Pad the slot axis with zero rows up to the next tier (``geom`` is
    the GROWN geometry) and rebuild the upper tree at its depth."""
    B = n_physical if n_physical is not None else geom.n_slots
    ds_index, ds_sigs, ds_valid = pad_slots(repo, B)
    return assemble(ds_index, ds_sigs, ds_valid, geom)
