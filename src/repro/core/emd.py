"""Top-k EMD exemplar search (the paper's companion metric [67], available
in the authors' online Spadas demo; Section VII mentions it ships with the
system).

Exact EMD is O(n^3); the paper's own EMD work [67] prunes with grid
signatures.  We implement the z-order-histogram form on the unified index:
each dataset is a mass histogram over the 4^theta Morton cells (the same
grid the signatures use), and EMD is computed with entropy-regularized
Sinkhorn iterations on the cell-center cost matrix — fully batched over
candidate datasets, one `lax.scan` per Sinkhorn run, TPU-native.

Pruning reuses the repository tree: a dataset whose signature does not
intersect the query's dilated signature cannot have small EMD; the dense
GBO pass provides that filter for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zorder
from repro.core.index import DatasetIndex
from repro.core.repo_index import Repository

Array = jax.Array


def cell_histogram(points: Array, valid: Array, lo: Array, hi: Array,
                   theta: int) -> Array:
    """Normalized mass histogram over Morton cells: (4^theta,) f32."""
    n_cells = zorder.num_cells(theta)
    ids = zorder.cell_ids(points, lo, hi, theta)
    ids = jnp.where(valid, ids, n_cells)
    h = jnp.zeros((n_cells + 1,), jnp.float32).at[ids].add(1.0)[:n_cells]
    return h / jnp.maximum(h.sum(), 1.0)


def cell_centers(lo: Array, hi: Array, theta: int) -> Array:
    """(4^theta, 2) coordinates of cell centers (for the cost matrix)."""
    n = 1 << theta
    ids = jnp.arange(zorder.num_cells(theta), dtype=jnp.uint32)
    x = ids & jnp.uint32(0x55555555)
    x = (x | (x >> 1)) & jnp.uint32(0x33333333)
    x = (x | (x >> 2)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x >> 4)) & jnp.uint32(0x00FF00FF)
    x = (x | (x >> 8)) & jnp.uint32(0x0000FFFF)
    y = (ids >> 1) & jnp.uint32(0x55555555)
    y = (y | (y >> 1)) & jnp.uint32(0x33333333)
    y = (y | (y >> 2)) & jnp.uint32(0x0F0F0F0F)
    y = (y | (y >> 4)) & jnp.uint32(0x00FF00FF)
    y = (y | (y >> 8)) & jnp.uint32(0x0000FFFF)
    span = (hi - lo)
    cx = lo[0] + (x.astype(jnp.float32) + 0.5) / n * span[0]
    cy = lo[1] + (y.astype(jnp.float32) + 0.5) / n * span[1]
    return jnp.stack([cx, cy], axis=-1)


def sinkhorn_emd(a: Array, b: Array, cost: Array, *, reg: float = 0.05,
                 iters: int = 100) -> Array:
    """Entropy-regularized EMD between histograms a, b over `cost` (n, n).

    Returns the transport cost <P, C>.  Masses are re-normalized; empty
    histograms yield 0."""
    eps = 1e-9
    a = a / jnp.maximum(a.sum(), eps)
    b = b / jnp.maximum(b.sum(), eps)
    K = jnp.exp(-cost / reg)

    def step(uv, _):
        u, v = uv
        u = a / jnp.maximum(K @ v, eps)
        v = b / jnp.maximum(K.T @ u, eps)
        return (u, v), None

    u0 = jnp.ones_like(a)
    v0 = jnp.ones_like(b)
    (u, v), _ = jax.lax.scan(step, (u0, v0), None, length=iters)
    P = u[:, None] * K * v[None, :]
    return jnp.sum(P * cost)


def topk_emd(repo: Repository, q_pts: Array, q_valid: Array, k: int, *,
             theta: int = 4, reg_cells: float = 0.5, iters: int = 100,
             prefilter: int = 0):
    """Top-k datasets by (Sinkhorn-approximate) EMD to the query.

    theta is the HISTOGRAM resolution (4^theta bins; keep <= 5 so the cost
    matrix (4^theta)^2 stays small).  `prefilter`: evaluate EMD only on the
    top-`prefilter` datasets by GBO overlap (0 = all) — the unified-index
    batch prune, mirroring the paper's [67] signature filter.
    """
    lo, hi = repo.space_lo, repo.space_hi
    centers = cell_centers(lo, hi, theta)
    scale = jnp.sqrt(jnp.sum((hi - lo) ** 2))
    cost = jnp.sqrt(
        jnp.sum((centers[:, None] - centers[None, :]) ** 2, axis=-1)) / scale
    reg = reg_cells / (1 << theta)

    q_hist = cell_histogram(q_pts, q_valid, lo, hi, theta)
    hists = jax.vmap(
        lambda p, v: cell_histogram(p, v, lo, hi, theta)
    )(repo.ds_index.points, repo.ds_index.valid)

    if prefilter and prefilter < repo.n_slots:
        # unified-index batch prune (the [67] signature filter): histogram
        # overlap orders candidates; only the top-`prefilter` run Sinkhorn
        scores = hists @ q_hist
        scores = jnp.where(repo.ds_valid, scores, -1.0)
        _, cand = jax.lax.top_k(scores, prefilter)
        sub = hists[cand]
        emds = jax.vmap(lambda h: sinkhorn_emd(q_hist, h, cost, reg=reg,
                                               iters=iters))(sub)
        emds_full = jnp.full((repo.n_slots,), jnp.inf).at[cand].set(emds)
    else:
        emds_full = jax.vmap(
            lambda h: sinkhorn_emd(q_hist, h, cost, reg=reg, iters=iters)
        )(hists)
    emds_full = jnp.where(repo.ds_valid, emds_full, jnp.inf)
    vals, ids = jax.lax.top_k(-emds_full, k)
    return -vals, ids
