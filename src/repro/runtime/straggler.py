"""Straggler mitigation: step-deadline watchdog + slow-rank policy.

On a synchronous SPMD mesh a straggling host stalls every collective, so
mitigation is (a) detection via step-time records, (b) policy: either
re-admit (transient), hot-spare swap, or elastic shrink (runtime/elastic).

The watchdog is deliberately host-side and framework-agnostic: it measures
wall time around the blocking `jax.block_until_ready` of each step, keeps a
robust (median + MAD) model of expected step time, and raises a
StragglerEvent when `k` consecutive steps exceed the deadline.  The trainer
(launch/train.py) responds by checkpointing and invoking the remesh plan —
exercised end-to-end in tests/test_fault_tolerance.py with simulated delays.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque


class StragglerEvent(RuntimeError):
    def __init__(self, step: int, step_time: float, deadline: float):
        super().__init__(
            f"step {step}: {step_time:.3f}s exceeded deadline "
            f"{deadline:.3f}s")
        self.step = step
        self.step_time = step_time
        self.deadline = deadline


@dataclasses.dataclass
class WatchdogConfig:
    window: int = 50           # steps in the rolling model
    warmup: int = 5            # ignore first N steps (compile)
    tolerance: float = 3.0     # deadline = median * tolerance
    min_deadline_s: float = 1e-3
    consecutive: int = 2       # trips after N consecutive violations


class StepWatchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig()):
        self.cfg = cfg
        self.times: deque[float] = deque(maxlen=cfg.window)
        self.step = 0
        self._t0 = None
        self._violations = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self):
        """Record one step; raises StragglerEvent when the policy trips."""
        dt = time.perf_counter() - self._t0
        self.step += 1
        if self.step <= self.cfg.warmup:
            return dt
        deadline = self.deadline()
        self.times.append(dt)
        if deadline is not None and dt > deadline:
            self._violations += 1
            if self._violations >= self.cfg.consecutive:
                raise StragglerEvent(self.step, dt, deadline)
        else:
            self._violations = 0
        return dt

    def deadline(self) -> float | None:
        if len(self.times) < 3:
            return None
        s = sorted(self.times)
        median = s[len(s) // 2]
        return max(median * self.cfg.tolerance, self.cfg.min_deadline_s)
