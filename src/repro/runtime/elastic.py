"""Elastic scaling + failure handling (DESIGN.md sec. 4).

The contract at 1000+ nodes: any pod/host can vanish; the job must resume
on the surviving mesh from the last committed checkpoint, with parameters
RE-SHARDED to the new topology.  Because checkpoints store logical arrays +
the logical->physical rule table (checkpoint/ckpt.py), re-sharding is just
`device_put` with shardings derived for the NEW mesh — no format migration.

`plan_remesh` computes the next mesh after excluding failed devices, always
keeping the model axis intact (TP requires a full ring) and shrinking the
data/pod axes, which only changes the gradient all-reduce span — training
semantics are preserved by re-scaling the per-device batch.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: dict
    new_shape: dict
    lost_devices: int
    per_device_batch_factor: float  # batch rescale to keep global batch

    @property
    def new_axis_sizes(self) -> tuple:
        return tuple(self.new_shape.values())


def plan_remesh(mesh_shape: dict, failed: int) -> RemeshPlan:
    """Shrink the mesh after `failed` device losses.

    Policy: keep 'model' intact; round ('pod' x 'data') DOWN to the largest
    size expressible as pod' x data' with pod' in {1, .., pod}."""
    model = mesh_shape.get("model", 1)
    pod = mesh_shape.get("pod", 1)
    data = mesh_shape.get("data", 1)
    total_replicas = pod * data
    avail = pod * data * model - failed
    max_replicas = avail // model
    if max_replicas < 1:
        raise RuntimeError("not enough devices for one model replica")
    # prefer keeping pod structure if possible
    best = None
    for p in range(pod, 0, -1):
        d = max_replicas // p
        if d >= 1:
            best = (p, d)
            break
    new = {}
    if "pod" in mesh_shape:
        new["pod"] = best[0]
    new["data"] = best[1]
    new["model"] = model
    new_replicas = best[0] * best[1]
    return RemeshPlan(
        old_shape=dict(mesh_shape),
        new_shape=new,
        lost_devices=failed,
        per_device_batch_factor=total_replicas / new_replicas,
    )


def make_mesh_from_plan(plan: RemeshPlan):
    names = tuple(plan.new_shape.keys())
    sizes = tuple(plan.new_shape.values())
    return jax.make_mesh(sizes, names)


def reshard_tree(tree, new_shardings):
    """Move a (host or device) pytree onto new-mesh shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        tree, new_shardings)
