"""Sharded, fault-tolerant checkpointing (no external deps).

Layout:  <dir>/step_<N>/
           manifest.json     — tree structure, leaf shapes/dtypes, logical
                               sharding rules, data-pipeline cursor
           shard_<i>.npz     — flat leaf arrays (np), chunked by size
           COMMITTED         — atomic commit marker (written last)

Fault-tolerance properties:
  * step-atomic: a crash mid-save leaves no COMMITTED marker; restore picks
    the newest committed step;
  * elastic: arrays are saved UNSHARDED-logical (gathered per leaf) with the
    logical rule table in the manifest, so restore can re-shard onto ANY
    mesh (different pod/data/model sizes) — runtime/elastic.py;
  * async: `save_async` snapshots to host then writes in a thread so the
    train loop continues.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import QTensor

_MAX_SHARD_BYTES = 1 << 30


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QTensor))
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extra: dict | None = None) -> Path:
    """Synchronous sharded save with atomic commit."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:08d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp_dir.exists():
        shutil.rmtree(tmp_dir)
    tmp_dir.mkdir(parents=True)

    flat, _ = _flatten_with_paths(tree)
    manifest: dict[str, Any] = {"step": step, "extra": extra or {},
                                "leaves": {}, "qtensors": {}}
    shard_idx, shard_bytes, shard_buf = 0, 0, {}

    def flush():
        nonlocal shard_idx, shard_bytes, shard_buf
        if shard_buf:
            np.savez(tmp_dir / f"shard_{shard_idx}.npz", **shard_buf)
            shard_idx += 1
            shard_bytes, shard_buf = 0, {}

    def add(key, arr):
        nonlocal shard_bytes
        a = np.asarray(jax.device_get(arr))
        manifest["leaves"][key] = {
            "shard": shard_idx, "shape": list(a.shape), "dtype": str(a.dtype)}
        shard_buf[key.replace("/", "__")] = a
        shard_bytes += a.nbytes
        if shard_bytes > _MAX_SHARD_BYTES:
            flush()

    for key, leaf in flat:
        if isinstance(leaf, QTensor):
            manifest["qtensors"][key] = {"shape": list(leaf.shape)}
            add(key + "/codes", leaf.codes)
            add(key + "/scales", leaf.scales)
        else:
            add(key, leaf)
    flush()

    (tmp_dir / "manifest.json").write_text(json.dumps(manifest))
    (tmp_dir / "COMMITTED").write_text(str(time.time()))
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp_dir.rename(step_dir)
    return step_dir


class AsyncSaver:
    """Snapshot-to-host then background write; at most one in flight."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, ckpt_dir, step, tree, extra=None):
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot (QTensor is a pytree)
        self._thread = threading.Thread(
            target=save, args=(ckpt_dir, step, host_tree, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "COMMITTED").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, tree_like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like`; optionally re-shard onto a
    (possibly different) mesh via `shardings` (elastic restart)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())

    shards: dict[int, Any] = {}

    def get(key):
        info = manifest["leaves"][key]
        si = info["shard"]
        if si not in shards:
            shards[si] = np.load(step_dir / f"shard_{si}.npz")
        return shards[si][key.replace("/", "__")]

    flat, treedef = _flatten_with_paths(tree_like)
    shard_flat = None
    if shardings is not None:
        shard_flat, _ = _flatten_with_paths(shardings)
        shard_flat = dict(shard_flat)

    restored = []
    for key, leaf in flat:
        if isinstance(leaf, QTensor):
            q = QTensor(jnp.asarray(get(key + "/codes")),
                        jnp.asarray(get(key + "/scales")),
                        tuple(manifest["qtensors"][key]["shape"]))
            restored.append(q)
        else:
            a = get(key)
            if shard_flat is not None and key in shard_flat and not isinstance(
                    shard_flat[key], QTensor):
                a = jax.device_put(a, shard_flat[key])
            else:
                a = jnp.asarray(a)
            restored.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    return tree, manifest["extra"]
