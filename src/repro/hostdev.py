"""Force N XLA host-platform devices BEFORE jax's first import.

The one shared implementation of the CPU scale-out switch: XLA pins the
device count at first jax init, so anything that wants a multi-device mesh
on a CPU-only machine must set the flag before ``import jax`` anywhere in
the process.  Entry points call::

    from repro import hostdev
    hostdev.apply()          # reads REPRO_HOST_DEVICES; no-op unless set

Used by tests/conftest.py (the multi-device CI job), bench_engine.py, and
serve_search.py.  This module must stay jax-free.
"""
from __future__ import annotations

import os
import sys

ENV_VAR = "REPRO_HOST_DEVICES"
_FLAG = "xla_force_host_platform_device_count"


def apply(n_devices: int | str | None = None) -> bool:
    """Request `n_devices` forced host devices (default: $REPRO_HOST_DEVICES).

    Returns True iff the flag was installed.  A no-op (False) when the env
    var is unset, jax is already imported (too late to take effect), or
    XLA_FLAGS already pins a device count (first writer wins)."""
    n = n_devices if n_devices is not None else os.environ.get(ENV_VAR)
    if not n or "jax" in sys.modules:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if _FLAG in flags:
        return False
    os.environ["XLA_FLAGS"] = f"{flags} --{_FLAG}={n}".strip()
    return True
