"""Training step: loss, grads, clipping, (optional) compression, AdamW.

The step is a pure function (params, opt_state, batch, step) -> (...) built
per-config so it can be jitted with explicit in/out shardings by both the
real trainer (launch/train.py) and the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.sharding_rules import shard
from repro.train import compression as comp
from repro.train import optimizer as opt_lib
from repro.train.optimizer import OptConfig, OptState

Array = jax.Array

AUX_LOSS_WEIGHT = 0.01
IGNORE = -1


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    err: Any | None      # compression error feedback (or None)
    step: Array


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptConfig,
                     *, param_dtype=jnp.float32, compress: bool = False):
    params = M.init_params(key, cfg, dtype=param_dtype)
    opt_state = opt_lib.init_state(params, opt_cfg)
    err = comp.init_error(params) if compress else None
    return TrainState(params, opt_state, err, jnp.zeros((), jnp.int32))


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = M.forward(params, cfg, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    take = jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[..., None], axis=-1,
        mode="clip")[..., 0]
    mask = (labels != IGNORE).astype(jnp.float32)
    ce = -(take * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + AUX_LOSS_WEIGHT * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    *, compress: bool = False, microbatch: int = 0,
                    param_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatch > 0 splits the batch into accumulation chunks (scan) — the
    compute/memory knob for giant archs.

    param_shardings (optional): pin each gradient leaf to its parameter's
    sharding before the optimizer.  Without this, GSPMD picks cotangent
    layouts from the loss side and the parameter update needs a
    replicate-and-repartition per leaf ("involuntary full
    rematerialization") — §Perf iteration 1 removes TBs/device of temps.
    """

    def grads_of(params, batch):
        if not microbatch:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, batch)
            return loss, ce, aux, grads

        def one(carry, mb):
            acc, tot = carry
            (loss, (ce, aux)), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, tot + jnp.array([loss, ce, aux])), None

        n_mb = batch["labels"].shape[0] // microbatch
        mbs = jax.tree.map(
            lambda x: x.reshape((n_mb, microbatch) + x.shape[1:]), batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, tot), _ = jax.lax.scan(one, (zeros, jnp.zeros(3)), mbs)
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        loss, ce, aux = tot / n_mb
        return loss, ce, aux, grads

    def train_step(state: TrainState, batch):
        loss, ce, aux, grads = grads_of(state.params, batch)
        if param_shardings is not None:
            grads = jax.tree.map(
                jax.lax.with_sharding_constraint, grads, param_shardings)
        err = state.err
        if compress:
            grads, err = comp.compress_with_feedback(grads, err)
        params, opt_state, gnorm = opt_lib.apply_updates(
            state.params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "grad_norm": gnorm}
        return TrainState(params, opt_state, err, state.step + 1), metrics

    return train_step
