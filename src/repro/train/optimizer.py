"""AdamW with optional block-quantized (int8) first/second moments.

The int8 states (blockwise absmax linear quantization, à la 8-bit Adam
[arXiv:2110.02861]) cut optimizer memory from 8 to ~2.06 bytes/param —
that is what lets arctic-480b / grok-314b train_4k fit the 256-chip
single-pod memory budget (DESIGN.md sec. 4); dense ≤33B archs default to
fp32 states.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    state_dtype: str = "fp32"        # "fp32" | "int8"
    q_block: int = 256


# ---------------------------------------------------------------------------
# blockwise int8 quantization
# ---------------------------------------------------------------------------


class QTensor(NamedTuple):
    codes: Array    # int8, (*lead, n_blocks, block) — LAST-axis blocking so
                    # the parent param's sharding carries over unchanged
                    # (flat blocking forced a full reshard every step; see
                    # EXPERIMENTS.md §Perf iteration 1)
    scales: Array   # fp32, (*lead, n_blocks)
    shape: tuple    # static original shape (aux data in pytree)

    def size_bytes(self) -> int:
        return self.codes.size + 4 * self.scales.size


def _quantize(x: Array, block: int) -> QTensor:
    shape = x.shape
    x = x.astype(jnp.float32)
    last = shape[-1]
    pad = (-last) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(*shape[:-1], -1, block)
    scales = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scales = jnp.maximum(scales, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scales[..., None]), -127, 127)
    return QTensor(codes.astype(jnp.int8), scales, shape)


def _dequantize(q: QTensor) -> Array:
    x = (q.codes.astype(jnp.float32) * q.scales[..., None])
    x = x.reshape(*q.shape[:-1], -1)
    return x[..., : q.shape[-1]]


jax.tree_util.register_pytree_node(
    QTensor,
    lambda q: ((q.codes, q.scales), q.shape),
    lambda shape, ch: QTensor(ch[0], ch[1], shape),
)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class OptState(NamedTuple):
    m: Any
    v: Any
    count: Array


def init_state(params, cfg: OptConfig) -> OptState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_dtype == "int8" and p.ndim >= 2:
            return _quantize(z, cfg.q_block)
        return z

    m = jax.tree.map(zero_like, params)
    v = jax.tree.map(zero_like, params)
    return OptState(m, v, jnp.zeros((), jnp.int32))


def _schedule(cfg: OptConfig, step: Array) -> Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """One AdamW step (with de/re-quantization of int8 states)."""
    count = state.count + 1
    lr = _schedule(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mq = isinstance(m, QTensor)
        m_f = _dequantize(m) if mq else m
        v_f = _dequantize(v) if mq else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        u = (m_f / c1) / (jnp.sqrt(v_f / c2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        if mq:
            return new_p, _quantize(m_f, cfg.q_block), _quantize(v_f, cfg.q_block)
        return new_p, m_f, v_f

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), gn
