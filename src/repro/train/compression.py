"""Error-feedback gradient compression (int8) for cross-pod data parallel.

1-bit/8-bit compressed all-reduce with an error accumulator [Seide et al.;
arXiv:1802.06058 style].  In SPMD form the quantization happens before the
(implicit) gradient reduction and the residual is carried in the train
state, so the compression error is re-injected next step — unbiased in the
long run.  Enabled per-run; the dry-run variant shows the collective-bytes
reduction in the roofline table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, block: int = 256):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127)
    return codes.astype(jnp.int8), scales


def dequantize_int8(codes, scales, shape):
    flat = (codes.astype(jnp.float32) * scales[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_with_feedback(grads, err):
    """Quantize (grads + err) to int8; return (dequantized grads, new err).

    err is a pytree of fp32 residuals matching grads (zeros initially)."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        codes, scales = quantize_int8(target)
        g_hat = dequantize_int8(codes, scales, g.shape)
        return g_hat, target - g_hat

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
