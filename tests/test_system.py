"""End-to-end behaviour tests: the paper's full pipeline (build index ->
multi-granularity search -> point search) and the framework integration
(Spadas curation -> token pipeline -> training)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_clustered_datasets
from repro.core import point_search, search, zorder
from repro.core.build import build_query_index, build_repository
from repro.data import discovery, synthetic, tokens as tok_lib
from repro import configs
from repro.train import optimizer as opt_lib, train_step as ts


def test_multi_granularity_pipeline():
    """The Fig. 1 user journey: RangeS -> ExempS -> RangeP -> NNP."""
    datasets = make_clustered_datasets(40, seed=3)
    repo, info = build_repository(datasets, leaf_capacity=16, theta=5)
    Q = datasets[5]
    q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=5)

    # 1. coarse: datasets in a region
    qlo, qhi = jnp.asarray(Q.min(0)), jnp.asarray(Q.max(0))
    mask, _ = search.range_search(repo, qlo, qhi)
    assert bool(mask[5])          # Q's own source dataset overlaps

    # 2. coarse: exemplar search (three metrics agree on the trivial match)
    v_ia, i_ia = search.topk_ia(repo, qlo, qhi, 3)
    v_gb, i_gb = search.topk_gbo(repo, q_sig, 3)
    v_h, i_h, _ = search.topk_hausdorff(repo, q_idx, 3)
    assert int(i_h[0]) == 5 and float(v_h[0]) < 1e-3   # H(Q,Q)=0
    assert 5 in np.asarray(i_gb).tolist()

    # 3. fine: points of the best dataset inside the region
    best = int(i_h[1])            # most similar *other* dataset
    d_idx = jax.tree.map(lambda x: x[best], repo.ds_index)
    take, _ = point_search.range_points(d_idx, qlo, qhi)
    pts = np.asarray(d_idx.points)[np.asarray(take)]
    assert ((pts >= np.asarray(qlo) - 1e-5).all()
            and (pts <= np.asarray(qhi) + 1e-5).all())

    # 4. fine: NN points for every query point
    dist, idx, stats = point_search.nnp_pruned(q_idx, d_idx)
    assert stats.pruned_fraction >= 0.0
    assert bool(jnp.isfinite(dist).all())


def test_spadas_curation_to_training():
    """Data-layer integration: curate -> tokenize -> train 10 steps."""
    lake = synthetic.trajectory_repository(32, seed=0)
    selected, repo, info = discovery.curate(lake, lake[0], k=12, theta=5)
    assert len(selected) >= 4
    cfg = configs.get_reduced("spadas_trajlm")
    pipe = discovery.pipeline_from_selection(lake, selected, repo, theta=5,
                                             seq_len=64, batch=2)
    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=2)
    state = ts.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(ts.make_train_step(cfg, opt_cfg))
    losses = []
    for _ in range(10):
        b = pipe.next_batch()
        assert b["tokens"].max() < cfg.vocab_size
        state, m = step(state, jax.tree.map(jnp.asarray, b))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_index_construction_scales_with_outliers_removed():
    datasets = synthetic.poi_repository(24, seed=7, outlier_frac=0.05)
    repo_noor, _ = build_repository(datasets, remove_outliers=False)
    repo_or, info = build_repository(datasets, remove_outliers=True)
    live_before = int(np.asarray(repo_noor.ds_index.valid).sum())
    live_after = int(np.asarray(repo_or.ds_index.valid).sum())
    assert live_after < live_before            # something was removed
    assert live_after > 0.8 * live_before      # but not the data itself
    # removal shrinks dataset radii (the Fig. 5 effect)
    r_b = np.asarray(repo_noor.ds_index.radii[:, 0])
    r_a = np.asarray(repo_or.ds_index.radii[:, 0])
    assert r_a.mean() < r_b.mean()
