"""Per-kernel allclose sweeps against the ref.py pure-jnp oracles
(spec deliverable c): shapes x dtypes x mask patterns, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(256, 512), (512, 1024), (300, 700), (257, 513)]
DIMS = [2, 3, 8, 11]


def _mk(rng, nq, nd, d, dtype):
    q = rng.normal(size=(nq, d)).astype(dtype)
    dd = rng.normal(loc=0.5, size=(nd, d)).astype(dtype)
    qv = rng.random(nq) > 0.05
    dv = rng.random(nd) > 0.05
    qv[0] = dv[0] = True
    return (jnp.asarray(q), jnp.asarray(dd), jnp.asarray(qv),
            jnp.asarray(dv))


@pytest.mark.parametrize("nq,nd", SHAPES)
@pytest.mark.parametrize("d", DIMS)
def test_hausdorff_kernel_sweep(nq, nd, d):
    rng = np.random.default_rng(nq + nd + d)
    q, dd, qv, dv = _mk(rng, nq, nd, d, np.float32)
    got = ops.directed_hausdorff(q, dd, qv, dv)
    want = ref.directed_hausdorff(q, dd, qv, dv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("nq,nd", SHAPES[:2])
def test_nn_distance_kernel_sweep(nq, nd):
    rng = np.random.default_rng(nq)
    q, dd, qv, dv = _mk(rng, nq, nd, 2, np.float32)
    gd, gi = ops.nn_distance(q, dd, qv, dv)
    wd, wi = ref.nn_distance(q, dd, qv, dv)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    assert (np.asarray(gi) == np.asarray(wi)).all()


@pytest.mark.parametrize("n,m", [(256, 256), (300, 400), (512, 257)])
@pytest.mark.parametrize("d", [2, 3])
def test_bound_matrix_kernel_sweep(n, m, d):
    rng = np.random.default_rng(n + m)
    oq = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    od = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    rq = jnp.asarray(rng.uniform(0, 2, n).astype(np.float32))
    rd = jnp.asarray(rng.uniform(0, 2, m).astype(np.float32))
    glb, gub = ops.bound_matrices(oq, rq, od, rd)
    wlb, wub = ref.bound_matrix(oq, rq, od, rd)
    np.testing.assert_allclose(glb, wlb, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gub, wub, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("na,nb,w", [(256, 256, 32), (300, 270, 8),
                                     (512, 300, 64)])
def test_set_intersect_kernel_sweep(na, nb, w):
    rng = np.random.default_rng(na + w)
    sa = jnp.asarray(rng.integers(0, 2**32, (na, w), dtype=np.uint32))
    sb = jnp.asarray(rng.integers(0, 2**32, (nb, w), dtype=np.uint32))
    got = ops.set_intersect_counts(sa, sb)
    want = ref.set_intersect_count(sa, sb)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_hausdorff_bf16_tolerance():
    rng = np.random.default_rng(9)
    q, dd, qv, dv = _mk(rng, 256, 512, 2, np.float32)
    got = ops.directed_hausdorff(q.astype(jnp.bfloat16).astype(jnp.float32),
                                 dd, qv, dv)
    want = ref.directed_hausdorff(q, dd, qv, dv)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_kernel_vs_ref_path_boundary():
    """Sizes below tile thresholds must route to ref and stay correct."""
    rng = np.random.default_rng(3)
    q, dd, qv, dv = _mk(rng, 10, 20, 2, np.float32)
    got = ops.directed_hausdorff(q, dd, qv, dv)
    want = ref.directed_hausdorff(q, dd, qv, dv)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("nq,nd", [(24, 100), (32, 130)])
def test_hausdorff_grid_matches_op_per_pair(nq, nd):
    """The (B, C) pair-grid evaluator (ExactHaus phase-2 hot path) must be
    BITWISE equal per pair to the jitted per-pair op (the host oracle's
    evaluation path) on sub-threshold shapes — tiled streaming (incl. a
    non-tile-multiple nd, which pads with invalid columns) reassociates
    only exact min/max.  The eager ref differs by fusion ulps (no FMA
    contraction outside jit), which is why the pipeline bit-identity
    contract is stated between the jitted programs."""
    rng = np.random.default_rng(nq + nd)
    B, C = 3, 4
    q = jnp.asarray(rng.normal(size=(B, nq, 2)).astype(np.float32))
    ds = jnp.asarray(rng.normal(size=(B, C, nd, 2)).astype(np.float32))
    qv = jnp.asarray(rng.random((B, nq)) > 0.1)
    dv = jnp.asarray(rng.random((B, C, nd)) > 0.3)
    got = np.asarray(ops.directed_hausdorff_grid(q, ds, qv, dv, tile=64))
    per_pair = jax.jit(jax.vmap(ref.directed_hausdorff,
                                in_axes=(None, 0, None, 0)))
    for b in range(B):
        want = np.asarray(per_pair(q[b], ds[b], qv[b], dv[b]))
        np.testing.assert_array_equal(got[b], want)


# ---------------------------------------------------------------------------
# Routing-boundary bit-identity (autotuner safety net): at, just below, and
# just above every kernel-vs-ref threshold the DEFAULT route must be bitwise
# one of the two explicitly-forced routes (routing determinism — resolve()
# picks a path, it never computes a third thing), and the two forced routes
# must agree with each other.  Kernel-vs-ref agreement is asserted BITWISE
# wherever XLA's FMA-contraction choice coincides for the two program
# shapes (empirically stable at the pinned shapes below) and within ~ulp
# tolerance elsewhere; production routing shifts are additionally gated
# bitwise per shape bucket by the engine tuner (engine/tune.py), so a
# tuned table can never shift a result.
# ---------------------------------------------------------------------------

BOUNDARY = [(255, 512), (256, 512), (257, 513)]


def _routes(fn, *args, **kw):
    """(default, forced-kernel, forced-ref) outputs of one op."""
    return (np.asarray(fn(*args, **kw)),
            np.asarray(fn(*args, use_kernel=True, **kw)),
            np.asarray(fn(*args, use_kernel=False, **kw)))


@pytest.mark.parametrize("nq,nd", BOUNDARY)
def test_hausdorff_routing_boundary(nq, nd):
    rng = np.random.default_rng(nq)
    q, dd, qv, dv = _mk(rng, nq, nd, 2, np.float32)
    default, kern, refp = _routes(ops.directed_hausdorff, q, dd, qv, dv)
    assert default.tobytes() in (kern.tobytes(), refp.tobytes())
    np.testing.assert_array_equal(kern, refp)


@pytest.mark.parametrize("nq,nd", BOUNDARY)
def test_nn_distance_routing_boundary(nq, nd):
    rng = np.random.default_rng(nq + 1)
    q, dd, qv, dv = _mk(rng, nq, nd, 2, np.float32)
    dd_, di = ops.nn_distance(q, dd, qv, dv)
    kd, ki = ops.nn_distance(q, dd, qv, dv, use_kernel=True)
    rd, ri = ops.nn_distance(q, dd, qv, dv, use_kernel=False)
    default, kern, refp = np.asarray(dd_), np.asarray(kd), np.asarray(rd)
    assert default.tobytes() in (kern.tobytes(), refp.tobytes())
    np.testing.assert_array_equal(kern, refp)
    # NN indices must be exactly equal on every route (argmin ties break
    # identically: both paths scan D in the same order)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(di), np.asarray(ki))


@pytest.mark.parametrize("n,m,bitwise", [(255, 256, True), (256, 256, True),
                                         (257, 256, False)])
def test_bound_matrices_routing_boundary(n, m, bitwise):
    """Single-tile shapes (<= one (256, 256) tile after padding) are
    bitwise across the route flip; the two-tile 257 crosses an XLA
    FMA-contraction boundary and agrees to ~ulp instead."""
    rng = np.random.default_rng(n + m)
    oq = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    od = jnp.asarray(rng.normal(size=(m, 2)).astype(np.float32))
    rq = jnp.asarray(rng.uniform(0, 2, n).astype(np.float32))
    rd = jnp.asarray(rng.uniform(0, 2, m).astype(np.float32))
    for part in (0, 1):
        default, kern, refp = _routes(
            lambda *a, **k: ops.bound_matrices(*a, **k)[part],
            oq, rq, od, rd)
        assert default.tobytes() in (kern.tobytes(), refp.tobytes())
        if bitwise:
            np.testing.assert_array_equal(kern, refp)
        else:
            np.testing.assert_allclose(kern, refp, rtol=1e-5, atol=1e-6)


LEVELS7 = ((0, 1), (1, 3), (3, 7))


def _mk_grid(rng, B, S, N=7, d=2):
    oq = rng.normal(size=(B, N, d)).astype(np.float32)
    od = rng.normal(size=(S, N, d)).astype(np.float32)
    rq = rng.uniform(0, 1, (B, N)).astype(np.float32)
    rd = rng.uniform(0, 1, (S, N)).astype(np.float32)
    qok = rng.random((B, N)) > 0.2
    dok = rng.random((S, N)) > 0.2
    qok[:, 0] = dok[:, 0] = True
    return tuple(map(jnp.asarray, (oq, rq, qok, od, rd, dok)))


@pytest.mark.parametrize("B,S,bitwise", [(1, 7, True), (3, 5, True),
                                         (4, 17, True), (1, 128, True),
                                         (8, 128, False), (8, 512, False)])
def test_bound_grid_routing_boundary(B, S, bitwise):
    """The fused batched bound kernel vs its fused jnp oracle across the
    engine's actual batch buckets — bitwise at the shapes where XLA's
    contraction choice coincides, ~ulp elsewhere — plus routing
    determinism of the default route."""
    rng = np.random.default_rng(B + S)
    args = _mk_grid(rng, B, S)
    for part in (0, 1):
        default, kern, refp = _routes(
            lambda *a, **k: ops.bound_grid(*a, levels=LEVELS7, **k)[part],
            *args)
        assert default.tobytes() in (kern.tobytes(), refp.tobytes())
        if bitwise:
            np.testing.assert_array_equal(kern, refp)
        else:
            np.testing.assert_allclose(kern, refp, rtol=5e-5, atol=1e-5)


def test_bound_grid_threshold_crossing(monkeypatch):
    """At the default (256, 256) threshold the route flips to the kernel;
    just below it stays on the fused oracle.  The default route must be
    bitwise equal to whichever forced route resolve() picked (routing
    determinism), and the two routes agree to ~ulp across the flip —
    a tuned table additionally gates any route change on BITWISE equality
    at the probe shape (engine/tune.py)."""
    from repro.kernels import autotune

    # this test pins DEFAULT routing semantics — neutralize the CI
    # forcing env vars (the rest of the suite runs under them unchanged)
    monkeypatch.delenv("REPRO_FORCE_KERNEL", raising=False)
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    assert not autotune.resolve("bound_grid", (255, 256)).use_kernel
    assert autotune.resolve("bound_grid", (256, 256)).use_kernel
    rng = np.random.default_rng(0)
    for B, expect_kernel in ((255, False), (256, True)):
        args = _mk_grid(rng, B, 256)
        default = ops.bound_grid(*args, levels=LEVELS7)
        forced = ops.bound_grid(*args, levels=LEVELS7,
                                use_kernel=expect_kernel)
        other = ops.bound_grid(*args, levels=LEVELS7,
                               use_kernel=not expect_kernel)
        for d, f, o in zip(default, forced, other):
            np.testing.assert_array_equal(np.asarray(d), np.asarray(f))
            np.testing.assert_allclose(np.asarray(f), np.asarray(o),
                                       rtol=5e-5, atol=1e-5)


def test_hausdorff_grid_kernel_path():
    """Kernel-sized shapes route the pair grid through the same Pallas
    streaming kernel as directed_hausdorff (vmapped over the grid), so
    the TPU hot path stays on the kernel; values match the per-pair op."""
    rng = np.random.default_rng(11)
    B, C, nq, nd = 2, 2, 256, 512
    q = jnp.asarray(rng.normal(size=(B, nq, 2)).astype(np.float32))
    ds = jnp.asarray(rng.normal(size=(B, C, nd, 2)).astype(np.float32))
    qv = jnp.asarray(rng.random((B, nq)) > 0.05)
    dv = jnp.asarray(rng.random((B, C, nd)) > 0.05)
    got = np.asarray(ops.directed_hausdorff_grid(q, ds, qv, dv))
    for b in range(B):
        for c in range(C):
            want = ops.directed_hausdorff(q[b], ds[b, c], qv[b], dv[b, c])
            np.testing.assert_allclose(got[b, c], np.asarray(want),
                                       rtol=1e-6)
