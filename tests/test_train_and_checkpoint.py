"""Training-substrate tests: optimizer (incl. int8 states), compression
error feedback, checkpoint/restore (crash-resume), pipeline resume,
watchdog + elastic remesh math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import ckpt as ckpt_lib
from repro.data import tokens as tok_lib
from repro.runtime import elastic, straggler
from repro.train import compression as comp
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def test_int8_quant_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 3)
    q = opt_lib._quantize(x, 256)
    y = opt_lib._dequantize(q)
    # blockwise absmax: error bounded by scale = blockmax/127
    err = np.abs(np.asarray(y - x))
    assert err.max() <= float(jnp.abs(x).max()) / 127 + 1e-6


def test_adamw_int8_tracks_fp32():
    cfg = opt_lib.OptConfig(lr=1e-2, warmup_steps=1)
    cfg8 = dataclasses.replace(cfg, state_dtype="int8", q_block=64)
    params = {"w": jnp.ones((64, 64)), "b": jnp.zeros((64,))}
    grads = {"w": jnp.full((64, 64), 0.1), "b": jnp.full((64,), 0.1)}
    s32 = opt_lib.init_state(params, cfg)
    s8 = opt_lib.init_state(params, cfg8)
    p32, p8 = params, params
    for _ in range(5):
        p32, s32, _ = opt_lib.apply_updates(p32, grads, s32, cfg)
        p8, s8, _ = opt_lib.apply_updates(p8, grads, s8, cfg8)
    np.testing.assert_allclose(p32["w"], p8["w"], atol=5e-3)


def test_compression_error_feedback_is_lossless_in_sum():
    """Error feedback: sum of dequantized grads over steps converges to the
    sum of true grads (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
    err = {"g": jnp.zeros((512,))}
    total = jnp.zeros((512,))
    for _ in range(20):
        g_hat, err = comp.compress_with_feedback({"g": g_true}, err)
        total = total + g_hat["g"]
    np.testing.assert_allclose(np.asarray(total) / 20, np.asarray(g_true),
                               atol=2e-2)
    assert float(jnp.abs(err["g"]).max()) < float(jnp.abs(g_true).max())


def test_loss_decreases_spadas_trajlm():
    cfg = configs.get_reduced("spadas_trajlm")
    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=5)
    key = jax.random.PRNGKey(0)
    state = ts.init_train_state(key, cfg, opt_cfg)
    step = jax.jit(ts.make_train_step(cfg, opt_cfg))
    docs = tok_lib.synthetic_corpus(64, cfg.vocab_size, seed=0)
    pipe = tok_lib.TokenPipeline(docs, 64, 4, seed=0)
    losses = []
    for _ in range(20):
        state, m = step(state, jax.tree.map(jnp.asarray, pipe.next_batch()))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_microbatched_grads_match_full():
    cfg = configs.get_reduced("llama3_8b")
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=1)
    key = jax.random.PRNGKey(0)
    s1 = ts.init_train_state(key, cfg, opt_cfg)
    s2 = ts.init_train_state(key, cfg, opt_cfg)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
    }
    full = jax.jit(ts.make_train_step(cfg, opt_cfg))
    micro = jax.jit(ts.make_train_step(cfg, opt_cfg, microbatch=2))
    s1, m1 = full(s1, batch)
    s2, m2 = micro(s2, batch)
    # equivalence up to fp accumulation order (amplified by Adam's rsqrt)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    w1 = jax.tree.leaves(s1.params)[0]
    w2 = jax.tree.leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-3)


def test_checkpoint_roundtrip_and_crash_resume(tmp_path):
    cfg = configs.get_reduced("spadas_trajlm")
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=2,
                                state_dtype="int8", q_block=64)
    key = jax.random.PRNGKey(0)
    state = ts.init_train_state(key, cfg, opt_cfg)
    step = jax.jit(ts.make_train_step(cfg, opt_cfg))
    docs = tok_lib.synthetic_corpus(32, cfg.vocab_size, seed=0)
    pipe = tok_lib.TokenPipeline(docs, 32, 2, seed=0)

    for _ in range(3):
        state, _ = step(state, jax.tree.map(jnp.asarray, pipe.next_batch()))
    ckpt_lib.save(tmp_path, 3, state,
                  extra={"step": 3, "pipeline": pipe.state.as_dict()})
    # continue the "original" run two more steps
    ref_state = state
    ref_losses = []
    ref_pipe_state = tok_lib.PipelineState.from_dict(pipe.state.as_dict())
    for _ in range(2):
        ref_state, m = step(ref_state,
                            jax.tree.map(jnp.asarray, pipe.next_batch()))
        ref_losses.append(float(m["loss"]))

    # "crash": restore from disk into a fresh state, resume pipeline
    fresh = ts.init_train_state(jax.random.PRNGKey(42), cfg, opt_cfg)
    restored, extra = ckpt_lib.restore(tmp_path, fresh)
    assert extra["step"] == 3
    pipe2 = tok_lib.TokenPipeline(
        docs, 32, 2, seed=0,
        state=tok_lib.PipelineState.from_dict(extra["pipeline"]))
    got_losses = []
    for _ in range(2):
        restored, m = step(restored,
                           jax.tree.map(jnp.asarray, pipe2.next_batch()))
        got_losses.append(float(m["loss"]))
    np.testing.assert_allclose(got_losses, ref_losses, rtol=1e-5)


def test_async_saver_and_latest_step(tmp_path):
    state = {"w": jnp.arange(10.0)}
    saver = ckpt_lib.AsyncSaver()
    saver.save(tmp_path, 1, state, extra={"step": 1})
    saver.save(tmp_path, 2, state, extra={"step": 2})
    saver.wait()
    assert ckpt_lib.latest_step(tmp_path) == 2


def test_pipeline_determinism_and_shardability():
    docs = tok_lib.synthetic_corpus(64, 512, seed=3)
    p1 = tok_lib.TokenPipeline(docs, 32, 4, seed=1)
    p2 = tok_lib.TokenPipeline(docs, 32, 4, seed=1)
    for _ in range(5):
        b1, b2 = p1.next_batch(), p2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_watchdog_trips_on_straggler():
    wd = straggler.StepWatchdog(straggler.WatchdogConfig(
        warmup=0, tolerance=2.0, consecutive=1, min_deadline_s=0.0))
    import time
    for _ in range(5):
        wd.start(); time.sleep(0.002); wd.stop()
    wd.start(); time.sleep(0.05)
    with pytest.raises(straggler.StragglerEvent):
        wd.stop()


def test_remesh_plan_preserves_model_axis():
    plan = elastic.plan_remesh({"pod": 2, "data": 16, "model": 16},
                               failed=16)
    assert plan.new_shape["model"] == 16
    assert plan.new_shape["pod"] * plan.new_shape["data"] <= 31
    assert plan.per_device_batch_factor > 1.0
    # catastrophic loss still leaves a valid single-replica mesh
    plan2 = elastic.plan_remesh({"pod": 2, "data": 16, "model": 16},
                                failed=496)
    assert plan2.new_shape["model"] == 16
    assert plan2.new_shape["data"] == 1
