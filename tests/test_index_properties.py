"""Property-based tests (hypothesis) on the unified index invariants and
the paper's bound math (Eq. 4, Lemma 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import geometry, index as il, outliers, search, zorder
from repro.core.build import build_query_index

SET = dict(max_examples=20, deadline=None)


def pointset(draw, min_n=8, max_n=200, d=2):
    n = draw(st.integers(min_n, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    scale = draw(st.floats(0.1, 50.0))
    return (rng.normal(size=(n, d)) * scale).astype(np.float32)


points_strategy = st.composite(pointset)


@given(points_strategy())
@settings(**SET)
def test_ball_and_box_invariants(pts):
    p, v, depth = il.pad_points(jnp.asarray(pts), 8)
    idx = il.build_index(p, v, depth)
    pts_t = np.asarray(idx.points)
    val_t = np.asarray(idx.valid)
    for lvl in range(depth + 1):
        seg = p.shape[0] >> lvl
        pp = pts_t.reshape(1 << lvl, seg, -1)
        vv = val_t.reshape(1 << lvl, seg)
        sl = idx.level_slice(lvl)
        c = np.asarray(idx.centers[sl])
        r = np.asarray(idx.radii[sl])
        lo = np.asarray(idx.box_lo[sl])
        hi = np.asarray(idx.box_hi[sl])
        dist = np.linalg.norm(pp - c[:, None], axis=-1)
        assert not ((dist > r[:, None] + 1e-3) & vv).any()
        assert not (((pp < lo[:, None] - 1e-4) | (pp > hi[:, None] + 1e-4))
                    & vv[..., None]).any()


@given(points_strategy())
@settings(**SET)
def test_half_ball_property_of_mean_centers(pts):
    """Eq. 4's lower bound needs >=1 point in any half-ball; mean-centered
    nodes satisfy it (DESIGN.md sec. 2).  Check random directions."""
    c = pts.mean(axis=0)
    rng = np.random.default_rng(0)
    for _ in range(8):
        u = rng.normal(size=pts.shape[1])
        proj = (pts - c) @ u
        assert (proj <= 1e-4).any() and (proj >= -1e-4).any()


@given(points_strategy(), points_strategy())
@settings(**SET)
def test_eq4_bounds_sound(q, d):
    """LB <= H(Q->D) <= UB for mean-centered bounding balls."""
    oq, rq = q.mean(0), np.linalg.norm(q - q.mean(0), axis=1).max()
    od, rd = d.mean(0), np.linalg.norm(d - d.mean(0), axis=1).max()
    cd = float(np.linalg.norm(oq - od))
    lb = max(cd - rd, 0.0)
    ub = float(np.sqrt(cd**2 + rd**2) + rq)
    dd = np.sqrt(((q[:, None] - d[None]) ** 2).sum(-1))
    h = dd.min(axis=1).max()
    assert lb <= h + 1e-4
    assert h <= ub + 1e-4


@given(points_strategy(), points_strategy(), st.floats(0.05, 5.0))
@settings(**SET)
def test_lemma1_approx_error_bound(q, d, eps):
    """|ApproHaus - ExactHaus| <= 2*eps (Lemma 1)."""
    q_idx, _ = build_query_index(q, leaf_capacity=4)
    d_idx, _ = build_query_index(d, leaf_capacity=4)
    # guarantee holds when the stopping level's radii < eps; approx_level
    # returns the leaf level otherwise -> use effective eps
    lq = search.approx_level(q_idx, eps)
    ld = search.approx_level(d_idx, eps)
    r_eff = max(
        float(np.asarray(il.leaf_radii(q_idx)).max()),
        float(np.asarray(il.leaf_radii(d_idx)).max()),
        eps,
    )
    approx = float(search.hausdorff_pair_approx(q_idx, d_idx, eps))
    dd = np.sqrt(((q[:, None] - d[None]) ** 2).sum(-1))
    exact = dd.min(axis=1).max()
    assert abs(approx - exact) <= 2 * r_eff + 1e-3


@given(points_strategy())
@settings(**SET)
def test_outlier_removal_only_removes_far_points(pts):
    p, v, depth = il.pad_points(jnp.asarray(pts), 8)
    idx = il.build_index(p, v, depth)
    refined, r_prime = outliers.remove_outliers(idx)
    # refinement never removes the majority and never adds validity
    assert int(refined.valid.sum()) <= int(idx.valid.sum())
    assert int(refined.valid.sum()) >= int(0.5 * int(idx.valid.sum()))
    # stats re-tightened: every surviving point inside the recomputed ball
    # (radii can move slightly since centers are means of the survivors)
    pts_t = np.asarray(refined.points)
    val_t = np.asarray(refined.valid)
    seg = pts_t.shape[0]
    c = np.asarray(refined.centers[0])
    r = float(refined.radii[0])
    dist = np.linalg.norm(pts_t - c[None], axis=-1)
    assert not ((dist > r + 1e-3) & val_t).any()


@given(st.integers(0, 2**31 - 1), st.integers(2, 7))
@settings(**SET)
def test_zorder_bijective_and_sorted(seed, theta):
    rng = np.random.default_rng(seed)
    ix = rng.integers(0, 1 << theta, 128).astype(np.uint32)
    iy = rng.integers(0, 1 << theta, 128).astype(np.uint32)
    codes = np.asarray(zorder.morton2(jnp.asarray(ix), jnp.asarray(iy)))
    assert codes.max() < zorder.num_cells(theta)
    # decode by de-interleave and compare
    def deinterleave(c):
        x = c & 0x55555555
        x = (x | (x >> 1)) & 0x33333333
        x = (x | (x >> 2)) & 0x0F0F0F0F
        x = (x | (x >> 4)) & 0x00FF00FF
        x = (x | (x >> 8)) & 0x0000FFFF
        return x
    assert (deinterleave(codes) == ix).all()
    assert (deinterleave(codes >> 1) == iy).all()


@given(points_strategy(), points_strategy(), st.integers(3, 6))
@settings(**SET)
def test_signature_algebra(a, b, theta):
    lo = jnp.asarray(np.minimum(a.min(0), b.min(0))[:2])
    hi = jnp.asarray(np.maximum(a.max(0), b.max(0))[:2])
    va = jnp.ones(len(a), bool)
    vb = jnp.ones(len(b), bool)
    sa = zorder.signature(jnp.asarray(a), va, lo, hi, theta)
    sb = zorder.signature(jnp.asarray(b), vb, lo, hi, theta)
    ca = set(np.asarray(zorder.cell_ids(jnp.asarray(a), lo, hi,
                                        theta)).tolist())
    cb = set(np.asarray(zorder.cell_ids(jnp.asarray(b), lo, hi,
                                        theta)).tolist())
    assert int(zorder.sig_count(sa)) == len(ca)
    assert int(zorder.sig_intersect_count(sa, sb)) == len(ca & cb)
    assert int(zorder.sig_count(zorder.sig_union(sa, sb))) == len(ca | cb)
