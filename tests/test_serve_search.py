"""Serving front-end (continuous micro-batching) behaviors.

Direct coverage for `repro.launch.serve_search.SearchServer`: mixed op
types in one queue drain, request -> response id mapping under grouping,
the queue-timeout flush (partial batches must not stall), and stop()
failing still-queued requests instead of hanging their futures.
"""
import numpy as np
import pytest

from conftest import make_clustered_datasets
from repro.core import zorder
from repro.core.build import build_repository
from repro.engine import QueryEngine
from repro.launch.serve_search import OPS, Request, SearchServer, make_traffic

THETA = 5
K = 4


@pytest.fixture(scope="module")
def env():
    datasets = make_clustered_datasets(17, seed=4, n_points=(20, 60))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    return datasets, repo


def test_mixed_ops_one_drain(env):
    """A burst covering all seven op types PLUS pipeline requests is
    answered correctly and grouped: one dispatch group per compatible
    (op, statics) set — the whole mixed drain is ONE engine.search call,
    not one call per request."""
    import jax

    datasets, repo = env
    engine = QueryEngine(repo)
    server = SearchServer(engine, max_batch=64, max_wait_ms=250.0).start()
    try:
        traffic = make_traffic(repo, datasets, 27, seed=3)  # 3 of each kind
        assert {op for op, _ in traffic} == set(OPS)
        futures = [server.submit(op, **p) for op, p in traffic]
        results = [f.result(timeout=600) for f in futures]
        assert len(results) == 27
        assert server.stats.requests == 27
        # grouping: far fewer dispatch groups than requests (11 groups if
        # the whole burst landed in one drain — 9 stage-1 op/static groups
        # + 2 pipeline stage-2 groups; allow a few straggler drains)
        assert server.stats.batches <= 22
        assert server.stats.mean_batch > 1.0
        assert engine.stats.pipeline_stage1 == engine.stats.pipeline_stage2 \
            == 6
        # spot-check each op type against a direct engine call
        for (op, payload), res in zip(traffic, results):
            if op == "range_search":
                want = engine.range_search(payload["r_lo"][None],
                                           payload["r_hi"][None])[0]
                np.testing.assert_array_equal(np.asarray(res),
                                              np.asarray(want))
            elif op == "topk_gbo":
                vals, ids = engine.topk_gbo(payload["q_sig"][None],
                                            payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals[0]))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids[0]))
            elif op == "topk_hausdorff":
                # ExactHaus responses carry (vals, ids, SearchStats) — the
                # engine no longer discards the stats; top-k values/ids
                # are padding-invariant, so a solo rebuild must agree
                q_batch = engine.build_queries([payload["q"]])
                qi = jax.tree.map(lambda x: x[0], q_batch)
                vals, ids, stats = engine.topk_hausdorff(qi, payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids))
                assert res[2].exact_evaluations > 0
                assert 0.0 <= res[2].pruned_fraction <= 1.0
            elif op == "pipeline":
                # pipeline responses are the full SearchResult: stage-2
                # rows over the k winners + the stage-1 result, equal to
                # the two-call host baseline
                stage1 = res.extras["stage1"]
                ds = payload["dataset"]
                if ds["op"] == "topk_ia":
                    want_v, want_i = engine.topk_ia(
                        ds["r_lo"][None], ds["r_hi"][None], ds["k"])
                    np.testing.assert_array_equal(
                        np.asarray(stage1.vals), np.asarray(want_v[0]))
                    np.testing.assert_array_equal(
                        np.asarray(stage1.ids), np.asarray(want_i[0]))
                    ids = np.asarray(stage1.ids)
                    valid = ids >= 0
                    pt = payload["point"]
                    k = ds["k"]
                    want = engine.range_points(
                        np.where(valid, ids, 0),
                        np.broadcast_to(pt["r_lo"], (k, 2)),
                        np.broadcast_to(pt["r_hi"], (k, 2)))
                    got = np.asarray(res.mask)
                    np.testing.assert_array_equal(
                        got[valid], np.asarray(want)[valid])
                    assert not got[~valid].any()
    finally:
        server.stop()


def test_request_response_id_mapping(env):
    """Each future must receive ITS query's rows even though requests are
    grouped and answered as one batch — distinct queries, per-request
    verification against single-query engine calls."""
    datasets, repo = env
    engine = QueryEngine(repo)
    server = SearchServer(QueryEngine(repo), max_batch=16,
                          max_wait_ms=100.0).start()
    try:
        rng = np.random.default_rng(7)
        lo = rng.uniform(-60, 40, (9, 2)).astype(np.float32)
        hi = lo + rng.uniform(5, 40, (9, 2)).astype(np.float32)
        futures = [server.submit("topk_ia", q_lo=lo[i], q_hi=hi[i], k=K)
                   for i in range(9)]
        got = [f.result(timeout=600) for f in futures]
        for i, (v, j) in enumerate(got):
            want_v, want_j = engine.topk_ia(lo[i][None], hi[i][None], K)
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(want_v[0]))
            np.testing.assert_array_equal(np.asarray(j),
                                          np.asarray(want_j[0]))
    finally:
        server.stop()


def test_queue_timeout_flush(env):
    """A partial batch (far below max_batch) must flush after max_wait and
    resolve its futures — the server never waits for a full batch."""
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=1024,
                          max_wait_ms=5.0).start()
    try:
        rng = np.random.default_rng(11)
        lo = rng.uniform(-60, 40, (3, 2)).astype(np.float32)
        hi = lo + 5.0
        futures = [server.submit("range_search", r_lo=lo[i], r_hi=hi[i])
                   for i in range(3)]
        for f in futures:
            f.result(timeout=120)        # completing at all proves the flush
        assert server.stats.requests == 3
        assert server.stats.batches >= 1
    finally:
        server.stop()


def test_adaptive_drain_and_latency_stats(env):
    """The adaptive (queue-depth-driven) policy must answer every request
    correctly, book per-op latency EWMAs on both the server (request
    latency) and the engine (dispatch latency — what sizes the straggler
    window), and expose latency percentiles."""
    datasets, repo = env
    engine = QueryEngine(repo)
    server = SearchServer(engine, max_batch=16, max_wait_ms=100.0,
                          adaptive=True).start()
    try:
        rng = np.random.default_rng(23)
        lo = rng.uniform(-60, 40, (6, 2)).astype(np.float32)
        hi = lo + 8.0
        futures = [server.submit("range_search", r_lo=lo[i], r_hi=hi[i])
                   for i in range(6)]
        got = [f.result(timeout=600) for f in futures]
        direct = QueryEngine(repo)
        for i, res in enumerate(got):
            want = direct.range_search(lo[i][None], hi[i][None])[0]
            np.testing.assert_array_equal(np.asarray(res),
                                          np.asarray(want))
        assert server.stats.requests == 6
        assert server.stats.op_ewma["range_search"] > 0.0
        assert server.stats.p99_ms >= server.stats.p50_ms >= 0.0
        assert engine.stats.latency_ewma["range_search"] > 0.0
        # a lone straggler after the EWMAs exist exercises the sized
        # window path and still resolves promptly
        lone = server.submit("range_search", r_lo=lo[0], r_hi=hi[0])
        np.testing.assert_array_equal(
            np.asarray(lone.result(timeout=600)), np.asarray(got[0]))
    finally:
        server.stop()


def test_depth_scaled_drain_bound(env):
    """Under deep backlog (queue deeper than max_batch) the adaptive
    drain grows to OVERFILL x max_batch so dispatch overhead amortises
    over more requests; the static policy keeps the fixed bound.  Calls
    _drain directly on an unstarted, pre-filled server — no dispatcher
    thread, fully deterministic."""
    datasets, repo = env
    engine = QueryEngine(repo)
    from repro.launch.serve_search import Request

    def prefill(adaptive, n):
        server = SearchServer(engine, max_batch=8, max_wait_ms=2.0,
                              adaptive=adaptive)
        for _ in range(n):
            server._queue.put(Request("range_search", None))
        return server

    deep = prefill(True, 3 * 8)
    assert len(deep._drain()) == 3 * 8      # whole backlog, one drain
    assert SearchServer.OVERFILL * 8 >= 3 * 8
    over = prefill(True, 5 * 8)             # backlog beyond OVERFILL
    assert len(over._drain()) == SearchServer.OVERFILL * 8
    shallow = prefill(True, 4)              # no overfill below max_batch
    assert len(shallow._drain()) == 4
    static = prefill(False, 3 * 8)
    assert len(static._drain()) == 8        # seed policy: fixed bound


def test_submit_unknown_op_and_stopped_server(env):
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=8)
    with pytest.raises(RuntimeError):
        server.submit("range_search", r_lo=np.zeros(2), r_hi=np.ones(2))
    server.start()
    with pytest.raises(ValueError):
        server.submit("not_an_op")
    server.stop()


def test_poisoned_request_isolated(env):
    """A malformed request sharing a drain with healthy ones must fail
    ONLY its own future: the server falls back to per-request execution
    when the mixed engine call raises."""
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=16,
                          max_wait_ms=200.0).start()
    try:
        rng = np.random.default_rng(13)
        lo = rng.uniform(-60, 40, (2, 2)).astype(np.float32)
        hi = lo + 5.0
        good1 = server.submit("topk_ia", q_lo=lo[0], q_hi=hi[0], k=K)
        # same (op, k) group, wrong box rank: poisons the group stack
        bad = server.submit("topk_ia", q_lo=np.zeros(3, np.float32),
                            q_hi=np.ones(3, np.float32), k=K)
        good2 = server.submit("range_search", r_lo=lo[1], r_hi=hi[1])
        v, j = good1.result(timeout=600)
        assert np.asarray(v).shape == (K,)
        assert np.asarray(good2.result(timeout=600)).shape[0] > 0
        with pytest.raises(Exception):
            bad.result(timeout=600)
        # the dispatcher thread survived the poisoned drain: a fresh
        # request after the failure still resolves
        after = server.submit("topk_ia", q_lo=lo[1], q_hi=hi[1], k=K)
        v2, _ = after.result(timeout=600)
        assert np.asarray(v2).shape == (K,)
    finally:
        server.stop()


def test_stop_fails_queued_requests(env):
    """Requests still queued when the server stops get an exception, not a
    forever-pending future."""
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=8).start()
    server.stop()                        # dispatcher fully exited
    req = Request("range_search", dict(r_lo=np.zeros(2), r_hi=np.ones(2)))
    server._queue.put(req)               # lands after the dispatcher died
    server.stop()                        # second stop drains + fails it
    assert req.future.done()
    with pytest.raises(RuntimeError):
        req.future.result(timeout=0)


def check_replicated_serving():
    """SearchServer over a ReplicatedQueryEngine (2 x 4 mesh): a mixed
    burst pre-filled BEFORE the dispatcher starts drains as ONE batch ->
    one engine.search call, every future gets the same legacy response
    shapes as the single-device server, answers are bit-identical to
    direct local-engine calls, and a poisoned request sharing a drain
    fails only its own future (per-request fallback works on the replica
    dispatch path too)."""
    import jax

    from repro.engine import ReplicatedQueryEngine

    datasets = make_clustered_datasets(17, seed=4, n_points=(20, 60))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    local = QueryEngine(repo)
    engine = ReplicatedQueryEngine(repo, n_replicas=2, n_data=4)
    server = SearchServer(engine, max_batch=64, max_wait_ms=250.0)
    traffic = make_traffic(repo, datasets, 27, seed=3)   # 3 of each kind
    assert {op for op, _ in traffic} == set(OPS)
    # pre-fill the queue so the whole burst is visible to the FIRST drain
    from repro.launch.serve_search import _to_query
    reqs = [Request(op, _to_query(op, p)) for op, p in traffic]
    for r in reqs:
        server._queue.put(r)
    server.start()
    try:
        results = [r.future.result(timeout=600) for r in reqs]
        # one drain, one search(): exactly the single-drain group count (9
        # stage-1 op/static groups + 2 pipeline stage-2 groups) — a split
        # drain would re-plan its groups and book more
        assert server.stats.batches == 11
        assert server.stats.batch_size_sum == 27
        s = engine.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
        assert s.plan_groups <= s.replica_subgroups <= s.plan_groups * 2
        # legacy response shapes + bit-identity vs the local engine
        for (op, payload), res in zip(traffic, results):
            if op == "range_search":
                want = local.range_search(payload["r_lo"][None],
                                          payload["r_hi"][None])[0]
                np.testing.assert_array_equal(np.asarray(res),
                                              np.asarray(want))
            elif op == "topk_ia":
                vals, ids = local.topk_ia(payload["q_lo"][None],
                                          payload["q_hi"][None],
                                          payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals[0]))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids[0]))
            elif op == "topk_hausdorff":
                q_batch = local.build_queries([payload["q"]])
                qi = jax.tree.map(lambda x: x[0], q_batch)
                vals, ids, _ = local.topk_hausdorff(qi, payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids))
                assert res[2].exact_evaluations > 0
            elif op == "pipeline":
                assert res.op == "pipeline"
                assert res.extras["stage1"] is not None
        # poisoned request isolated on the replica path: wrong box rank
        # poisons its group; the server falls back per-request and only
        # the bad future fails
        rng = np.random.default_rng(13)
        lo = rng.uniform(-60, 40, (2, 2)).astype(np.float32)
        hi = lo + 5.0
        good = server.submit("topk_ia", q_lo=lo[0], q_hi=hi[0], k=K)
        bad = server.submit("topk_ia", q_lo=np.zeros(3, np.float32),
                            q_hi=np.ones(3, np.float32), k=K)
        v, j = good.result(timeout=600)
        assert np.asarray(v).shape == (K,)
        import pytest as _pytest
        with _pytest.raises(Exception):
            bad.result(timeout=600)
        after = server.submit("range_search", r_lo=lo[1], r_hi=hi[1])
        assert np.asarray(after.result(timeout=600)).ndim == 1
    finally:
        server.stop()
    print("REPLICATED_SERVING_OK")


def test_replicated_serving():
    from conftest import dispatch_device_check
    dispatch_device_check("test_serve_search", "check_replicated_serving")
