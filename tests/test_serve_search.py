"""Serving front-end (continuous micro-batching) behaviors.

Direct coverage for `repro.launch.serve_search.SearchServer`: mixed op
types in one queue drain, request -> response id mapping under grouping,
the queue-timeout flush (partial batches must not stall), and stop()
failing still-queued requests instead of hanging their futures.
"""
import numpy as np
import pytest

from conftest import make_clustered_datasets
from repro.core import zorder
from repro.core.build import build_repository
from repro.engine import Pipeline, Query, QueryEngine
from repro.launch.serve_search import OPS, Request, SearchServer, make_traffic

THETA = 5
K = 4


@pytest.fixture(scope="module")
def env():
    datasets = make_clustered_datasets(17, seed=4, n_points=(20, 60))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    return datasets, repo


def test_mixed_ops_one_drain(env):
    """A burst covering all seven op types PLUS pipeline requests is
    answered correctly and grouped: one dispatch group per compatible
    (op, statics) set — the whole mixed drain is ONE engine.search call,
    not one call per request."""
    import jax

    datasets, repo = env
    engine = QueryEngine(repo)
    server = SearchServer(engine, max_batch=64, max_wait_ms=250.0).start()
    try:
        traffic = make_traffic(repo, datasets, 27, seed=3)  # >= 2 of each kind
        assert {op for op, _ in traffic} == set(OPS)
        futures = [server.submit(op, **p) for op, p in traffic]
        results = [f.result(timeout=600) for f in futures]
        assert len(results) == 27
        assert server.stats.requests == 27
        # grouping: far fewer dispatch groups than requests (14 groups if
        # the whole burst landed in one drain — 11 stage-1 op/static groups
        # + 3 pipeline stage-2 groups; allow a few straggler drains)
        assert server.stats.batches <= 27
        assert server.stats.mean_batch > 1.0
        assert engine.stats.pipeline_stage1 == engine.stats.pipeline_stage2 \
            == 6
        # spot-check each op type against a direct engine call
        for (op, payload), res in zip(traffic, results):
            if op == "range_search":
                want = engine.range_search(payload["r_lo"][None],
                                           payload["r_hi"][None])[0]
                np.testing.assert_array_equal(np.asarray(res),
                                              np.asarray(want))
            elif op == "topk_gbo":
                vals, ids = engine.topk_gbo(payload["q_sig"][None],
                                            payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals[0]))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids[0]))
            elif op == "topk_hausdorff":
                # ExactHaus responses carry (vals, ids, SearchStats) — the
                # engine no longer discards the stats; top-k values/ids
                # are padding-invariant, so a solo rebuild must agree
                q_batch = engine.build_queries([payload["q"]])
                qi = jax.tree.map(lambda x: x[0], q_batch)
                vals, ids, stats = engine.topk_hausdorff(qi, payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids))
                assert res[2].exact_evaluations > 0
                assert 0.0 <= res[2].pruned_fraction <= 1.0
            elif op == "pipeline":
                # pipeline responses are the full SearchResult: stage-2
                # rows over the k winners + the stage-1 result, equal to
                # the two-call host baseline
                stage1 = res.extras["stage1"]
                ds = payload["dataset"]
                pt = payload["point"]
                if ds["op"] == "topk_ia":
                    want_v, want_i = engine.topk_ia(
                        ds["r_lo"][None], ds["r_hi"][None], ds["k"])
                    np.testing.assert_array_equal(
                        np.asarray(stage1.vals), np.asarray(want_v[0]))
                    np.testing.assert_array_equal(
                        np.asarray(stage1.ids), np.asarray(want_i[0]))
                if ds["op"] == "topk_ia" and pt["op"] == "range_points":
                    ids = np.asarray(stage1.ids)
                    valid = ids >= 0
                    k = ds["k"]
                    want = engine.range_points(
                        np.where(valid, ids, 0),
                        np.broadcast_to(pt["r_lo"], (k, 2)),
                        np.broadcast_to(pt["r_hi"], (k, 2)))
                    got = np.asarray(res.mask)
                    np.testing.assert_array_equal(
                        got[valid], np.asarray(want)[valid])
                    assert not got[~valid].any()
                elif pt["op"] in ("topk_overlap", "topk_coverage"):
                    # dataset→dataset rerank kind: equal to the same
                    # Pipeline answered by a direct engine call
                    want = engine.search([Pipeline(
                        Query(**ds), Query(**pt))])[0]
                    np.testing.assert_array_equal(
                        np.asarray(res.vals), np.asarray(want.vals))
                    np.testing.assert_array_equal(
                        np.asarray(res.ids), np.asarray(want.ids))
    finally:
        server.stop()


def test_request_response_id_mapping(env):
    """Each future must receive ITS query's rows even though requests are
    grouped and answered as one batch — distinct queries, per-request
    verification against single-query engine calls."""
    datasets, repo = env
    engine = QueryEngine(repo)
    server = SearchServer(QueryEngine(repo), max_batch=16,
                          max_wait_ms=100.0).start()
    try:
        rng = np.random.default_rng(7)
        lo = rng.uniform(-60, 40, (9, 2)).astype(np.float32)
        hi = lo + rng.uniform(5, 40, (9, 2)).astype(np.float32)
        futures = [server.submit("topk_ia", q_lo=lo[i], q_hi=hi[i], k=K)
                   for i in range(9)]
        got = [f.result(timeout=600) for f in futures]
        for i, (v, j) in enumerate(got):
            want_v, want_j = engine.topk_ia(lo[i][None], hi[i][None], K)
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(want_v[0]))
            np.testing.assert_array_equal(np.asarray(j),
                                          np.asarray(want_j[0]))
    finally:
        server.stop()


def test_queue_timeout_flush(env):
    """A partial batch (far below max_batch) must flush after max_wait and
    resolve its futures — the server never waits for a full batch."""
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=1024,
                          max_wait_ms=5.0).start()
    try:
        rng = np.random.default_rng(11)
        lo = rng.uniform(-60, 40, (3, 2)).astype(np.float32)
        hi = lo + 5.0
        futures = [server.submit("range_search", r_lo=lo[i], r_hi=hi[i])
                   for i in range(3)]
        for f in futures:
            f.result(timeout=120)        # completing at all proves the flush
        assert server.stats.requests == 3
        assert server.stats.batches >= 1
    finally:
        server.stop()


def test_adaptive_drain_and_latency_stats(env):
    """The adaptive (queue-depth-driven) policy must answer every request
    correctly, book per-op latency EWMAs on both the server (request
    latency) and the engine (dispatch latency — what sizes the straggler
    window), and expose latency percentiles."""
    datasets, repo = env
    engine = QueryEngine(repo)
    server = SearchServer(engine, max_batch=16, max_wait_ms=100.0,
                          adaptive=True).start()
    try:
        rng = np.random.default_rng(23)
        lo = rng.uniform(-60, 40, (6, 2)).astype(np.float32)
        hi = lo + 8.0
        futures = [server.submit("range_search", r_lo=lo[i], r_hi=hi[i])
                   for i in range(6)]
        got = [f.result(timeout=600) for f in futures]
        direct = QueryEngine(repo)
        for i, res in enumerate(got):
            want = direct.range_search(lo[i][None], hi[i][None])[0]
            np.testing.assert_array_equal(np.asarray(res),
                                          np.asarray(want))
        assert server.stats.requests == 6
        assert server.stats.op_ewma["range_search"] > 0.0
        assert server.stats.p99_ms >= server.stats.p50_ms >= 0.0
        assert engine.stats.latency_ewma["range_search"] > 0.0
        # a lone straggler after the EWMAs exist exercises the sized
        # window path and still resolves promptly
        lone = server.submit("range_search", r_lo=lo[0], r_hi=hi[0])
        np.testing.assert_array_equal(
            np.asarray(lone.result(timeout=600)), np.asarray(got[0]))
    finally:
        server.stop()


def test_depth_scaled_drain_bound(env):
    """Under deep backlog (queue deeper than max_batch) the adaptive
    drain grows to OVERFILL x max_batch so dispatch overhead amortises
    over more requests; the static policy keeps the fixed bound.  Calls
    _drain directly on an unstarted, pre-filled server — no dispatcher
    thread, fully deterministic."""
    datasets, repo = env
    engine = QueryEngine(repo)
    from repro.launch.serve_search import Request

    def prefill(adaptive, n):
        server = SearchServer(engine, max_batch=8, max_wait_ms=2.0,
                              adaptive=adaptive)
        for _ in range(n):
            server._queue.put(Request("range_search", None))
        return server

    deep = prefill(True, 3 * 8)
    assert len(deep._drain()) == 3 * 8      # whole backlog, one drain
    assert SearchServer.OVERFILL * 8 >= 3 * 8
    over = prefill(True, 5 * 8)             # backlog beyond OVERFILL
    assert len(over._drain()) == SearchServer.OVERFILL * 8
    shallow = prefill(True, 4)              # no overfill below max_batch
    assert len(shallow._drain()) == 4
    static = prefill(False, 3 * 8)
    assert len(static._drain()) == 8        # seed policy: fixed bound


def test_submit_unknown_op_and_stopped_server(env):
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=8)
    with pytest.raises(RuntimeError):
        server.submit("range_search", r_lo=np.zeros(2), r_hi=np.ones(2))
    server.start()
    with pytest.raises(ValueError):
        server.submit("not_an_op")
    server.stop()


def test_poisoned_request_isolated(env):
    """A malformed request sharing a drain with healthy ones must fail
    ONLY its own future: the server falls back to per-request execution
    when the mixed engine call raises."""
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=16,
                          max_wait_ms=200.0).start()
    try:
        rng = np.random.default_rng(13)
        lo = rng.uniform(-60, 40, (2, 2)).astype(np.float32)
        hi = lo + 5.0
        good1 = server.submit("topk_ia", q_lo=lo[0], q_hi=hi[0], k=K)
        # same (op, k) group, wrong box rank: poisons the group stack
        bad = server.submit("topk_ia", q_lo=np.zeros(3, np.float32),
                            q_hi=np.ones(3, np.float32), k=K)
        good2 = server.submit("range_search", r_lo=lo[1], r_hi=hi[1])
        v, j = good1.result(timeout=600)
        assert np.asarray(v).shape == (K,)
        assert np.asarray(good2.result(timeout=600)).shape[0] > 0
        with pytest.raises(Exception):
            bad.result(timeout=600)
        # the dispatcher thread survived the poisoned drain: a fresh
        # request after the failure still resolves
        after = server.submit("topk_ia", q_lo=lo[1], q_hi=hi[1], k=K)
        v2, _ = after.result(timeout=600)
        assert np.asarray(v2).shape == (K,)
    finally:
        server.stop()


def test_stop_fails_queued_requests(env):
    """Requests still queued when the server stops get an exception, not a
    forever-pending future."""
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=8).start()
    server.stop()                        # dispatcher fully exited
    req = Request("range_search", dict(r_lo=np.zeros(2), r_hi=np.ones(2)))
    server._queue.put(req)               # lands after the dispatcher died
    server.stop()                        # second stop drains + fails it
    assert req.future.done()
    with pytest.raises(RuntimeError):
        req.future.result(timeout=0)


# -- live serving: the mutation lane ----------------------------------------


def _segment_queries(ds_id, probe_lo, probe_hi):
    """Three fixed queries reused verbatim across segments, so a stale
    cached row from an earlier epoch would be SERVED (not just possible)
    if epoch keying were broken: dataset discovery, top-k, and a point
    probe into ``ds_id`` (box tight around the ORIGINAL content, so a
    replace that moves the points visibly changes the mask)."""
    lo = np.float32([20, 20])
    return [
        ("range_search", dict(r_lo=lo, r_hi=lo + 40.0)),
        ("topk_ia", dict(q_lo=np.float32([-60, -60]),
                         q_hi=np.float32([60, 60]), k=3)),
        ("range_points", dict(ds_id=ds_id, r_lo=probe_lo, r_hi=probe_hi)),
    ]


def _res_np(res):
    return [np.asarray(x) for x in (res if isinstance(res, tuple) else (res,))]


def _assert_same(got, want_engine, traffic):
    """Each legacy response equals the same legacy call on a cold engine."""
    for (op, payload), res in zip(traffic, got):
        if op == "range_search":
            want = want_engine.range_search(payload["r_lo"][None],
                                            payload["r_hi"][None])[0]
        elif op == "topk_ia":
            want = want_engine.topk_ia(payload["q_lo"][None],
                                       payload["q_hi"][None], payload["k"])
            want = (want[0][0], want[1][0])
        else:                                       # range_points
            want = want_engine.range_points(
                np.int32([payload["ds_id"]]), payload["r_lo"][None],
                payload["r_hi"][None])[0]
        for x, y in zip(_res_np(res), _res_np(want)):
            np.testing.assert_array_equal(x, np.asarray(y))


def test_live_interleaved_mutation_drain():
    """Mutations submitted MID-BURST take effect exactly at their stream
    position: the whole interleaved burst is pre-filled before the
    dispatcher starts, so one drain sees [queries, replace, same queries,
    ingest, delete, same queries] — each segment's answers must be
    bit-identical to a cold engine over the frozen equivalent of the
    repository AT THAT POINT (the middle segment repeats the first
    segment's payloads verbatim, so a cached epoch-0 row being re-served
    after the replace would be caught, and the replaced dataset's point
    probe must visibly change)."""
    from repro.core import repo_mutate
    from repro.engine import LiveRepository
    from repro.launch.serve_search import Mutation, _to_query

    datasets = make_clustered_datasets(10, seed=5, n_points=(20, 50))
    live = LiveRepository(datasets, leaf_capacity=16, theta=THETA,
                          result_cache_size=64)
    n_slots = live.n_slots
    new0 = (datasets[0] + np.float32(30.0))        # visibly moved
    fresh = (datasets[3] + np.float32(7.0))
    ingest_slot = min(set(range(n_slots)) - live.live_ids)

    traffic = _segment_queries(
        ds_id=0, probe_lo=datasets[0].min(0) - np.float32(1.0),
        probe_hi=datasets[0].max(0) + np.float32(1.0))
    reqs = [[Request(op, _to_query(op, p)) for op, p in traffic]
            for _ in range(3)]
    muts = [Mutation("replace", ds_id=0, points=new0),
            Mutation("ingest", points=fresh),
            Mutation("delete", ds_id=1)]
    server = SearchServer(live=live, max_batch=64, max_wait_ms=250.0)
    for item in (*reqs[0], muts[0], *reqs[1], muts[1], muts[2], *reqs[2]):
        server._queue.put(item)
    server.start()
    try:
        got = [[r.future.result(timeout=600) for r in seg] for seg in reqs]
        assert muts[0].future.result(timeout=600) == 0
        assert muts[1].future.result(timeout=600) == ingest_slot
        assert muts[2].future.result(timeout=600) is None
    finally:
        server.stop()

    # the adjacent ingest+delete COALESCE into one publish (one epoch);
    # the replace, separated by queries, publishes alone — so 3 applied
    # mutations produce 2 data epochs and exactly 1 coalesced mutation
    assert live.epoch == 2
    assert server.stats.mutations == 3
    assert server.stats.mutation_latencies[0] >= 0.0
    assert live.engine.stats.mutations_coalesced == 1
    assert len(live.engine.stats.publish_seconds) == 2

    # frozen equivalents of the repository at each segment's position
    slots0 = list(datasets) + [None] * (n_slots - len(datasets))
    slots1 = [new0] + slots0[1:]
    cold0 = QueryEngine(repo_mutate.build_frozen(slots0, live.geometry),
                        leaf_capacity=16)
    cold1 = QueryEngine(repo_mutate.build_frozen(slots1, live.geometry),
                        leaf_capacity=16)
    cold2 = QueryEngine(live.frozen_repository(), leaf_capacity=16)
    _assert_same(got[0], cold0, traffic)
    _assert_same(got[1], cold1, traffic)
    _assert_same(got[2], cold2, traffic)
    # the replace was actually visible: the point probe into ds 0 must
    # differ between the first two segments (same payload, new content)
    assert not np.array_equal(np.asarray(got[0][2]), np.asarray(got[1][2]))


def test_live_poisoned_row_fallback_and_lane_errors():
    """A poisoned query sharing a drain with healthy queries AND a
    mutation on a LIVE engine fails only its own future: the mutation
    still publishes, healthy futures resolve with post-mutation-correct
    results, and the dispatcher survives.  Plus the lane's error
    contract: no live repo -> RuntimeError, unknown mutation -> ValueError."""
    from repro.engine import LiveRepository

    datasets = make_clustered_datasets(8, seed=9, n_points=(20, 40))
    live = LiveRepository(datasets, leaf_capacity=16, theta=THETA)
    # a TIGHT cluster: sparse bases get fully dropped by outlier removal
    # (their MBR refines to empty), which would make the mask probe moot
    fresh = (datasets[4] + np.float32(4.0))
    ingest_slot = min(set(range(live.n_slots)) - live.live_ids)
    server = SearchServer(live=live, max_batch=16, max_wait_ms=200.0).start()
    try:
        with pytest.raises(ValueError):
            server.submit_mutation("compact")
        lo = np.float32([-200, -200])      # covers the whole [0,100]^2 lake
        good1 = server.submit("topk_ia", q_lo=lo, q_hi=-lo, k=3)
        bad = server.submit("topk_ia", q_lo=np.zeros(3, np.float32),
                            q_hi=np.ones(3, np.float32), k=3)
        mfut = server.submit_mutation("ingest", points=fresh)
        good2 = server.submit("range_search", r_lo=lo, r_hi=-lo)
        assert np.asarray(good1.result(timeout=600)[0]).shape == (3,)
        with pytest.raises(Exception):
            bad.result(timeout=600)
        assert mfut.result(timeout=600) == ingest_slot
        mask = np.asarray(good2.result(timeout=600))
        # a mutation whose apply raises fails ITS future, nothing else
        bad_mut = server.submit_mutation("delete", ds_id=999)
        with pytest.raises(KeyError):
            bad_mut.result(timeout=600)
        # dispatcher survived; post-mutation answers match a cold engine
        after = server.submit("range_search", r_lo=lo, r_hi=-lo)
        cold = QueryEngine(live.frozen_repository(), leaf_capacity=16)
        want = cold.range_search(lo[None], (-lo)[None])[0]
        np.testing.assert_array_equal(np.asarray(after.result(timeout=600)),
                                      np.asarray(want))
        if ingest_slot < mask.shape[0]:
            assert mask[ingest_slot]       # good2 saw the ingested dataset
    finally:
        server.stop()


def test_mutation_lane_needs_live(env):
    datasets, repo = env
    server = SearchServer(QueryEngine(repo), max_batch=8).start()
    try:
        with pytest.raises(RuntimeError):
            server.submit_mutation("ingest", points=datasets[0])
    finally:
        server.stop()


# -- injectable clock (deterministic drain-bound / latency tests) -----------


class _FakeClock:
    """Virtual time: every call returns the current instant, then
    advances by ``step`` (0 = pinned)."""

    def __init__(self, t=0.0, step=0.0):
        self.t, self.step = t, step

    def __call__(self):
        now = self.t
        self.t += self.step
        return now


def test_clock_injected_static_drain_deadline(env):
    """The static drain's deadline reads the INJECTED clock: with virtual
    time jumping past max_wait between queue reads, a pre-filled partial
    batch drains and exits immediately — no real sleeping against a
    5-second window (the old sleep-based timing assumption)."""
    import time as _time

    datasets, repo = env
    clk = _FakeClock(t=100.0, step=10.0)           # step >> max_wait
    server = SearchServer(QueryEngine(repo), max_batch=64,
                          max_wait_ms=5000.0, adaptive=False, clock=clk)
    for _ in range(3):
        server._queue.put(Request("range_search", None, t_submit=clk()))
    t0 = _time.perf_counter()
    batch = server._drain()
    elapsed = _time.perf_counter() - t0
    assert len(batch) == 3                 # instantly-available rows taken
    assert elapsed < 2.0                   # virtual deadline, real exit
    assert clk.t > 100.0                   # the drain consulted the clock


def test_clock_injected_latency_accounting(env):
    """With a PINNED injected clock, submit->resolve latency is exactly
    0.0 for every request — latency stats become deterministic instead
    of sleep-calibrated."""
    datasets, repo = env
    clk = _FakeClock(t=50.0, step=0.0)
    server = SearchServer(QueryEngine(repo), max_batch=8, max_wait_ms=20.0,
                          adaptive=False, clock=clk).start()
    try:
        lo = np.float32([-10, -10])
        futures = [server.submit("range_search", r_lo=lo, r_hi=-lo)
                   for _ in range(3)]
        for f in futures:
            f.result(timeout=600)
    finally:
        server.stop()
    assert server.stats.latencies == [0.0, 0.0, 0.0]
    assert server.stats.p99_ms == server.stats.p50_ms == 0.0


def check_replicated_serving():
    """SearchServer over a ReplicatedQueryEngine (2 x 4 mesh): a mixed
    burst pre-filled BEFORE the dispatcher starts drains as ONE batch ->
    one engine.search call, every future gets the same legacy response
    shapes as the single-device server, answers are bit-identical to
    direct local-engine calls, and a poisoned request sharing a drain
    fails only its own future (per-request fallback works on the replica
    dispatch path too)."""
    import jax

    from repro.engine import ReplicatedQueryEngine

    datasets = make_clustered_datasets(17, seed=4, n_points=(20, 60))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    local = QueryEngine(repo)
    engine = ReplicatedQueryEngine(repo, n_replicas=2, n_data=4)
    server = SearchServer(engine, max_batch=64, max_wait_ms=250.0)
    traffic = make_traffic(repo, datasets, 27, seed=3)   # >= 2 of each kind
    assert {op for op, _ in traffic} == set(OPS)
    # pre-fill the queue so the whole burst is visible to the FIRST drain
    from repro.launch.serve_search import _to_query
    reqs = [Request(op, _to_query(op, p)) for op, p in traffic]
    for r in reqs:
        server._queue.put(r)
    server.start()
    try:
        results = [r.future.result(timeout=600) for r in reqs]
        # one drain, one search(): exactly the single-drain group count (11
        # stage-1 op/static groups + 3 pipeline stage-2 groups) — a split
        # drain would re-plan its groups and book more
        assert server.stats.batches == 14
        assert server.stats.batch_size_sum == 27
        s = engine.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
        assert s.plan_groups <= s.replica_subgroups <= s.plan_groups * 2
        # legacy response shapes + bit-identity vs the local engine
        for (op, payload), res in zip(traffic, results):
            if op == "range_search":
                want = local.range_search(payload["r_lo"][None],
                                          payload["r_hi"][None])[0]
                np.testing.assert_array_equal(np.asarray(res),
                                              np.asarray(want))
            elif op == "topk_ia":
                vals, ids = local.topk_ia(payload["q_lo"][None],
                                          payload["q_hi"][None],
                                          payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals[0]))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids[0]))
            elif op == "topk_hausdorff":
                q_batch = local.build_queries([payload["q"]])
                qi = jax.tree.map(lambda x: x[0], q_batch)
                vals, ids, _ = local.topk_hausdorff(qi, payload["k"])
                np.testing.assert_array_equal(np.asarray(res[0]),
                                              np.asarray(vals))
                np.testing.assert_array_equal(np.asarray(res[1]),
                                              np.asarray(ids))
                assert res[2].exact_evaluations > 0
            elif op == "pipeline":
                assert res.op == "pipeline"
                assert res.extras["stage1"] is not None
        # poisoned request isolated on the replica path: wrong box rank
        # poisons its group; the server falls back per-request and only
        # the bad future fails
        rng = np.random.default_rng(13)
        lo = rng.uniform(-60, 40, (2, 2)).astype(np.float32)
        hi = lo + 5.0
        good = server.submit("topk_ia", q_lo=lo[0], q_hi=hi[0], k=K)
        bad = server.submit("topk_ia", q_lo=np.zeros(3, np.float32),
                            q_hi=np.ones(3, np.float32), k=K)
        v, j = good.result(timeout=600)
        assert np.asarray(v).shape == (K,)
        import pytest as _pytest
        with _pytest.raises(Exception):
            bad.result(timeout=600)
        after = server.submit("range_search", r_lo=lo[1], r_hi=hi[1])
        assert np.asarray(after.result(timeout=600)).ndim == 1
    finally:
        server.stop()
    print("REPLICATED_SERVING_OK")


def test_replicated_serving():
    from conftest import dispatch_device_check
    dispatch_device_check("test_serve_search", "check_replicated_serving")
