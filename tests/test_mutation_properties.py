"""Property tests: random mutation-vs-query interleavings on a live
repository, checked against a host-side model.

Each example drives a random interleaving of
{ingest, delete, replace, search(mixed batch), cache-hit replay} against
`LiveRepository`, mirroring every mutation into a plain host-side dict
(slot id -> points).  After every step the cheap invariants hold:

  * the data epoch is monotone and the live-id set equals the model's;
  * ``cache_hits + cache_misses == dispatches`` (the executable-cache
    invariant is undisturbed by mutations and epoch purges);
  * a replayed query batch with NO intervening mutation is served from
    the result cache (hits strictly increase).

At checkpoints (and at the end) the FULL tentpole contract is asserted:
the resident repository is bitwise equal to `build_frozen(model)` and a
mixed op batch returns bit-identical results to a cold engine over that
frozen build — on local dispatch in the hypothesis/seeded sweep, and on
the 3-shard and 2x4 replica meshes via `dispatch_device_check` (with a
per-device residency bound: mutated slot bodies stay sharded).

Runs under hypothesis when installed (the CI path); without it — or with
``REPRO_SEEDED_PROPS=1`` set, the deterministic-CI knob — the same
property runs over a seeded sweep so the contract never silently skips
(pattern from tests/test_exacthaus_properties.py).

Geometry is pinned across examples (fixed point budget per dataset, fixed
leaf capacity, ``point_capacity=32``) so every example reuses the same
stage executables instead of recompiling per draw.
"""
import os

import numpy as np
import pytest

from conftest import dispatch_device_check
from repro.engine import LiveRepository, Query
from test_live_repository import (
    WHOLE_HI,
    WHOLE_LO,
    check_bit_identity,
)

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

USE_SEEDED = (not HAVE_HYPOTHESIS
              or bool(os.environ.get("REPRO_SEEDED_PROPS")))

N_INIT = 6
LEAF = 8
POINT_CAP = 32


def _mk_dataset(rng):
    n = int(rng.integers(8, 28))
    c = rng.uniform(-40, 40, 2)
    return (c + rng.normal(0, rng.uniform(1, 4), (n, 2))).astype(np.float32)


def _mixed_batch(rng, live_ids):
    """A random mixed dataset+point op batch over the current live set."""
    ids = sorted(live_ids)
    lo = np.sort(rng.uniform(-50, 30, (2, 2)).astype(np.float32), axis=0)
    qpts = _mk_dataset(rng)[:12]
    return [
        Query(op="range_search", r_lo=lo[0], r_hi=lo[1]),
        Query(op="topk_ia", r_lo=lo[0], r_hi=lo[1],
              k=int(rng.integers(1, 5))),
        Query(op="topk_hausdorff_approx", q=qpts, k=2, eps=0.05),
        Query(op="range_points", ds_id=int(rng.choice(ids)),
              r_lo=WHOLE_LO, r_hi=WHOLE_HI),
        Query(op="nnp", ds_id=int(rng.choice(ids)), q=qpts),
    ]


def _run_interleaving(seed: int, mesh=None, steps: int = 12,
                      checkpoints=(5,)):
    rng = np.random.default_rng(seed)
    init = [_mk_dataset(rng) for _ in range(N_INIT)]
    live = LiveRepository(init, mesh=mesh, leaf_capacity=LEAF,
                          point_capacity=POINT_CAP, result_cache_size=64)
    model = {j: init[j] for j in range(N_INIT)}
    last_batch = None
    mutated_since_search = True
    prev_epoch = live.epoch

    for step in range(steps):
        kind = int(rng.integers(0, 5))
        if kind == 0:
            ds = _mk_dataset(rng)
            sid = live.ingest(ds)
            assert sid not in model           # a freed or fresh slot
            model[sid] = ds
            mutated_since_search = True
        elif kind == 1 and len(model) > 1:
            sid = int(rng.choice(sorted(model)))
            live.delete(sid)
            del model[sid]
            mutated_since_search = True
        elif kind == 2:
            sid = int(rng.choice(sorted(model)))
            ds = _mk_dataset(rng)
            live.replace(sid, ds)
            model[sid] = ds
            mutated_since_search = True
        elif kind == 3:
            last_batch = _mixed_batch(rng, live.live_ids)
            live.search(last_batch)
            mutated_since_search = False
        elif last_batch is not None and all(
                q.ds_id is None or q.ds_id in live.live_ids
                for q in last_batch):
            # cache-hit replay: identical batch, same epoch -> served
            # from the result cache, bit-identical by the cache contract
            h0 = live.stats.result_cache_hits
            live.search(last_batch)
            if not mutated_since_search:
                assert live.stats.result_cache_hits >= h0 + len(last_batch)

        # cheap per-step invariants
        assert live.epoch >= prev_epoch
        prev_epoch = live.epoch
        assert live.live_ids == set(model)
        s = live.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
        for j in range(live.n_slots):
            got = live._slot_data.get(j)
            want = model.get(j)
            assert (got is None) == (want is None)

        if step in checkpoints:
            check_bit_identity(live, mesh=mesh, leaf_capacity=LEAF)

    check_bit_identity(live, mesh=mesh, leaf_capacity=LEAF)
    return live


if not USE_SEEDED:
    @given(seed=st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_mutation_interleaving_matches_frozen(seed):
        _run_interleaving(seed)

else:
    @pytest.mark.parametrize("seed", range(8))
    def test_mutation_interleaving_matches_frozen(seed):
        _run_interleaving(seed)


# -- concurrent-prepare model (two-stage pipeline) --------------------------
#
# The serving scheduler prepares the NEXT mutation run while the current
# query segment is in flight, then publishes the whole run as one epoch.
# This model replays that interleaving DETERMINISTICALLY (no threads, no
# sleeps): for every round a random N-mutation run is prepared first,
# queries are served with the prepared-but-unpublished group in flight —
# they must still see the pre-publish snapshot bit-exactly — and only
# then does the group publish, landing all N mutations at ONE stream
# position / data epoch.


def _mk_group_specs(rng, model, n):
    """A random run of n mutations, sequentially valid as a group: the
    view tracks in-group deletes so no later item targets a dead id."""
    specs, view = [], set(model)
    for _ in range(n):
        kind = int(rng.integers(0, 3))
        if kind == 0 or len(view) <= 1:
            specs.append(("ingest", None, _mk_dataset(rng)))
        elif kind == 1:
            sid = int(rng.choice(sorted(view)))
            view.discard(sid)
            specs.append(("delete", sid, None))
        else:
            specs.append(("replace", int(rng.choice(sorted(view))),
                          _mk_dataset(rng)))
    return specs


def _run_concurrent_prepare(seed: int, mesh=None, rounds: int = 8,
                            checkpoints=(2, 5)):
    rng = np.random.default_rng(seed)
    init = [_mk_dataset(rng) for _ in range(N_INIT)]
    live = LiveRepository(init, mesh=mesh, leaf_capacity=LEAF,
                          point_capacity=POINT_CAP, result_cache_size=64)
    model = {j: init[j] for j in range(N_INIT)}
    disp = live.engine.dispatch

    for rnd in range(rounds):
        specs = _mk_group_specs(rng, model, int(rng.integers(1, 5)))
        epoch0 = live.epoch
        layout0 = getattr(disp, "repo_epoch", 0)
        mc0 = live.engine.stats.mutations_coalesced

        group = live.prepare_group(specs)
        assert all(p.error is None for p in group.items)
        # prepare is INVISIBLE: epoch, live set, and every query answer
        # still belong to the pre-publish stream position
        assert live.epoch == epoch0
        assert live.live_ids == set(model)
        if rnd in checkpoints:
            check_bit_identity(live, mesh=mesh, leaf_capacity=LEAF)
        else:
            live.search(_mixed_batch(rng, live.live_ids))

        outcomes = live.publish_group(group)
        for (op, ds_id, pts), out in zip(specs, outcomes):
            assert not isinstance(out, Exception)
            if op == "ingest":
                assert out not in model       # a freed or fresh slot
                model[out] = pts
            elif op == "delete":
                assert out is None
                del model[ds_id]
            else:
                assert out == ds_id
                model[ds_id] = pts
        # the whole run lands at ONE data epoch (plus one per tier
        # growth the prepare stage reserved virtually), and every
        # mutation beyond the first is booked as coalesced
        grows = getattr(disp, "repo_epoch", 0) - layout0
        assert live.epoch == epoch0 + 1 + grows
        assert live.engine.stats.mutations_coalesced == mc0 + len(specs) - 1
        assert live.live_ids == set(model)
        s = live.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
        for j in range(live.n_slots):
            assert (live._slot_data.get(j) is None) == (model.get(j) is None)

    check_bit_identity(live, mesh=mesh, leaf_capacity=LEAF)
    return live


if not USE_SEEDED:
    @given(seed=st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_concurrent_prepare_matches_stream_position(seed):
        _run_concurrent_prepare(seed)

else:
    @pytest.mark.parametrize("seed", range(6))
    def test_concurrent_prepare_matches_stream_position(seed):
        _run_concurrent_prepare(seed)


def test_server_coalesced_runs_fake_clock():
    """The full scheduler under an INJECTABLE clock (virtual seconds, no
    sleeps): a pre-filled drain [queries, M, M, queries, M, queries]
    must answer every segment at its stream position while the adjacent
    mutation pair coalesces into one publish whose prepare overlapped
    the preceding segment — and the overlap/publish accounting comes out
    of the fake clock, not wall time."""
    from repro.launch.serve_search import Mutation, SearchServer

    class _TickClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            self.t += 1.0
            return self.t

    rng = np.random.default_rng(17)
    tick = _TickClock()
    init = [_mk_dataset(rng) for _ in range(N_INIT)]
    live = LiveRepository(init, leaf_capacity=LEAF, clock=tick,
                          point_capacity=POINT_CAP, result_cache_size=64)
    model = {j: init[j] for j in range(N_INIT)}

    from repro.launch.serve_search import Request

    def seg():
        # point-op targets avoid the to-be-deleted id so every segment
        # is valid at (and after) its stream position
        q = _mixed_batch(rng, set(model) - {2})
        return [Request(x.op, x, t_submit=0.0) for x in q]

    d0, d1 = _mk_dataset(rng), _mk_dataset(rng)
    segs = [seg(), seg(), seg()]
    muts = [Mutation("ingest", points=d0, t_submit=0.0),
            Mutation("replace", ds_id=1, points=d1, t_submit=0.0),
            Mutation("delete", ds_id=2, t_submit=0.0)]
    server = SearchServer(live=live, max_batch=64, max_wait_ms=250.0,
                          clock=tick)
    for item in (*segs[0], muts[0], muts[1], *segs[1], muts[2], *segs[2]):
        server._queue.put(item)
    server.start()
    try:
        got = [[r.future.result(timeout=600) for r in s] for s in segs]
        sid = muts[0].future.result(timeout=600)
        assert muts[1].future.result(timeout=600) == 1
        assert muts[2].future.result(timeout=600) is None
    finally:
        server.stop()

    # run [ingest, replace] coalesced -> one epoch; delete alone -> one
    assert live.epoch == 2
    assert live.engine.stats.mutations_coalesced == 1
    assert len(live.engine.stats.publish_seconds) == 2
    assert server.stats.mutations == 3
    # every duration was measured on the virtual clock: publishes and
    # the overlap window are whole (positive) ticks
    assert all(t >= 1.0 for t in live.engine.stats.publish_seconds)
    assert live.engine.stats.prepare_overlap_seconds >= 0.0
    assert all(t >= 1.0 for t in server.stats.mutation_latencies)

    # segment answers match the frozen oracle at each stream position
    from repro.core import repo_mutate
    from repro.engine import QueryEngine
    states = [dict(model)]
    model[sid] = d0
    model[1] = d1
    states.append(dict(model))
    del model[2]
    states.append(dict(model))
    assert live.live_ids == set(model)
    from repro.launch.serve_search import _legacy_result
    for want_state, s, res in zip(states, segs, got):
        slots = [want_state.get(j) for j in range(live.n_slots)]
        cold = QueryEngine(repo_mutate.build_frozen(slots, live.geometry),
                           leaf_capacity=LEAF)
        want = cold.search([r.query for r in s])
        for a, b in zip(res, want):
            for x, y in zip(_leaves(a), _leaves(_legacy_result(b))):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _leaves(res):
    """Flatten a search result (array or tuple of arrays) to arrays."""
    if isinstance(res, tuple):
        out = []
        for x in res:
            out.extend(_leaves(x))
        return out
    return [res]


def _check_mesh_interleaving(mesh, n_devices):
    import jax

    from repro.engine import repo_device_bytes
    live = _run_interleaving(3, mesh=mesh, steps=10, checkpoints=(4,))
    dev = repo_device_bytes(live.repo)
    assert len(dev) == n_devices
    total = sum(dev.values())
    body = sum(np.asarray(x).nbytes
               for x in jax.tree.leaves(live.repo.ds_index))
    n_sh = int(live.engine.dispatch.n_shards)
    # slot bodies stay sharded through arbitrary interleavings: no device
    # holds more than its shard plus the replicated (tiny) remainder
    assert max(dev.values()) <= (total - body) + body // n_sh + body // 8


def check_mutation_props_sharded():
    from repro.engine import data_mesh
    _check_mesh_interleaving(data_mesh(3), 3)


def check_mutation_props_replicated():
    from repro.engine import replica_mesh
    _check_mesh_interleaving(replica_mesh(2, 4), 8)


def test_mutation_interleaving_sharded():
    dispatch_device_check("test_mutation_properties",
                          "check_mutation_props_sharded", devices=3)


def test_mutation_interleaving_replicated():
    dispatch_device_check("test_mutation_properties",
                          "check_mutation_props_replicated", devices=8)


# the coalesced (bucket > 1) owner-write updater under both mesh shapes:
# the concurrent-prepare model drives groups of up to 4 through the
# batched shard_map scatter and asserts the same bit-identity bar


def check_concurrent_prepare_sharded():
    from repro.engine import data_mesh
    _run_concurrent_prepare(5, mesh=data_mesh(3), rounds=6,
                            checkpoints=(2,))


def check_concurrent_prepare_replicated():
    from repro.engine import replica_mesh
    _run_concurrent_prepare(5, mesh=replica_mesh(2, 4), rounds=6,
                            checkpoints=(2,))


def test_concurrent_prepare_sharded():
    dispatch_device_check("test_mutation_properties",
                          "check_concurrent_prepare_sharded", devices=3)


def test_concurrent_prepare_replicated():
    dispatch_device_check("test_mutation_properties",
                          "check_concurrent_prepare_replicated", devices=8)
