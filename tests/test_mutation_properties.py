"""Property tests: random mutation-vs-query interleavings on a live
repository, checked against a host-side model.

Each example drives a random interleaving of
{ingest, delete, replace, search(mixed batch), cache-hit replay} against
`LiveRepository`, mirroring every mutation into a plain host-side dict
(slot id -> points).  After every step the cheap invariants hold:

  * the data epoch is monotone and the live-id set equals the model's;
  * ``cache_hits + cache_misses == dispatches`` (the executable-cache
    invariant is undisturbed by mutations and epoch purges);
  * a replayed query batch with NO intervening mutation is served from
    the result cache (hits strictly increase).

At checkpoints (and at the end) the FULL tentpole contract is asserted:
the resident repository is bitwise equal to `build_frozen(model)` and a
mixed op batch returns bit-identical results to a cold engine over that
frozen build — on local dispatch in the hypothesis/seeded sweep, and on
the 3-shard and 2x4 replica meshes via `dispatch_device_check` (with a
per-device residency bound: mutated slot bodies stay sharded).

Runs under hypothesis when installed (the CI path); without it — or with
``REPRO_SEEDED_PROPS=1`` set, the deterministic-CI knob — the same
property runs over a seeded sweep so the contract never silently skips
(pattern from tests/test_exacthaus_properties.py).

Geometry is pinned across examples (fixed point budget per dataset, fixed
leaf capacity, ``point_capacity=32``) so every example reuses the same
stage executables instead of recompiling per draw.
"""
import os

import numpy as np
import pytest

from conftest import dispatch_device_check
from repro.engine import LiveRepository, Query
from test_live_repository import (
    WHOLE_HI,
    WHOLE_LO,
    check_bit_identity,
)

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

USE_SEEDED = (not HAVE_HYPOTHESIS
              or bool(os.environ.get("REPRO_SEEDED_PROPS")))

N_INIT = 6
LEAF = 8
POINT_CAP = 32


def _mk_dataset(rng):
    n = int(rng.integers(8, 28))
    c = rng.uniform(-40, 40, 2)
    return (c + rng.normal(0, rng.uniform(1, 4), (n, 2))).astype(np.float32)


def _mixed_batch(rng, live_ids):
    """A random mixed dataset+point op batch over the current live set."""
    ids = sorted(live_ids)
    lo = np.sort(rng.uniform(-50, 30, (2, 2)).astype(np.float32), axis=0)
    qpts = _mk_dataset(rng)[:12]
    return [
        Query(op="range_search", r_lo=lo[0], r_hi=lo[1]),
        Query(op="topk_ia", r_lo=lo[0], r_hi=lo[1],
              k=int(rng.integers(1, 5))),
        Query(op="topk_hausdorff_approx", q=qpts, k=2, eps=0.05),
        Query(op="range_points", ds_id=int(rng.choice(ids)),
              r_lo=WHOLE_LO, r_hi=WHOLE_HI),
        Query(op="nnp", ds_id=int(rng.choice(ids)), q=qpts),
    ]


def _run_interleaving(seed: int, mesh=None, steps: int = 12,
                      checkpoints=(5,)):
    rng = np.random.default_rng(seed)
    init = [_mk_dataset(rng) for _ in range(N_INIT)]
    live = LiveRepository(init, mesh=mesh, leaf_capacity=LEAF,
                          point_capacity=POINT_CAP, result_cache_size=64)
    model = {j: init[j] for j in range(N_INIT)}
    last_batch = None
    mutated_since_search = True
    prev_epoch = live.epoch

    for step in range(steps):
        kind = int(rng.integers(0, 5))
        if kind == 0:
            ds = _mk_dataset(rng)
            sid = live.ingest(ds)
            assert sid not in model           # a freed or fresh slot
            model[sid] = ds
            mutated_since_search = True
        elif kind == 1 and len(model) > 1:
            sid = int(rng.choice(sorted(model)))
            live.delete(sid)
            del model[sid]
            mutated_since_search = True
        elif kind == 2:
            sid = int(rng.choice(sorted(model)))
            ds = _mk_dataset(rng)
            live.replace(sid, ds)
            model[sid] = ds
            mutated_since_search = True
        elif kind == 3:
            last_batch = _mixed_batch(rng, live.live_ids)
            live.search(last_batch)
            mutated_since_search = False
        elif last_batch is not None and all(
                q.ds_id is None or q.ds_id in live.live_ids
                for q in last_batch):
            # cache-hit replay: identical batch, same epoch -> served
            # from the result cache, bit-identical by the cache contract
            h0 = live.stats.result_cache_hits
            live.search(last_batch)
            if not mutated_since_search:
                assert live.stats.result_cache_hits >= h0 + len(last_batch)

        # cheap per-step invariants
        assert live.epoch >= prev_epoch
        prev_epoch = live.epoch
        assert live.live_ids == set(model)
        s = live.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
        for j in range(live.n_slots):
            got = live._slot_data.get(j)
            want = model.get(j)
            assert (got is None) == (want is None)

        if step in checkpoints:
            check_bit_identity(live, mesh=mesh, leaf_capacity=LEAF)

    check_bit_identity(live, mesh=mesh, leaf_capacity=LEAF)
    return live


if not USE_SEEDED:
    @given(seed=st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_mutation_interleaving_matches_frozen(seed):
        _run_interleaving(seed)

else:
    @pytest.mark.parametrize("seed", range(8))
    def test_mutation_interleaving_matches_frozen(seed):
        _run_interleaving(seed)


def _check_mesh_interleaving(mesh, n_devices):
    import jax

    from repro.engine import repo_device_bytes
    live = _run_interleaving(3, mesh=mesh, steps=10, checkpoints=(4,))
    dev = repo_device_bytes(live.repo)
    assert len(dev) == n_devices
    total = sum(dev.values())
    body = sum(np.asarray(x).nbytes
               for x in jax.tree.leaves(live.repo.ds_index))
    n_sh = int(live.engine.dispatch.n_shards)
    # slot bodies stay sharded through arbitrary interleavings: no device
    # holds more than its shard plus the replicated (tiny) remainder
    assert max(dev.values()) <= (total - body) + body // n_sh + body // 8


def check_mutation_props_sharded():
    from repro.engine import data_mesh
    _check_mesh_interleaving(data_mesh(3), 3)


def check_mutation_props_replicated():
    from repro.engine import replica_mesh
    _check_mesh_interleaving(replica_mesh(2, 4), 8)


def test_mutation_interleaving_sharded():
    dispatch_device_check("test_mutation_properties",
                          "check_mutation_props_sharded", devices=3)


def test_mutation_interleaving_replicated():
    dispatch_device_check("test_mutation_properties",
                          "check_mutation_props_replicated", devices=8)
