"""Property tests for ExactHaus: brute-force equivalence + pruning bounds.

For random repositories and random queries, on BOTH dispatchers (local
and sharded):

  * the ExactHaus top-k equals the brute-force directed Hausdorff over
    all valid datasets — ascending values match the sorted truth and the
    returned ids point at datasets carrying exactly those values (the
    formulation that stays well-defined when duplicated datasets tie at
    the top-k boundary);
  * the device pipeline, the sharded engine, and the seed host loop
    `topk_hausdorff_host` return BIT-IDENTICAL values and ids (the
    documented tie-order contract: per-shard chunking may change which
    extra candidates get evaluated, never the returned set);
  * phase 2 never evaluates more candidates than survive the bound
    phases: `exact_evaluations <= candidates_after_bounds`, and the
    bound-phase counters agree across every schedule;
  * BATCHED runs (one shared phase-2 work frontier per dispatch): every
    query in a random batch — ragged per-query point counts, duplicate
    queries, batch sizes straddling bucket boundaries — is bit-identical
    to its solo `topk_hausdorff_host` run on both dispatchers.

Runs under hypothesis when installed (the CI path); without it the same
properties run over a seeded random sweep so the suite never silently
skips the contract (pattern from tests/test_merge_properties.py).

Repositories come from a small seed pool with FIXED padded shapes
(n_datasets <= 16 -> 16 slots; every pool repo includes exact duplicate
datasets for LB/value ties), so executables are reused across examples
instead of recompiling per draw.
"""
import jax
import numpy as np
import pytest

from repro.core import search
from repro.core.build import build_repository
from repro.engine import QueryEngine, ShardedQueryEngine
from repro.engine.sharded import data_mesh

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

REPO_SEEDS = (0, 1, 2)
K_POOL = (1, 3, 7, 16)       # 16 == slot count: k past the valid datasets
Q_SIZES = (6, 20)            # two point buckets only (16 and 32)
_ENVS: dict = {}


def _make_datasets(seed: int):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 14))
    out = []
    for _ in range(n):
        npts = int(rng.integers(4, 30))
        c = rng.uniform(-40, 40, 2)
        pts = c + rng.normal(size=(npts, 2)) * rng.uniform(0.5, 8.0)
        out.append(pts.astype(np.float32))
    # exact duplicates: duplicate LBs (Eq. 4 zero-clamp) AND duplicate
    # Hausdorff values that can land ON the top-k boundary
    out.append(out[0].copy())
    out.append(out[-2].copy())
    return out


def _env(repo_seed: int):
    if repo_seed not in _ENVS:
        datasets = _make_datasets(repo_seed)
        repo, _ = build_repository(datasets, leaf_capacity=16, theta=5,
                                   remove_outliers=False)
        n_sh = min(jax.device_count(), 8)
        _ENVS[repo_seed] = (
            datasets, repo, QueryEngine(repo),
            ShardedQueryEngine(repo, mesh=data_mesh(n_sh)),
        )
    return _ENVS[repo_seed]


def _run_case(repo_seed: int, q_seed: int, q_size: int, k: int):
    datasets, repo, eng, sng = _env(repo_seed)
    rng = np.random.default_rng(q_seed)
    base = datasets[int(rng.integers(len(datasets)))]
    take = rng.integers(0, len(base), q_size)
    q = (base[take] + rng.normal(size=(q_size, 2)) * 0.5).astype(np.float32)

    q_batch = eng.build_queries([q])
    qi = jax.tree.map(lambda x: x[0], q_batch)

    # ---- oracle 1: the seed host loop ------------------------------------
    vh, ih, sh = search.topk_hausdorff_host(repo, qi, k)
    vh, ih = np.asarray(vh), np.asarray(ih)

    # ---- oracle 2: brute-force directed Hausdorff ------------------------
    truth = np.array([
        np.sqrt(((q[:, None, :] - d[None, :, :]) ** 2).sum(-1)).min(1).max()
        for d in datasets
    ])
    n_valid = len(datasets)
    kk = min(k, n_valid)
    want = np.sort(truth)[:kk]
    np.testing.assert_allclose(vh[:kk], want, rtol=1e-5, atol=1e-4)
    # ids must name datasets whose true values ARE the top-k values (the
    # tie-safe formulation), and be distinct
    np.testing.assert_allclose(truth[ih[:kk]], want, rtol=1e-5, atol=1e-4)
    assert len(set(ih[:kk].tolist())) == kk
    if k > n_valid:                      # overrun: pruned-slot sentinels
        assert (vh[kk:] > 1e30).all()

    # ---- both dispatchers: bit-identical to the host loop ----------------
    vd, jd, sd = eng.topk_hausdorff(qi, k)
    np.testing.assert_array_equal(np.asarray(vd), vh)
    np.testing.assert_array_equal(np.asarray(jd), ih)
    vs, js, ss = sng.topk_hausdorff(qi, k)
    np.testing.assert_array_equal(np.asarray(vs), vh)
    np.testing.assert_array_equal(np.asarray(js), ih)

    # ---- pruning soundness accounting ------------------------------------
    for stats in (sd, ss, sh):
        assert 0 <= stats.exact_evaluations <= stats.candidates_after_bounds
        assert stats.candidates_after_bounds == sd.candidates_after_bounds
        assert stats.nodes_evaluated == sd.nodes_evaluated
    # the single-device schedules agree exactly; the sharded schedule may
    # evaluate different extras but never more than the candidate set
    assert sd.exact_evaluations == sh.exact_evaluations


BATCH_SIZES = (1, 3, 5, 9)   # below / straddling / above bucket boundaries


def _run_batched_case(repo_seed: int, q_seed: int, batch: int, k: int):
    """Every query in a random (B, ...) ExactHaus batch must be
    bit-identical to its solo `topk_hausdorff_host` run, on BOTH
    dispatchers — ragged per-query point counts (mixed sizes padded into
    one bucket), duplicate queries inside the batch, duplicate-LB ties
    (the repo pool interleaves cloned datasets), and batch sizes that
    straddle bucket boundaries."""
    datasets, repo, eng, sng = _env(repo_seed)
    rng = np.random.default_rng(q_seed)
    qs = []
    for _ in range(batch):
        base = datasets[int(rng.integers(len(datasets)))]
        q_size = Q_SIZES[int(rng.integers(len(Q_SIZES)))]   # ragged sizes
        take = rng.integers(0, len(base), q_size)
        qs.append((base[take]
                   + rng.normal(size=(q_size, 2)) * 0.5).astype(np.float32))
    if batch >= 2:
        qs[-1] = qs[0].copy()     # duplicate query inside the batch

    q_batch = eng.build_queries(qs)
    for engine in (eng, sng):
        vals, ids, stats = engine.topk_hausdorff(q_batch, k)
        assert vals.shape == (batch, min(k, repo.n_slots))
        assert len(stats) == batch
        for b in range(batch):
            qi = jax.tree.map(lambda x, b=b: x[b], q_batch)
            vh, ih, sh = search.topk_hausdorff_host(repo, qi, k)
            np.testing.assert_array_equal(np.asarray(vals[b]),
                                          np.asarray(vh))
            np.testing.assert_array_equal(np.asarray(ids[b]),
                                          np.asarray(ih))
            # bound phases are schedule-independent; phase-2 never
            # evaluates more than the candidate set
            assert stats[b].nodes_evaluated == sh.nodes_evaluated
            assert (stats[b].candidates_after_bounds
                    == sh.candidates_after_bounds)
            assert 0 <= stats[b].exact_evaluations \
                <= stats[b].candidates_after_bounds
        if engine is eng:
            # same chunk => each query's phase-2 trajectory is its solo
            # loop in lockstep: evaluated matches the host loop exactly
            for b in range(batch):
                qi = jax.tree.map(lambda x, b=b: x[b], q_batch)
                _, _, sh = search.topk_hausdorff_host(repo, qi, k)
                assert stats[b].exact_evaluations == sh.exact_evaluations
    # duplicate rows in one batch return identical answers
    if batch >= 2:
        vals, ids, _ = eng.topk_hausdorff(q_batch, k)
        np.testing.assert_array_equal(np.asarray(vals[-1]),
                                      np.asarray(vals[0]))
        np.testing.assert_array_equal(np.asarray(ids[-1]),
                                      np.asarray(ids[0]))


def _case_from_seed(seed: int):
    rng = np.random.default_rng(seed)
    return (
        REPO_SEEDS[int(rng.integers(len(REPO_SEEDS)))],
        int(rng.integers(2**31 - 1)),
        Q_SIZES[int(rng.integers(len(Q_SIZES)))],
        K_POOL[int(rng.integers(len(K_POOL)))],
    )


def _batched_case_from_seed(seed: int):
    rng = np.random.default_rng(seed)
    return (
        REPO_SEEDS[int(rng.integers(len(REPO_SEEDS)))],
        int(rng.integers(2**31 - 1)),
        BATCH_SIZES[int(rng.integers(len(BATCH_SIZES)))],
        K_POOL[int(rng.integers(len(K_POOL)))],
    )


if HAVE_HYPOTHESIS:
    @given(
        repo_seed=st.sampled_from(REPO_SEEDS),
        q_seed=st.integers(0, 2**31 - 1),
        q_size=st.sampled_from(Q_SIZES),
        k=st.sampled_from(K_POOL),
    )
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_exacthaus_matches_brute_and_host(repo_seed, q_seed, q_size, k):
        _run_case(repo_seed, q_seed, q_size, k)

    @given(
        repo_seed=st.sampled_from(REPO_SEEDS),
        q_seed=st.integers(0, 2**31 - 1),
        batch=st.sampled_from(BATCH_SIZES),
        k=st.sampled_from(K_POOL),
    )
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_exacthaus_batched_matches_solo_host(repo_seed, q_seed, batch,
                                                 k):
        _run_batched_case(repo_seed, q_seed, batch, k)

else:
    @pytest.mark.parametrize("seed", range(10))
    def test_exacthaus_matches_brute_and_host(seed):
        _run_case(*_case_from_seed(seed))

    @pytest.mark.parametrize("seed", range(6))
    def test_exacthaus_batched_matches_solo_host(seed):
        _run_batched_case(*_batched_case_from_seed(seed))
