"""Per-architecture smoke tests (spec deliverable f): reduced config, one
forward + one train step + one decode step on CPU; output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models import ssm
from repro.models.layers import attention
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def _batch(cfg, key, B=2, S=32, labels=False):
    batch = {}
    if cfg.embed_input:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.vision_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    lg, caches, ln = M.prefill(params, cfg, batch, max_len=S + 8)
    assert lg.shape == (B, 1, cfg.vocab_size)
    tok = (jnp.zeros((B, 1), jnp.int32) if cfg.embed_input else
           jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16))
    ctx = batch.get("image_embeds")
    lg2, caches = M.decode_step(params, cfg, tok, caches, jnp.int32(S),
                                ctx=ctx)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg2.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["llama3_8b", "grok_1_314b", "mamba2_780m",
                                  "jamba_v0_1_52b"])
def test_train_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(1)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=1)
    state = ts.init_train_state(key, cfg, opt_cfg)
    step = jax.jit(ts.make_train_step(cfg, opt_cfg))
    batch = _batch(cfg, key, B=2, S=32, labels=True)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    state, m2 = step(state, batch)
    assert float(m2["loss"]) < float(m["loss"])  # one step on same batch


def test_prefill_decode_consistency():
    """Decoding the (n+1)th token after an n-token prefill must equal the
    teacher-forced logits at position n."""
    cfg = configs.get_reduced("llama3_8b")
    cfg = dataclasses.replace(cfg, attn_q_chunk=16, attn_kv_chunk=16)
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = M.forward(params, cfg, {"tokens": tokens})
    lg, caches, _ = M.prefill(params, cfg, {"tokens": tokens[:, :-1]},
                              max_len=S + 4)
    lg2, _ = M.decode_step(params, cfg, tokens[:, -1:], caches,
                           jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(lg2[:, 0]), np.asarray(full[:, -1]), atol=0.15, rtol=0.05)


def test_prefill_decode_consistency_mamba():
    cfg = configs.get_reduced("mamba2_780m")
    key = jax.random.PRNGKey(3)
    params = M.init_params(key, cfg)
    B, P, S = 2, 32, 64   # prefill length = one ssm chunk; forward = two
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = M.forward(params, cfg, {"tokens": tokens})
    lg, caches, _ = M.prefill(params, cfg, {"tokens": tokens[:, :P]},
                              max_len=P + 4)
    lg2, _ = M.decode_step(params, cfg, tokens[:, P:P + 1], caches,
                           jnp.int32(P))
    # teacher-forced logits at position P are conditioned on tokens[0..P]
    np.testing.assert_allclose(
        np.asarray(lg2[:, 0]), np.asarray(full[:, P]), atol=0.15, rtol=0.05)


def test_ssd_chunked_equals_sequential():
    key = jax.random.PRNGKey(1)
    B, L, H, P, N = 2, 64, 4, 16, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y1, s1 = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y2, s2 = ssm.ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=1e-3, rtol=1e-3)


def test_chunked_attention_equals_naive():
    key = jax.random.PRNGKey(0)
    B, S, H, KH, D = 2, 128, 8, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    G = H // KH
    q5 = q.reshape(B, S, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    want = jnp.transpose(want, (0, 3, 1, 2, 4)).reshape(B, S, H, D)
    got = attention(q, k, v, causal=True, q_chunk=32, kv_chunk=16)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_analytic_param_counts_close_to_real():
    """cfg.param_count() (used for MODEL_FLOPS) must track actual inits."""
    for arch in ["llama3_8b", "mamba2_780m", "grok_1_314b"]:
        cfg = configs.get_reduced(arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        real = sum(x.size for x in jax.tree.leaves(params))
        assert abs(real - cfg.param_count()) / real < 0.05, arch


def test_int8_kv_cache_decode_matches_fp():
    """§Perf iteration 8: int8 KV cache decode tracks the fp path within
    quantization noise, and prefill->decode stays consistent."""
    cfg = dataclasses.replace(configs.get_reduced("llama3_8b"),
                              kv_cache_dtype="int8",
                              attn_q_chunk=16, attn_kv_chunk=16)
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = M.forward(params, cfg, {"tokens": tokens})
    lg, caches, _ = M.prefill(params, cfg, {"tokens": tokens[:, :-1]},
                              max_len=S + 4)
    assert caches[0]["k"].dtype == jnp.int8
    assert caches[0]["k_scale"].dtype == jnp.bfloat16
    lg2, caches = M.decode_step(params, cfg, tokens[:, -1:], caches,
                                jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(full[:, -1]),
                               atol=0.2, rtol=0.1)  # int8 quant noise
    lg3, _ = M.decode_step(params, cfg, tokens[:, -1:], caches, jnp.int32(S))
    assert bool(jnp.isfinite(lg3.astype(jnp.float32)).all())
