"""Top-k EMD exemplar search (the paper's companion metric [67])."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import emd
from repro.core.build import build_repository


def _cluster(rng, center, n=100):
    return (center + rng.normal(size=(n, 2))).astype(np.float32)


def test_emd_ranks_by_distribution_distance():
    rng = np.random.default_rng(0)
    A, B, C = np.array([0., 0.]), np.array([20., 0.]), np.array([0., 20.])
    lake = ([_cluster(rng, A) for _ in range(5)]
            + [_cluster(rng, B) for _ in range(5)]
            + [_cluster(rng, C) for _ in range(5)])
    repo, _ = build_repository(lake, leaf_capacity=16, theta=5)
    q = _cluster(rng, A)
    vals, ids = emd.topk_emd(repo, jnp.asarray(q), jnp.ones(len(q), bool),
                             15, theta=4)
    ids = np.asarray(ids)
    vals = np.asarray(vals)
    assert all(i < 5 for i in ids[:5])           # cluster A first
    assert vals[:5].max() < vals[5:].min()       # strict separation
    assert np.isfinite(vals).all() and (vals >= -1e-6).all()


def test_emd_prefilter_matches_full():
    rng = np.random.default_rng(1)
    lake = [_cluster(rng, rng.uniform(0, 30, 2)) for _ in range(16)]
    repo, _ = build_repository(lake, leaf_capacity=16, theta=5)
    q = lake[3]
    v_full, i_full = emd.topk_emd(repo, jnp.asarray(q),
                                  jnp.ones(len(q), bool), 3, theta=4)
    v_pre, i_pre = emd.topk_emd(repo, jnp.asarray(q),
                                jnp.ones(len(q), bool), 3, theta=4,
                                prefilter=8)
    assert int(i_full[0]) == int(i_pre[0]) == 3  # self-match survives filter
    np.testing.assert_allclose(np.asarray(v_full)[0], np.asarray(v_pre)[0],
                               atol=1e-5)


def test_sinkhorn_emd_basic_properties():
    rng = np.random.default_rng(2)
    n = 64
    cost = np.abs(np.arange(n)[:, None] - np.arange(n)[None, :]) / n
    a = np.zeros(n, np.float32); a[10] = 1.0
    b = np.zeros(n, np.float32); b[20] = 1.0
    d = float(emd.sinkhorn_emd(jnp.asarray(a), jnp.asarray(b),
                               jnp.asarray(cost), reg=0.01, iters=200))
    # point masses 10 cells apart on a line: EMD = 10/n
    assert abs(d - 10 / n) < 0.02
    d0 = float(emd.sinkhorn_emd(jnp.asarray(a), jnp.asarray(a),
                                jnp.asarray(cost), reg=0.01, iters=200))
    assert d0 < 0.01
