"""LiveRepository: online mutations under the bit-identity contract.

The correctness bar (tentpole): after ANY mutation sequence, the resident
repository — and every op's results — must be bit-identical to a COLD
engine built by `repo_mutate.build_frozen` from the equivalent frozen slot
contents.  These tests drive targeted mutation sequences (the random
interleavings live in tests/test_mutation_properties.py) and additionally
pin down:

  * epoch semantics: the data epoch is monotone, bumps exactly once per
    published mutation, and per-slot epochs move only for touched slots;
  * result-cache versioning: a query cached at epoch N is NEVER served
    after a `replace()` of a dataset it touched (booked as a result-cache
    MISS + `epoch_invalidations`, not a silent eviction), while per-slot
    point-op entries SURVIVE mutations of other datasets;
  * the `cache_hits + cache_misses == dispatches` invariant across
    mutation-heavy sequences;
  * placement accounting: single-dataset mutations upload only that
    dataset's padded payload (never the repository), deletes and tier
    growth upload NOTHING;
  * the bucket-ladder slot tier: growth doubles capacity, bumps the
    dispatcher LAYOUT epoch (executable retirement), and preserves
    bit-identity; capacity/validation errors raise before any state
    changes;
  * per-device residency bounds on the 3-shard and 2x4 replica meshes
    (`check_live_*` bodies run via `dispatch_device_check`, so the
    single-device tier-1 session still exercises them in subprocesses).
"""
import jax
import numpy as np
import pytest

from conftest import dispatch_device_check
from repro.core import repo_mutate
from repro.engine import LiveRepository, Query, QueryEngine

# -- helpers ----------------------------------------------------------------


def make_datasets(n, seed=0, n_points=30, d=2, spread=3.0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        c = rng.uniform(-40, 40, size=d)
        out.append((c + rng.normal(0, spread, size=(n_points, d)))
                   .astype(np.float32))
    return out


WHOLE_LO = np.float32([-60, -60])
WHOLE_HI = np.float32([60, 60])


def mixed_queries(live_ids, qpts):
    """One query per op family — a mixed batch touching dataset- and
    point-granularity paths in a single search() call."""
    ids = sorted(live_ids)
    return [
        Query(op="range_search", r_lo=WHOLE_LO, r_hi=WHOLE_HI),
        Query(op="topk_ia", r_lo=np.float32([-20, -20]),
              r_hi=np.float32([30, 30]), k=4),
        Query(op="topk_hausdorff_approx", q=qpts, k=3, eps=0.05),
        Query(op="topk_hausdorff", q=qpts, k=3),
        Query(op="range_points", ds_id=ids[0], r_lo=WHOLE_LO, r_hi=WHOLE_HI),
        Query(op="nnp", ds_id=ids[-1], q=qpts),
    ]


def assert_results_equal(got, want):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert a.op == b.op
        for name in ("vals", "ids", "mask"):
            x, y = getattr(a, name), getattr(b, name)
            assert (x is None) == (y is None), (a.op, name)
            if x is None:
                continue
            x, y = np.asarray(x), np.asarray(y)
            en = bool(np.issubdtype(x.dtype, np.floating))
            assert np.array_equal(x, y, equal_nan=en), (a.op, name)


def assert_repo_equal(live_repo, frozen, *, n_slots):
    """Bitwise pytree equality over the logical slot region + the full
    upper tree (live slot arrays may carry extra shard-alignment padding
    rows; they are zero and outside the logical region)."""
    la, lb = jax.tree.leaves(live_repo), jax.tree.leaves(frozen)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if x.shape != y.shape:
            assert x.shape[1:] == y.shape[1:]
            m = min(x.shape[0], y.shape[0])
            assert m >= n_slots
            x, y = x[:m], y[:m]
        en = bool(np.issubdtype(x.dtype, np.floating))
        assert np.array_equal(x, y, equal_nan=en)


def check_bit_identity(live, *, mesh=None, leaf_capacity=8):
    """The tentpole assertion: resident pytree == frozen oracle AND a
    mixed op batch == the same batch on a cold engine over the oracle."""
    frozen = live.frozen_repository()
    assert_repo_equal(live.repo, frozen, n_slots=live.n_slots)
    cold = QueryEngine(frozen, leaf_capacity=leaf_capacity, mesh=mesh)
    qpts = make_datasets(1, seed=99, n_points=12)[0]
    qs = mixed_queries(live.live_ids, qpts)
    assert_results_equal(live.search(qs), cold.search(qs))


# -- bit-identity under targeted sequences (local dispatch) -----------------


def test_init_matches_frozen_oracle():
    ds = make_datasets(6, seed=3)
    live = LiveRepository(ds, leaf_capacity=8)
    frozen = repo_mutate.build_frozen(
        list(ds) + [None] * (live.n_slots - len(ds)), live.geometry)
    assert_repo_equal(live.repo, frozen, n_slots=live.n_slots)


def test_mutation_sequence_bit_identical():
    ds = make_datasets(7, seed=1)
    live = LiveRepository(ds, leaf_capacity=8, result_cache_size=32)
    extra = make_datasets(4, seed=7)

    sid = live.ingest(extra[0])
    assert sid == 7
    check_bit_identity(live)

    live.delete(2)
    check_bit_identity(live)

    live.replace(4, extra[1])
    check_bit_identity(live)

    # re-ingest lands in the freed slot (smallest-slot free list)
    assert live.ingest(extra[2]) == 2
    check_bit_identity(live)

    # growth-triggering ingest: free list empty at 8 slots
    assert live.n_slots == 8
    live.ingest(extra[3])
    assert live.n_slots == 16
    check_bit_identity(live)


def test_epoch_monotone_and_per_slot():
    ds = make_datasets(5, seed=2)
    live = LiveRepository(ds, leaf_capacity=8)
    assert live.epoch == 0 and live.engine.repo_epoch == 0

    seen = [live.epoch]
    sid = live.ingest(make_datasets(1, seed=11)[0])
    seen.append(live.epoch)
    live.replace(sid, make_datasets(1, seed=12)[0])
    seen.append(live.epoch)
    live.delete(sid)
    seen.append(live.epoch)
    assert seen == [0, 1, 2, 3]          # exactly one bump per mutation
    assert live.engine.repo_epoch == 3

    # only the touched slot's epoch moved
    assert live.slot_epochs[sid] == 3
    assert all(live.slot_epochs[j] == 0 for j in range(live.n_slots)
               if j != sid)

    # installing an older epoch is refused
    with pytest.raises(ValueError):
        live.engine.set_repo_epoch(1)


# -- result-cache versioning (satellite: cache epochs) ----------------------


def test_replace_invalidates_cached_dataset_result():
    ds = make_datasets(6, seed=5, spread=1.0)
    live = LiveRepository(ds, leaf_capacity=8, result_cache_size=16)
    q = [Query(op="range_search", r_lo=WHOLE_LO, r_hi=WHOLE_HI)]

    first = live.search(q)
    assert live.stats.result_cache_misses == 1
    again = live.search(q)
    assert live.stats.result_cache_hits == 1          # served from cache
    assert_results_equal(first, again)

    # move dataset 3 far outside the old box: the cached row MUST retire
    far = (make_datasets(1, seed=21)[0] + np.float32([500, 500]))
    live.replace(3, far)
    assert live.stats.epoch_invalidations >= 1
    after = live.search(q)
    assert live.stats.result_cache_misses == 2        # booked as a MISS
    assert live.stats.result_cache_hits == 1          # NOT served stale
    mask_before = np.asarray(first[0].mask)
    mask_after = np.asarray(after[0].mask)
    assert mask_before[3] and not mask_after[3]       # value really moved

    # and the fresh result is the frozen oracle's
    cold = QueryEngine(live.frozen_repository(), leaf_capacity=8)
    assert_results_equal(after, cold.search(q))


def test_point_op_cache_survives_unrelated_mutations():
    ds = make_datasets(6, seed=6)
    live = LiveRepository(ds, leaf_capacity=8, result_cache_size=16)
    qpts = make_datasets(1, seed=33, n_points=10)[0]
    q = [Query(op="nnp", ds_id=2, q=qpts),
         Query(op="range_points", ds_id=2, r_lo=WHOLE_LO, r_hi=WHOLE_HI)]

    live.search(q)
    base_misses = live.stats.result_cache_misses
    live.search(q)
    assert live.stats.result_cache_hits == 2

    # mutate OTHER datasets: per-slot entries for ds 2 must survive
    live.replace(4, make_datasets(1, seed=34)[0])
    live.delete(0)
    live.search(q)
    assert live.stats.result_cache_hits == 4
    assert live.stats.result_cache_misses == base_misses

    # mutate ds 2 itself: both entries retire, refreshed results match
    # the oracle
    live.replace(2, make_datasets(1, seed=35)[0])
    fresh = live.search(q)
    assert live.stats.result_cache_misses == base_misses + 2
    cold = QueryEngine(live.frozen_repository(), leaf_capacity=8,
                       result_cache_size=16)
    assert_results_equal(fresh, cold.search(q))


def test_cache_counter_invariant_across_mutations():
    ds = make_datasets(6, seed=8)
    live = LiveRepository(ds, leaf_capacity=8, result_cache_size=16)
    qpts = make_datasets(1, seed=44, n_points=10)[0]
    rng = np.random.default_rng(9)
    for step in range(6):
        live.search(mixed_queries(live.live_ids, qpts))
        kind = step % 3
        if kind == 0:
            live.ingest(make_datasets(1, seed=100 + step)[0])
        elif kind == 1:
            live.replace(int(rng.choice(sorted(live.live_ids))),
                         make_datasets(1, seed=200 + step)[0])
        else:
            live.delete(int(rng.choice(sorted(live.live_ids))))
        s = live.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
    assert live.stats.epoch_invalidations > 0


# -- placement accounting (no full re-upload) -------------------------------


def test_mutations_upload_only_the_touched_payload():
    ds = make_datasets(6, seed=4)
    live = LiveRepository(ds, leaf_capacity=8)
    geom = live.geometry
    per_payload = geom.point_capacity * (4 * geom.dim + 1)
    # repository slot bodies dwarf one payload: re-uploading would show
    repo_bytes = sum(np.asarray(x).nbytes
                     for x in jax.tree.leaves(live.repo.ds_index))
    assert repo_bytes > 4 * per_payload

    assert live.bytes_uploaded == 0
    live.ingest(make_datasets(1, seed=50)[0])
    assert live.bytes_uploaded == per_payload
    live.replace(1, make_datasets(1, seed=51)[0])
    assert live.bytes_uploaded == 2 * per_payload
    live.delete(3)                       # uploads nothing
    assert live.bytes_uploaded == 2 * per_payload

    # fill to force growth: the growth itself uploads nothing beyond the
    # triggering ingest's payload
    n_ingests = 2
    while live.n_slots == 8:
        live.ingest(make_datasets(1, seed=60 + n_ingests)[0])
        n_ingests += 1
    assert live.bytes_uploaded == n_ingests * per_payload


# -- the slot tier (bucket ladder) ------------------------------------------


def test_tier_growth_doubles_and_bumps_layout_epoch():
    ds = make_datasets(4, seed=10)
    live = LiveRepository(ds, leaf_capacity=8)
    n0 = live.n_slots
    assert getattr(live.engine.dispatch, "repo_epoch", 0) == 0

    live.search([Query(op="range_search", r_lo=WHOLE_LO, r_hi=WHOLE_HI)])

    i = 0
    while live.n_slots == n0:            # fill the tier, then one more
        live.ingest(make_datasets(1, seed=70 + i)[0])
        i += 1
    assert live.n_slots == 2 * n0        # the ladder doubles
    assert live.engine.dispatch.repo_epoch == 1
    # post-growth queries still match a cold engine (executables built
    # against the old slot count were retired by the layout epoch)
    check_bit_identity(live)


def test_validation_errors_leave_state_untouched():
    ds = make_datasets(3, seed=12)
    live = LiveRepository(ds, leaf_capacity=8)
    epoch = live.epoch

    with pytest.raises(ValueError):
        live.ingest(np.zeros((0, 2), np.float32))       # empty
    with pytest.raises(ValueError):
        live.ingest(np.zeros((5, 3), np.float32))       # wrong dim
    cap = live.geometry.point_capacity
    with pytest.raises(ValueError):
        live.ingest(np.zeros((cap + 1, 2), np.float32))  # oversize
    with pytest.raises(KeyError):
        live.delete(2 ** 20)                            # never existed
    live.delete(1)
    with pytest.raises(KeyError):
        live.delete(1)                                  # already gone
    with pytest.raises(KeyError):
        live.replace(1, ds[0])                          # not live

    assert live.epoch == epoch + 1                      # only the delete
    assert live.live_ids == {0, 2}
    check_bit_identity(live)


def test_point_capacity_headroom_admits_larger_ingests():
    ds = make_datasets(3, seed=13, n_points=20)
    live = LiveRepository(ds, leaf_capacity=8, point_capacity=128)
    big = make_datasets(1, seed=14, n_points=100)[0]
    live.ingest(big)
    check_bit_identity(live)


def test_failed_prepare_returns_reserved_slot(monkeypatch):
    """A prepare that fails AFTER reserving a slot (poisoned payload
    blowing up mid-row-build) must put the slot back: the free list is
    never half-reserved, no bytes are booked, and the next ingest reuses
    the same slot."""
    ds = make_datasets(3, seed=15)
    live = LiveRepository(ds, leaf_capacity=8)
    free0 = sorted(live._free)
    bytes0 = live.bytes_uploaded
    epoch0 = live.epoch

    def poisoned(points, geom):
        raise RuntimeError("poisoned payload")

    monkeypatch.setattr(repo_mutate, "build_row", poisoned)
    with pytest.raises(RuntimeError):
        live.ingest(ds[0])
    group = live.prepare_group([("ingest", None, ds[0])])
    assert isinstance(group.items[0].error, RuntimeError)
    monkeypatch.undo()

    # nothing half-reserved, nothing published, nothing booked
    assert sorted(live._free) == free0
    assert live.bytes_uploaded == bytes0
    assert live.epoch == epoch0
    # the next ingest reuses the slot the failed prepares gave back
    sid = live.ingest(make_datasets(1, seed=16)[0])
    assert sid == free0[0]
    check_bit_identity(live)


def test_abort_group_returns_all_reservations():
    """abort_group on a prepared-but-unpublished group frees EVERY
    ingest reservation (subsequent ingests reuse the slots, smallest
    first) and the group can never publish afterwards."""
    ds = make_datasets(3, seed=17)
    live = LiveRepository(ds, leaf_capacity=8)
    free0 = sorted(live._free)
    epoch0 = live.epoch
    extra = make_datasets(3, seed=18)

    group = live.prepare_group([("ingest", None, extra[0]),
                                ("ingest", None, extra[1]),
                                ("replace", 0, extra[2])])
    assert [p.slot for p in group.items[:2]] == free0[:2]
    live.abort_group(group)
    with pytest.raises(RuntimeError):
        live.publish_group(group)
    with pytest.raises(RuntimeError):
        live.abort_group(group)

    assert sorted(live._free) == free0
    assert live.epoch == epoch0              # nothing published
    assert live.live_ids == {0, 1, 2}
    a = live.ingest(extra[0])
    b = live.ingest(extra[1])
    assert [a, b] == free0[:2]               # reservations were reusable
    check_bit_identity(live)


# -- mesh dispatchers (subprocess-or-inprocess via conftest) ----------------


def _check_live_on_mesh(mesh, n_devices):
    from repro.engine import repo_device_bytes
    ds = make_datasets(7, seed=1)
    live = LiveRepository(ds, mesh=mesh, leaf_capacity=8,
                          result_cache_size=16)
    extra = make_datasets(4, seed=7)
    live.ingest(extra[0])
    live.delete(2)
    live.replace(4, extra[1])
    check_bit_identity(live, mesh=mesh)

    # per-device residency: slot bodies stay sharded after mutations —
    # no device holds everything (the replicated upper tree + space
    # bounds are tiny)
    dev = repo_device_bytes(live.repo)
    assert len(dev) == n_devices
    total = sum(dev.values())
    body = sum(np.asarray(x).nbytes
               for x in jax.tree.leaves(live.repo.ds_index))
    n_sh = int(live.engine.dispatch.n_shards)
    assert max(dev.values()) <= (total - body) + body // n_sh + body // 8

    # growth on the mesh: shard-aligned, still bit-identical
    while live.n_slots == 8:
        live.ingest(make_datasets(1, seed=80 + live.mutations)[0])
    assert live.n_slots == 16
    check_bit_identity(live, mesh=mesh)

    s = live.stats
    assert s.cache_hits + s.cache_misses == s.dispatches


def check_live_sharded():
    from repro.engine import data_mesh
    _check_live_on_mesh(data_mesh(3), 3)


def check_live_replicated():
    from repro.engine import replica_mesh
    _check_live_on_mesh(replica_mesh(2, 4), 8)


def test_live_sharded_bit_identity():
    dispatch_device_check("test_live_repository", "check_live_sharded",
                          devices=3)


def test_live_replicated_bit_identity():
    dispatch_device_check("test_live_repository", "check_live_replicated",
                          devices=8)
