"""Autotuned dispatch-constant semantics (`repro.kernels.autotune` +
`repro.engine.tune`): untuned defaults reproduce the seed constants,
explicit arguments beat table entries beat defaults, env vars force
routing, measured sweeps install the fastest bitwise-safe candidate, and
the epoch bump retires engine executables built under stale constants.
"""
import numpy as np
import pytest

from conftest import make_clustered_datasets
from repro.core.build import build_repository
from repro.engine import QueryEngine
from repro.kernels import autotune
from repro.kernels.autotune import KernelConfig

THETA = 5


@pytest.fixture(autouse=True)
def _clean_table(monkeypatch):
    """Each test sees an untuned table and no forcing env."""
    monkeypatch.delenv("REPRO_FORCE_KERNEL", raising=False)
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    autotune.clear()
    yield
    autotune.clear()


def test_defaults_reproduce_seed_constants():
    """An untuned process must route exactly like the seed's hard-coded
    thresholds: kernel at (256, 512)+ streaming shapes, ref below."""
    cfg = autotune.resolve("directed_hausdorff", (256, 512))
    assert (cfg.use_kernel, cfg.tq, cfg.td) == (True, 256, 512)
    assert not autotune.resolve("directed_hausdorff", (255, 512)).use_kernel
    assert not autotune.resolve("directed_hausdorff", (256, 511)).use_kernel
    assert not autotune.resolve("nn_distance", (100, 100)).use_kernel
    grid = autotune.resolve("hausdorff_grid", (24, 100))
    assert not grid.use_kernel and grid.tile == 128
    bm = autotune.resolve("bound_matrices", (256, 256))
    assert bm.use_kernel and (bm.tq, bm.td) == (256, 256)
    # fused bound grid: conservative default keeps the jnp oracle at the
    # engine's usual batch buckets
    bg = autotune.resolve("bound_grid", (8, 128))
    assert not bg.use_kernel and (bg.tq, bg.td) == (8, 128)
    assert autotune.resolve("bound_grid", (256, 256)).use_kernel


def test_explicit_args_beat_table_beat_defaults():
    shape = (64, 64)
    assert not autotune.resolve("directed_hausdorff", shape).use_kernel
    autotune.set_config("directed_hausdorff", shape,
                        KernelConfig(True, 32, 32, min_q=1, min_d=1))
    cfg = autotune.resolve("directed_hausdorff", shape)
    assert cfg.use_kernel and (cfg.tq, cfg.td) == (32, 32)
    # explicit tile arguments double as thresholds (seed keyword
    # semantics): tq=128 > 64 rows pushes the call back to ref
    assert not autotune.resolve("directed_hausdorff", shape, tq=128).use_kernel
    # explicit use_kernel overrides table, defaults, and size rules
    assert autotune.resolve("directed_hausdorff", (2, 2),
                            use_kernel=True).use_kernel
    assert not autotune.resolve("directed_hausdorff", (1024, 1024),
                                use_kernel=False).use_kernel


def test_bucketing_shares_entries():
    autotune.set_config("nn_distance", (300, 600), KernelConfig(False))
    # (300, 600) buckets to (512, 1024): every shape in that bucket hits
    # the tuned entry, other buckets stay on defaults
    assert not autotune.resolve("nn_distance", (511, 1024)).use_kernel
    assert autotune.resolve("nn_distance", (256, 512)).use_kernel


def test_epoch_bumps_on_table_changes():
    e0 = autotune.epoch()
    autotune.set_config("directed_hausdorff", (64, 64), KernelConfig(False))
    assert autotune.epoch() == e0 + 1
    autotune.clear()
    assert autotune.epoch() == e0 + 2


def test_env_forcing(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_KERNEL", "1")
    assert autotune.resolve("directed_hausdorff", (4, 4)).use_kernel
    monkeypatch.delenv("REPRO_FORCE_KERNEL")
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    assert not autotune.resolve("directed_hausdorff",
                                (1024, 1024)).use_kernel
    # explicit per-call arguments still beat the environment
    assert autotune.resolve("directed_hausdorff", (1024, 1024),
                            use_kernel=True).use_kernel


def test_ensure_tuned_picks_fastest_and_caches():
    cands = [KernelConfig(False), KernelConfig(True, 8, 8, min_q=1, min_d=1)]
    clock = [0.0]
    runs = []

    def runner(cfg):
        runs.append(cfg)
        clock[0] += 0.1 if cfg.use_kernel else 0.5   # kernel is "faster"

    cfg, info = autotune.ensure_tuned("directed_hausdorff", (64, 64),
                                      runner, cands, repeats=2,
                                      timer=lambda: clock[0])
    assert cfg.use_kernel and info["chosen"] == 1
    assert len(runs) == 2 * len(cands) + len(cands)  # warmup + timed
    # the verdict is installed and resolve() serves it
    assert autotune.resolve("directed_hausdorff", (64, 64)).use_kernel
    # a second sweep short-circuits on the cached entry
    n = len(runs)
    cfg2, info2 = autotune.ensure_tuned("directed_hausdorff", (64, 64),
                                        runner, cands, repeats=2,
                                        timer=lambda: clock[0])
    assert info2 is None and len(runs) == n and cfg2 == cfg


@pytest.fixture(scope="module")
def engine():
    datasets = make_clustered_datasets(9, seed=2, n_points=(10, 30))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    return QueryEngine(repo, result_cache_size=0)


def test_engine_rekeys_executables_on_epoch_bump(engine):
    """A tuner update must retire every cached executable (their routing
    constants are stale): the same query misses the executable cache once
    after set_config, then caches again — and returns identical results."""
    rng = np.random.default_rng(5)
    lo = rng.uniform(-60, 40, (2, 2)).astype(np.float32)
    hi = lo + 10.0
    want = [np.asarray(r) for r in engine.range_search(lo, hi)]
    misses0 = engine.stats.cache_misses
    engine.range_search(lo, hi)
    assert engine.stats.cache_misses == misses0      # warm: pure hits
    autotune.set_config("directed_hausdorff", (64, 64), KernelConfig(False))
    got = [np.asarray(r) for r in engine.range_search(lo, hi)]
    assert engine.stats.cache_misses == misses0 + 1  # re-keyed once
    engine.range_search(lo, hi)
    assert engine.stats.cache_misses == misses0 + 1  # cached again
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_engine_tune_gates_and_installs(engine):
    """engine.tune() runs the measured sweeps, installs verdicts for its
    probe buckets (epoch bump), picks a default chunk from the candidate
    list, and leaves results bit-identical to the untuned engine."""
    rng = np.random.default_rng(7)
    lo = rng.uniform(-60, 40, (2, 2)).astype(np.float32)
    hi = lo + 10.0
    want = [np.asarray(r) for r in engine.range_search(lo, hi)]
    e0 = autotune.epoch()
    report = engine.tune(batches=(2,), chunks=(16, 32), chunk_batch=2,
                         repeats=1)
    assert autotune.epoch() > e0
    assert engine.default_chunk in (16, 32)
    assert report["chunk"]["chosen"] in (16, 32)
    # every sweep row carries its gate accounting and a winner
    rows = [report["directed_hausdorff"], report["hausdorff_grid"],
            *report["bound_grid"].values()]
    for row in rows:
        assert row["candidates_rejected_bitwise"] >= 0
        if not row["cached"]:
            assert len(row["timings_s"]) >= 1
    got = [np.asarray(r) for r in engine.range_search(lo, hi)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
