"""ShardedQueryEngine vs QueryEngine: bit-identical equivalence.

Every op of the sharded engine must reproduce the unsharded engine
bit-for-bit (values AND ids AND masks — np.testing.assert_array_equal, no
tolerance) on forced 8-device host meshes, covering

  * all seven serving ops, including the genuinely sharded ExactHaus
    (per-shard phase-2 loops + tau all-reduce) checked against the host
    oracle `topk_hausdorff_host` — values and ids bit-identical, bound
    counters equal, `evaluated <= candidates_after_bounds` — both
    per-query AND as a (B, ...) batch in one dispatch (the shared
    per-shard phase-2 work frontier, across query-bucket and slot
    padding),
  * duplicate-LB / duplicate-value ties at the top-k boundary (cloned
    datasets) under 8- and 3-shard schedules,
  * uneven shard remainders (num_datasets not divisible by the shard
    count, AND a 3-shard mesh whose slot padding is exercised:
    64 slots -> 66),
  * the shape-bucket padding interaction (batch sizes below, at, and
    above a bucket boundary),
  * top-k overrun past the valid dataset count (`-1` sentinel ids),
  * the no-replicated-repository regression: per-device resident bytes
    of the dataset-axis arrays are total/N.

When the session already has >= 8 devices (the multi-device CI job sets
``REPRO_HOST_DEVICES=8``, applied by conftest before jax's first import)
the checks run in-process; otherwise each test re-runs its body in a
subprocess with XLA_FLAGS forcing 8 host devices (same pattern as
tests/test_distributed.py).
"""
import numpy as np

from conftest import dispatch_device_check, make_clustered_datasets

THETA = 5
K = 6


def _dispatch(fn_name: str):
    """Run `fn_name` in-process when the session has >= 8 devices, else in
    a forced-8-device subprocess (shared conftest harness)."""
    dispatch_device_check("test_engine_sharded", fn_name)


def _build(n_datasets: int, seed: int = 2):
    import jax.numpy as jnp
    from repro.core import zorder
    from repro.core.build import build_repository
    from repro.engine import QueryEngine

    datasets = make_clustered_datasets(n_datasets, seed=seed,
                                       n_points=(30, 120))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    eng = QueryEngine(repo)
    q_sets = [datasets[i % n_datasets] for i in (0, 3, 9, 11, 20)]
    sigs = np.stack([
        np.asarray(zorder.signature(jnp.asarray(q),
                                    jnp.ones(len(q), bool),
                                    repo.space_lo, repo.space_hi, THETA))
        for q in q_sets
    ])
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, THETA))
    return datasets, repo, eng, q_sets, sigs, eps


def _assert_all_ops_equal(eng, sng, repo, q_batch, sigs, eps, lo, hi,
                          ds_ids, ks):
    eq = np.testing.assert_array_equal
    eq(np.asarray(sng.range_search(lo, hi)),
       np.asarray(eng.range_search(lo, hi)))
    for k in ks:
        v1, i1 = eng.topk_ia(lo, hi, k)
        v2, i2 = sng.topk_ia(lo, hi, k)
        eq(np.asarray(v2), np.asarray(v1))
        eq(np.asarray(i2), np.asarray(i1))
        v1, i1 = eng.topk_gbo(sigs, k)
        v2, i2 = sng.topk_gbo(sigs, k)
        eq(np.asarray(v2), np.asarray(v1))
        eq(np.asarray(i2), np.asarray(i1))
        v1, i1, e1 = eng.topk_hausdorff_approx(q_batch, k, eps)
        v2, i2, e2 = sng.topk_hausdorff_approx(q_batch, k, eps)
        eq(np.asarray(v2), np.asarray(v1))
        eq(np.asarray(i2), np.asarray(i1))
        eq(np.asarray(e2), np.asarray(e1))
    eq(np.asarray(sng.range_points(ds_ids, lo, hi)),
       np.asarray(eng.range_points(ds_ids, lo, hi)))
    d1, x1 = eng.nnp(ds_ids, q_batch)
    d2, x2 = sng.nnp(ds_ids, q_batch)
    eq(np.asarray(d2), np.asarray(d1))
    eq(np.asarray(x2), np.asarray(x1))


def check_sharded_equivalence_8dev():
    """All ops, 8 even shards, ragged batch (bucket padding), k overrun."""
    import jax
    from repro.engine import ShardedQueryEngine
    from repro.engine.sharded import data_mesh

    datasets, repo, eng, q_sets, sigs, eps = _build(33)
    mesh = data_mesh(8)
    sng = ShardedQueryEngine(repo, mesh=mesh)
    assert sng.dispatch.n_shards == 8
    assert sng.dispatch.n_slots_sharded == repo.n_slots  # 64: even split

    rng = np.random.default_rng(0)
    B = len(q_sets)                       # 5 -> bucket 8: padding exercised
    assert eng.bucket_for(B) > B
    lo = rng.uniform(-60, 40, (B, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (B, 2)).astype(np.float32)
    ds_ids = np.array([1, 4, 7, 2, 9], np.int32)
    q_batch = eng.build_queries(q_sets)
    # k = K (normal), k crossing the per-shard slot count (8), and k at the
    # full slot count (> n_valid: the -1 sentinel rows must merge identically)
    _assert_all_ops_equal(eng, sng, repo, q_batch, sigs, eps, lo, hi,
                          ds_ids, ks=(K, 33, repo.n_slots))
    v, j = sng.topk_ia(lo, hi, repo.n_slots)
    v, j = np.asarray(v), np.asarray(j)
    assert (j[v < 0] == -1).all() and (v < 0).any()

    # ExactHaus, genuinely sharded: per-shard phase-2 loops with the tau
    # all-reduce must match the unsharded engine AND the host oracle
    # bit-for-bit (values and ids), including k past the valid count;
    # only `evaluated` is schedule-dependent (asserted bounded, not equal)
    from repro.core import search
    for qi_ix in (0, 1):
        qi = jax.tree.map(lambda x, i=qi_ix: x[i], q_batch)
        for k in (K, 33, repo.n_slots):
            vh, ih, sh = search.topk_hausdorff_host(repo, qi, k)
            v1, i1, s1 = eng.topk_hausdorff(qi, k)
            v2, i2, s2 = sng.topk_hausdorff(qi, k)
            np.testing.assert_array_equal(np.asarray(v1), np.asarray(vh))
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(ih))
            np.testing.assert_array_equal(np.asarray(v2), np.asarray(vh))
            np.testing.assert_array_equal(np.asarray(i2), np.asarray(ih))
            # bound phases are slot-deterministic: counters match exactly
            assert s2.nodes_evaluated == sh.nodes_evaluated
            assert s2.candidates_after_bounds == sh.candidates_after_bounds
            assert 0 < s2.exact_evaluations <= s2.candidates_after_bounds

    # BATCHED ExactHaus on the sharded engine: the whole ragged batch in
    # ONE dispatch (shared per-shard phase-2 frontier, batched tau
    # all-reduce) — every row bit-identical to its solo host-oracle run
    for k in (K, repo.n_slots):
        vb, ib, sb = sng.topk_hausdorff(q_batch, k)
        assert vb.shape[0] == B and len(sb) == B
        for i in range(B):
            qi = jax.tree.map(lambda x, i=i: x[i], q_batch)
            vh, ih, sh = search.topk_hausdorff_host(repo, qi, k)
            np.testing.assert_array_equal(np.asarray(vb[i]), np.asarray(vh))
            np.testing.assert_array_equal(np.asarray(ib[i]), np.asarray(ih))
            assert sb[i].nodes_evaluated == sh.nodes_evaluated
            assert (sb[i].candidates_after_bounds
                    == sh.candidates_after_bounds)
            assert sb[i].exact_evaluations <= sb[i].candidates_after_bounds

    # shared stats plumbing: every sharded dispatch books a hit or a miss
    s = sng.stats
    assert s.cache_hits + s.cache_misses == s.dispatches
    print("SHARDED_8DEV_OK")


def check_sharded_uneven_shards():
    """3-shard mesh over 64 slots: the slot-padding path (64 -> 66) and
    num_datasets not divisible by the shard count, at several buckets."""
    from repro.engine import ShardedQueryEngine
    from repro.engine.sharded import data_mesh

    datasets, repo, eng, q_sets, sigs, eps = _build(33)
    sng = ShardedQueryEngine(repo, mesh=data_mesh(3))
    assert sng.dispatch.n_slots_sharded == 66       # padded: 64 % 3 != 0
    assert sng.dispatch.shard_slots == 22

    rng = np.random.default_rng(1)
    q_batch = eng.build_queries(q_sets)
    for B in (1, 5, 12):                 # below/at/above bucket boundaries
        lo = rng.uniform(-60, 40, (B, 2)).astype(np.float32)
        hi = lo + rng.uniform(5, 40, (B, 2)).astype(np.float32)
        np.testing.assert_array_equal(
            np.asarray(sng.range_search(lo, hi)),
            np.asarray(eng.range_search(lo, hi)))
        for k in (K, repo.n_slots):      # k > shard_slots crosses shards
            v1, i1 = eng.topk_ia(lo, hi, k)
            v2, i2 = sng.topk_ia(lo, hi, k)
            np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))
            np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))
        ds_ids = rng.integers(0, 33, B).astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(sng.range_points(ds_ids, lo, hi)),
            np.asarray(eng.range_points(ds_ids, lo, hi)))
    lo = rng.uniform(-60, 40, (5, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (5, 2)).astype(np.float32)
    _assert_all_ops_equal(eng, sng, repo, q_batch, sigs, eps, lo, hi,
                          np.arange(5, dtype=np.int32), ks=(K, 33))

    # sharded ExactHaus across the 64 -> 66 slot padding: the pad slots
    # must neither surface in the top-k nor perturb the stats counters
    import jax
    from repro.core import search
    qi = jax.tree.map(lambda x: x[2], q_batch)
    for k in (K, repo.n_slots):
        vh, ih, sh = search.topk_hausdorff_host(repo, qi, k)
        v2, i2, s2 = sng.topk_hausdorff(qi, k)
        np.testing.assert_array_equal(np.asarray(v2), np.asarray(vh))
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(ih))
        assert s2.nodes_evaluated == sh.nodes_evaluated
        assert s2.candidates_after_bounds == sh.candidates_after_bounds
        assert s2.exact_evaluations <= s2.candidates_after_bounds
    # batched ExactHaus across the same slot padding AND the query-bucket
    # padding (5 queries -> bucket 8): rows bit-identical to solo host runs
    vb, ib, sb = sng.topk_hausdorff(q_batch, K)
    for i in range(len(q_sets)):
        qi = jax.tree.map(lambda x, i=i: x[i], q_batch)
        vh, ih, sh = search.topk_hausdorff_host(repo, qi, K)
        np.testing.assert_array_equal(np.asarray(vb[i]), np.asarray(vh))
        np.testing.assert_array_equal(np.asarray(ib[i]), np.asarray(ih))
        assert sb[i].candidates_after_bounds == sh.candidates_after_bounds
    print("SHARDED_UNEVEN_OK")


def check_sharded_exacthaus_ties():
    """Duplicate datasets force duplicate LBs (the Eq. 4 zero-clamp) AND
    duplicate exact Hausdorff values at the top-k boundary; every schedule
    must return the host oracle's ids (ties toward the smallest slot id)."""
    import jax
    from repro.core import search
    from repro.core.build import build_repository
    from repro.engine import QueryEngine, ShardedQueryEngine
    from repro.engine.sharded import data_mesh

    base = make_clustered_datasets(9, seed=7, n_points=(20, 50))
    # interleave exact copies: slots i and i+9 hold identical datasets
    datasets = base + [d.copy() for d in base] + base[:4]
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    eng = QueryEngine(repo)
    q_batch = eng.build_queries([base[0], base[4]])
    for mesh_n in (8, 3):
        sng = ShardedQueryEngine(repo, mesh=data_mesh(mesh_n))
        for qi_ix in (0, 1):
            qi = jax.tree.map(lambda x, i=qi_ix: x[i], q_batch)
            # k = 9 lands the boundary ON a duplicated value; 5 mid-tie
            for k in (5, 9, 18, repo.n_slots):
                vh, ih, sh = search.topk_hausdorff_host(repo, qi, k)
                v2, i2, s2 = sng.topk_hausdorff(qi, k)
                np.testing.assert_array_equal(np.asarray(v2),
                                              np.asarray(vh))
                np.testing.assert_array_equal(np.asarray(i2),
                                              np.asarray(ih))
                assert s2.candidates_after_bounds == \
                    sh.candidates_after_bounds
    print("SHARDED_TIES_OK")


def check_sharded_search_mixed():
    """The declarative `search()` API on the SHARDED engine: one mixed
    batch covering all seven ops plus a pipeline, every row bit-identical
    to the unsharded engine's search() — on the 8-shard even mesh AND the
    uneven 3-shard mesh (slot padding 64 -> 66)."""
    from repro.engine import Pipeline, Query, ShardedQueryEngine
    from repro.engine.sharded import data_mesh

    datasets, repo, eng, q_sets, sigs, eps = _build(33)
    rng = np.random.default_rng(5)
    lo = rng.uniform(-60, 40, (5, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (5, 2)).astype(np.float32)
    batch = [
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=K),
        Query(op="range_search", r_lo=lo[1], r_hi=hi[1]),
        Query(op="nnp", ds_id=4, q=q_sets[1]),
        Query(op="topk_hausdorff", q=q_sets[0], k=K),
        Query(op="topk_gbo", q_sig=sigs[0], k=K),
        Query(op="range_points", ds_id=7, r_lo=lo[3], r_hi=hi[3]),
        Query(op="topk_hausdorff_approx", q=q_sets[2], k=K, eps=eps),
        Pipeline(Query(op="topk_ia", r_lo=lo[4], r_hi=hi[4], k=3),
                 Query(op="range_points", r_lo=lo[3], r_hi=hi[3])),
        Pipeline(Query(op="topk_gbo", q_sig=sigs[1], k=3),
                 Query(op="nnp", q=q_sets[3])),
        # k past the valid count: sentinel winners must merge identically
        Pipeline(Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0],
                       k=repo.n_slots),
                 Query(op="range_points", r_lo=lo[1], r_hi=hi[1])),
    ]
    want = eng.search(batch)
    for mesh_n in (8, 3):
        sng = ShardedQueryEngine(repo, mesh=data_mesh(mesh_n))
        got = sng.search(batch)
        assert len(got) == len(want)
        for a, b in zip(got, want):
            assert a.op == b.op
            for field in ("vals", "ids", "mask"):
                x, y = getattr(a, field), getattr(b, field)
                assert (x is None) == (y is None), (a.op, field)
                if x is not None:
                    np.testing.assert_array_equal(
                        np.asarray(x), np.asarray(y), err_msg=a.op)
            if a.op == "pipeline":
                np.testing.assert_array_equal(
                    np.asarray(a.extras["ds_ids"]),
                    np.asarray(b.extras["ds_ids"]))
        s = sng.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
        assert s.pipeline_stage1 == s.pipeline_stage2 == 3
        # same planner on both dispatchers: identical group compilation
        assert s.plan_groups == eng.stats.plan_groups
        assert s.group_counts == eng.stats.group_counts
    print("SHARDED_SEARCH_OK")


def check_sharded_no_replicated_repo():
    """Regression: ShardedDispatcher must not retain a replicated
    repository copy — per-device bytes of the dataset-axis arrays are
    exactly total/N, and the only full-size arrays on every device are the
    (tiny) upper tree and space bounds."""
    import jax
    from repro.engine import ShardedQueryEngine
    from repro.engine.sharded import data_mesh, repo_device_bytes

    datasets, repo, eng, *_ = _build(33)
    sng = ShardedQueryEngine(repo, mesh=data_mesh(8))
    d = sng.dispatch
    assert not hasattr(d, "repo_host")
    # the engine holds the PLACED repository, not the builder's copy
    assert sng.repo is d.repo

    ds_arrays = (d.repo.ds_index, d.repo.ds_sigs, d.repo.ds_valid)
    ds_total = sum(x.nbytes for x in jax.tree.leaves(ds_arrays))
    per_dev = repo_device_bytes(ds_arrays)
    assert len(per_dev) == 8
    assert max(per_dev.values()) == ds_total // 8     # even 64/8 split

    # full accounting: per-device = 1/N of the dataset arrays + the
    # replicated upper tree/space bounds (which must stay small)
    rep_total = sum(x.nbytes for x in jax.tree.leaves(
        (d.repo.repo, d.repo.space_lo, d.repo.space_hi)))
    full = repo_device_bytes(d.repo)
    assert len(full) == 8
    assert max(full.values()) == ds_total // 8 + rep_total
    assert rep_total < ds_total // 4    # the replicated part is not the repo

    # and the sharded ExactHaus actually runs on that placement
    q_batch = eng.build_queries([datasets[0]])
    qi = jax.tree.map(lambda x: x[0], q_batch)
    vals, ids, stats = sng.topk_hausdorff(qi, K)
    assert stats.exact_evaluations > 0
    print("SHARDED_NO_REPLICA_OK")


def test_sharded_equivalence_8dev():
    _dispatch("check_sharded_equivalence_8dev")


def test_sharded_uneven_shards():
    _dispatch("check_sharded_uneven_shards")


def test_sharded_exacthaus_ties():
    _dispatch("check_sharded_exacthaus_ties")


def test_sharded_no_replicated_repo():
    _dispatch("check_sharded_no_replicated_repo")


def test_sharded_search_mixed():
    _dispatch("check_sharded_search_mixed")
