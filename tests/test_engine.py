"""Engine equivalence: batched single-dispatch ops vs per-query seed ops.

Every QueryEngine op must reproduce a per-query Python loop over the seed
search layer — including ragged batch sizes that exercise the shape-bucket
padding — plus brute-force oracles at point granularity, the device/host
ExactHaus bit-equivalence, the top-k padding sentinel, and the executable
cache behavior.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_clustered_datasets
from repro.core import point_search, search, zorder
from repro.core.build import build_repository
from repro.engine import QueryEngine

# ragged on purpose: 5 queries land in the 8-bucket, exercising padding
N_QUERIES = 5
THETA = 5
K = 6


@pytest.fixture(scope="module")
def env():
    # 33 datasets -> 64 padded slots, so top-k can overrun the valid count.
    # result_cache_size=0: the equivalence tests here repeat identical
    # inputs on purpose and must measure DISPATCH semantics, not the
    # result LRU (covered separately in test_result_cache_*).
    datasets = make_clustered_datasets(33, seed=2, n_points=(30, 120))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    engine = QueryEngine(repo, result_cache_size=0)
    rng = np.random.default_rng(0)
    lo = rng.uniform(-60, 40, (N_QUERIES, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (N_QUERIES, 2)).astype(np.float32)
    q_sets = [datasets[i] for i in (0, 3, 9, 11, 20)]
    q_batch = engine.build_queries(q_sets)
    sigs = np.stack([
        np.asarray(zorder.signature(jnp.asarray(q),
                                    jnp.ones(len(q), bool),
                                    repo.space_lo, repo.space_hi, THETA))
        for q in q_sets
    ])
    return datasets, repo, engine, lo, hi, q_sets, q_batch, sigs


def _q_at(q_batch, i):
    return jax.tree.map(lambda x: x[i], q_batch)


def test_bucketing_is_ragged(env):
    _, _, engine, *_ = env
    # the fixture batch must actually hit bucket padding
    assert engine.bucket_for(N_QUERIES) > N_QUERIES
    assert engine.bucket_for(8) == 8
    assert engine.bucket_for(300) == 512   # beyond the ladder: grows


def test_range_search_batched_matches_loop(env):
    _, repo, engine, lo, hi, *_ = env
    masks = engine.range_search(lo, hi)
    assert masks.shape[0] == N_QUERIES
    for i in range(N_QUERIES):
        want, _ = search.range_search(repo, jnp.asarray(lo[i]),
                                      jnp.asarray(hi[i]))
        np.testing.assert_array_equal(np.asarray(masks[i]),
                                      np.asarray(want))


def test_topk_ia_batched_matches_loop(env):
    _, repo, engine, lo, hi, *_ = env
    vals, ids = engine.topk_ia(lo, hi, K)
    for i in range(N_QUERIES):
        v, j = search.topk_ia(repo, jnp.asarray(lo[i]),
                              jnp.asarray(hi[i]), K)
        np.testing.assert_array_equal(np.asarray(vals[i]), np.asarray(v))
        np.testing.assert_array_equal(np.asarray(ids[i]), np.asarray(j))


def test_topk_gbo_batched_matches_loop(env):
    _, repo, engine, _, _, _, _, sigs = env
    vals, ids = engine.topk_gbo(sigs, K)
    for i in range(N_QUERIES):
        v, j = search.topk_gbo(repo, jnp.asarray(sigs[i]), K)
        np.testing.assert_array_equal(np.asarray(vals[i]), np.asarray(v))
        np.testing.assert_array_equal(np.asarray(ids[i]), np.asarray(j))


def test_topk_hausdorff_approx_batched_matches_loop(env):
    _, repo, engine, _, _, _, q_batch, _ = env
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, THETA))
    vals, ids, eps_eff = engine.topk_hausdorff_approx(q_batch, K, eps)
    for i in range(N_QUERIES):
        v, j, (lq, ld, ee) = search.topk_hausdorff_approx(
            repo, _q_at(q_batch, i), K, eps)
        # ids exactly; values to fp-fusion tolerance (jit vs eager FMA)
        np.testing.assert_array_equal(np.asarray(ids[i]), np.asarray(j))
        np.testing.assert_allclose(np.asarray(vals[i]), np.asarray(v),
                                   rtol=1e-5, atol=1e-5)
        assert abs(float(eps_eff[i]) - ee) < 1e-4


def test_range_points_batched_matches_brute(env):
    datasets, repo, engine, lo, hi, *_ = env
    ds_ids = np.array([1, 4, 7, 2, 9], np.int32)
    take = engine.range_points(ds_ids, lo, hi)
    for i, d in enumerate(ds_ids):
        d_idx = _q_at(repo.ds_index, int(d))
        # seed op
        want, _ = point_search.range_points(
            d_idx, jnp.asarray(lo[i]), jnp.asarray(hi[i]))
        np.testing.assert_array_equal(np.asarray(take[i]),
                                      np.asarray(want))
        # brute-force oracle over the raw padded points
        pts = np.asarray(d_idx.points)
        val = np.asarray(d_idx.valid)
        brute = (pts >= lo[i]).all(1) & (pts <= hi[i]).all(1) & val
        np.testing.assert_array_equal(np.asarray(take[i]), brute)


def test_nnp_batched_matches_brute(env):
    datasets, repo, engine, _, _, q_sets, q_batch, _ = env
    ds_ids = np.array([1, 4, 7, 2, 9], np.int32)
    dists, idxs = engine.nnp(ds_ids, q_batch)
    for i, d in enumerate(ds_ids):
        q_idx = _q_at(q_batch, i)
        d_idx = _q_at(repo.ds_index, int(d))
        # seed pruned op
        wd, wi, _ = point_search.nnp_pruned(q_idx, d_idx)
        np.testing.assert_array_equal(np.asarray(idxs[i]), np.asarray(wi))
        np.testing.assert_allclose(np.asarray(dists[i]), np.asarray(wd),
                                   rtol=1e-5, atol=1e-5)
        # brute-force oracle on the valid points
        qp = np.asarray(q_idx.points)
        qv = np.asarray(q_idx.valid)
        dp = np.asarray(d_idx.points)[np.asarray(d_idx.valid)]
        dd = np.sqrt(((qp[:, None] - dp[None]) ** 2).sum(-1)).min(1)
        got = np.asarray(dists[i])
        np.testing.assert_allclose(got[qv], dd[qv], atol=1e-4)


def test_exact_hausdorff_device_bitwise_matches_host(env):
    """The lax.while_loop phase 2 must reproduce the seed host-chunked
    loop exactly — same evaluation order, threshold, and arithmetic."""
    _, repo, engine, _, _, _, q_batch, _ = env
    for i in range(N_QUERIES):
        q_idx = _q_at(q_batch, i)
        vd, jd, sd = search.topk_hausdorff(repo, q_idx, K)
        vh, jh, sh = search.topk_hausdorff_host(repo, q_idx, K)
        np.testing.assert_array_equal(np.asarray(vd), np.asarray(vh))
        np.testing.assert_array_equal(np.asarray(jd), np.asarray(jh))
        assert sd.exact_evaluations == sh.exact_evaluations
        assert sd.candidates_after_bounds == sh.candidates_after_bounds
        # engine path reuses the same device pipeline AND surfaces the
        # SearchStats instead of discarding them
        ve, je, se = engine.topk_hausdorff(q_idx, K)
        np.testing.assert_array_equal(np.asarray(ve), np.asarray(vd))
        np.testing.assert_array_equal(np.asarray(je), np.asarray(jd))
        assert se == sd


def test_exact_hausdorff_batched_matches_solo(env):
    """A (B, ...) ExactHaus batch costs ONE dispatch and every row is
    bit-identical to its solo run — with the same chunk, each query's
    phase-2 trajectory is its solo loop in lockstep, so even the per-query
    `evaluated` counters match."""
    _, repo, engine, _, _, _, q_batch, _ = env
    d0 = engine.stats.dispatches
    vals, ids, stats = engine.topk_hausdorff(q_batch, K)
    assert engine.stats.dispatches == d0 + 1
    assert vals.shape == (N_QUERIES, K) and len(stats) == N_QUERIES
    for i in range(N_QUERIES):
        q_idx = _q_at(q_batch, i)
        vh, jh, sh = search.topk_hausdorff_host(repo, q_idx, K)
        np.testing.assert_array_equal(np.asarray(vals[i]), np.asarray(vh))
        np.testing.assert_array_equal(np.asarray(ids[i]), np.asarray(jh))
        assert stats[i].exact_evaluations == sh.exact_evaluations
        assert stats[i].candidates_after_bounds == sh.candidates_after_bounds
        assert stats[i].nodes_evaluated == sh.nodes_evaluated
    # a different chunk schedule changes WHICH extras get evaluated but
    # never the returned values/ids (tau soundness, ties included)
    v8, i8, s8 = engine.topk_hausdorff(q_batch, K, chunk=8)
    np.testing.assert_array_equal(np.asarray(v8), np.asarray(vals))
    np.testing.assert_array_equal(np.asarray(i8), np.asarray(ids))
    for i in range(N_QUERIES):
        assert 0 <= s8[i].exact_evaluations <= s8[i].candidates_after_bounds


def test_record_search_batched_aggregation(env):
    """EngineStats.record_search must aggregate per-query SearchStats
    across a batched dispatch: summed counters, mean pruned fraction —
    not assume one query per call."""
    datasets, repo, _, _, _, q_sets, _, _ = env
    engine = QueryEngine(repo, result_cache_size=0)
    q_batch = engine.build_queries(q_sets)
    _, _, stats = engine.topk_hausdorff(q_batch, K)
    per = engine.stats.per_op["topk_hausdorff"]
    assert per["queries"] == N_QUERIES
    assert per["dispatches"] == 1
    assert per["exact_evaluations"] == sum(
        s.exact_evaluations for s in stats)
    assert per["candidates_after_bounds"] == sum(
        s.candidates_after_bounds for s in stats)
    assert per["nodes_evaluated"] == sum(s.nodes_evaluated for s in stats)
    assert per["pruned_fraction"] == pytest.approx(
        sum(s.pruned_fraction for s in stats) / N_QUERIES)
    # a second batch ACCUMULATES counters and refreshes the mean fraction
    _, _, stats2 = engine.topk_hausdorff(q_batch, K + 1)
    per = engine.stats.per_op["topk_hausdorff"]
    assert per["exact_evaluations"] == (
        sum(s.exact_evaluations for s in stats)
        + sum(s.exact_evaluations for s in stats2))
    assert per["pruned_fraction"] == pytest.approx(
        sum(s.pruned_fraction for s in stats2) / N_QUERIES)


def test_result_cache_short_circuits(env):
    """Repeated queries are answered from the result LRU before bucketing:
    no new dispatch, result-cache counters booked (distinct from the
    executable-cache ones), results identical to the fresh dispatch."""
    datasets, repo, ref_engine, lo, hi, q_sets, _, sigs = env
    engine = QueryEngine(repo)            # default: result cache ON
    q_batch = engine.build_queries(q_sets)

    m1 = engine.range_search(lo, hi)
    v1, j1 = engine.topk_ia(lo, hi, K)
    g1, gj1 = engine.topk_gbo(sigs, K)
    a1, aj1, e1 = engine.topk_hausdorff_approx(q_batch, K, 1.0)
    h1, hj1, hs1 = engine.topk_hausdorff(q_batch, K)
    d0 = engine.stats.dispatches
    hits0 = engine.stats.result_cache_hits
    misses0 = engine.stats.result_cache_misses
    assert hits0 == 0 and misses0 == 5 * N_QUERIES
    assert engine.stats.queries == 5 * N_QUERIES

    # identical second pass: zero dispatches, all rows from the cache
    m2 = engine.range_search(lo, hi)
    v2, j2 = engine.topk_ia(lo, hi, K)
    g2, gj2 = engine.topk_gbo(sigs, K)
    a2, aj2, e2 = engine.topk_hausdorff_approx(q_batch, K, 1.0)
    h2, hj2, hs2 = engine.topk_hausdorff(q_batch, K)
    assert engine.stats.dispatches == d0
    assert engine.stats.result_cache_hits == hits0 + 5 * N_QUERIES
    assert engine.stats.result_cache_misses == misses0
    # cache-hit rows are still ANSWERED client queries: stats.queries
    # counts every answered row exactly once (hit or dispatched)
    assert engine.stats.queries == 10 * N_QUERIES
    assert engine.stats.per_op["topk_ia"]["queries"] == 2 * N_QUERIES
    for a, b in ((m1, m2), (v1, v2), (j1, j2), (g1, g2), (gj1, gj2),
                 (a1, a2), (aj1, aj2), (e1, e2), (h1, h2), (hj1, hj2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hs1 == hs2                     # SearchStats memoized alongside

    # results equal the cache-disabled reference engine's
    np.testing.assert_array_equal(np.asarray(v2),
                                  np.asarray(ref_engine.topk_ia(lo, hi, K)[0]))

    # partial hit: one cached row + one new row -> exactly one dispatch of
    # the 1-row miss sub-batch (bucket 1), cached row untouched
    d1 = engine.stats.dispatches
    lo2 = np.stack([lo[0], lo[0] + 101.0])
    hi2 = np.stack([hi[0], hi[0] + 101.0])
    v3, j3 = engine.topk_ia(lo2, hi2, K)
    assert engine.stats.dispatches == d1 + 1
    assert engine.stats.result_cache_hits == hits0 + 5 * N_QUERIES + 1
    np.testing.assert_array_equal(np.asarray(v3[0]), np.asarray(v1[0]))

    # per-op result counters ride along in per_op
    per = engine.stats.per_op["topk_ia"]
    assert per["result_hits"] == N_QUERIES + 1
    assert per["result_misses"] == N_QUERIES + 1
    # the executable-cache invariant is untouched by the result cache
    s = engine.stats
    assert s.cache_hits + s.cache_misses == s.dispatches


def test_result_cache_dedupes_in_batch_duplicates(env):
    """Duplicate queries INSIDE one cold batch dispatch once: the twin
    rows ride the same dispatch (booked as result-cache hits), and every
    answered row is counted in stats.queries exactly once."""
    datasets, repo, _, lo, hi, *_ = env
    engine = QueryEngine(repo)
    lo2 = np.stack([lo[0], lo[0]])
    hi2 = np.stack([hi[0], hi[0]])
    v, j = engine.topk_ia(lo2, hi2, K)
    np.testing.assert_array_equal(np.asarray(v[0]), np.asarray(v[1]))
    np.testing.assert_array_equal(np.asarray(j[0]), np.asarray(j[1]))
    s = engine.stats
    assert s.result_cache_misses == 1      # one distinct row dispatched
    assert s.result_cache_hits == 1        # its twin rode that dispatch
    assert s.queries == 2                  # both rows answered + counted
    assert s.per_op["topk_ia"]["queries"] == 2
    assert s.per_op["topk_ia"]["dispatches"] == 1


def test_result_cache_lru_bound(env):
    """The result cache is a bounded LRU: old entries are evicted and
    re-dispatch on the next request."""
    datasets, repo, _, lo, hi, *_ = env
    engine = QueryEngine(repo, result_cache_size=4)
    rng = np.random.default_rng(3)
    los = rng.uniform(-60, 40, (6, 2)).astype(np.float32)
    his = los + 5.0
    for i in range(6):                     # 6 distinct queries, cache of 4
        engine.topk_ia(los[i][None], his[i][None], K)
    assert len(engine._result_cache) == 4
    d0 = engine.stats.dispatches
    engine.topk_ia(los[0][None], his[0][None], K)   # evicted -> re-dispatch
    assert engine.stats.dispatches == d0 + 1
    engine.topk_ia(los[5][None], his[5][None], K)   # still resident -> hit
    assert engine.stats.dispatches == d0 + 1


def test_exact_hausdorff_matches_brute(env):
    datasets, repo, engine, _, _, q_sets, q_batch, _ = env
    Q = q_sets[1]
    truth = np.array([
        np.sqrt(((Q[:, None] - d[None]) ** 2).sum(-1)).min(1).max()
        for d in datasets
    ])
    vals, ids, stats = search.topk_hausdorff(repo, _q_at(q_batch, 1), K)
    want = set(np.argsort(truth)[:K].tolist())
    assert set(np.asarray(ids).tolist()) == want
    np.testing.assert_allclose(np.sort(np.asarray(vals)),
                               np.sort(truth)[:K], atol=1e-4)
    assert stats.exact_evaluations < len(datasets)  # pruning works


def test_topk_padding_sentinel(env):
    """k beyond the valid datasets must yield -1 ids, not padded slots."""
    datasets, repo, engine, lo, hi, _, _, sigs = env
    n_valid = int(repo.ds_valid.sum())
    k_over = repo.n_slots          # > n_valid by construction
    assert k_over > n_valid
    v, j = search.topk_ia(repo, jnp.asarray(lo[0]), jnp.asarray(hi[0]),
                          k_over)
    v, j = np.asarray(v), np.asarray(j)
    assert (j[v < 0] == -1).all()
    assert (j[n_valid:] == -1).all()
    v, j = search.topk_gbo(repo, jnp.asarray(sigs[0]), k_over)
    v, j = np.asarray(v), np.asarray(j)
    assert (j[v < 0] == -1).all()
    assert (j[n_valid:] == -1).all()
    # batched forms inherit the sentinel
    v, j = engine.topk_ia(lo, hi, k_over)
    assert (np.asarray(j)[np.asarray(v) < 0] == -1).all()
    v, j = engine.topk_gbo(sigs, k_over)
    assert (np.asarray(j)[np.asarray(v) < 0] == -1).all()


def test_range_search_pruned_fraction(env):
    """pruned_fraction must reflect the traversal, not be hard-coded 0."""
    _, repo, _, _, _, _, _, _ = env
    # a far-away box prunes at the root -> high pruned fraction
    far_lo = jnp.asarray(np.array([1e6, 1e6], np.float32))
    far_hi = far_lo + 1.0
    mask, stats = search.range_search(repo, far_lo, far_hi)
    assert int(np.asarray(mask).sum()) == 0
    assert stats.pruned_fraction > 0.5
    # a box covering everything visits every nonempty node (only the
    # empty padded subtrees count as pruned)
    mask, stats = search.range_search(
        repo, jnp.asarray(np.array([-1e6, -1e6], np.float32)),
        jnp.asarray(np.array([1e6, 1e6], np.float32)))
    assert int(np.asarray(mask).sum()) == 33
    assert 0.0 <= stats.pruned_fraction < 0.5


def test_executable_cache_reuse(env):
    _, repo, engine, lo, hi, *_ = env
    misses0 = engine.stats.cache_misses
    hits0 = engine.stats.cache_hits
    engine.topk_ia(lo, hi, 3)          # new (op, bucket, k) -> miss
    assert engine.stats.cache_misses == misses0 + 1
    engine.topk_ia(lo[:2], hi[:2], 3)  # bucket 2: new executable
    assert engine.stats.cache_misses == misses0 + 2
    engine.topk_ia(lo[:1], hi[:1], 3)  # bucket 1: new executable
    engine.topk_ia(lo, hi, 3)          # same bucket+k -> hit
    assert engine.stats.cache_hits == hits0 + 1
    d0 = engine.stats.dispatches
    engine.topk_ia(lo, hi, 3)
    assert engine.stats.dispatches == d0 + 1   # one dispatch per batch


def test_stats_hit_miss_consistent_across_ops(env):
    """Every dispatch path must book exactly one cache hit or miss through
    EngineStats.count — the invariant hits + misses == dispatches holds for
    the engine totals AND for every per-op breakdown."""
    datasets, repo, _, lo, hi, q_sets, _, sigs = env
    # fresh engine, result cache off: this test repeats identical inputs
    # to exercise the EXECUTABLE cache, which the result LRU would mask
    engine = QueryEngine(repo, result_cache_size=0)
    ds_ids = np.array([1, 4, 7, 2, 9], np.int32)
    q_batch = engine.build_queries(q_sets)      # counted: "build_queries"
    for _ in range(2):                   # second pass: all hits
        engine.range_search(lo, hi)
        engine.topk_ia(lo, hi, K)
        engine.topk_gbo(sigs, K)
        engine.topk_hausdorff_approx(q_batch, K, 1.0)
        engine.range_points(ds_ids, lo, hi)
        engine.nnp(ds_ids, q_batch)
    _, _, hstats = engine.topk_hausdorff(_q_at(q_batch, 0), K)
    s = engine.stats
    assert s.cache_hits + s.cache_misses == s.dispatches == 14
    assert s.cache_misses == 8           # 6 ops + build + exact_haus
    assert s.cache_hits == 6             # the second pass
    for op, per in s.per_op.items():
        assert per["hits"] + per["misses"] == per["dispatches"], op
    for op in ("range_search", "topk_ia", "topk_gbo",
               "topk_hausdorff_approx", "range_points", "nnp"):
        core = {key: s.per_op[op][key]
                for key in ("queries", "dispatches", "hits", "misses")}
        assert core == {"queries": 2 * N_QUERIES, "dispatches": 2,
                        "hits": 1, "misses": 1}, op
    # the point ops no longer discard their pruning masks: leaf/pair
    # counters and the pruned fraction ride in per_op
    for op in ("range_points", "nnp"):
        per = s.per_op[op]
        assert 0 <= per["leaves_scanned"] <= per["nodes_evaluated"], op
        assert 0.0 <= per["pruned_fraction"] <= 1.0, op
    assert s.per_op["build_queries"]["dispatches"] == 1
    per_h = s.per_op["topk_hausdorff"]
    assert {k: per_h[k] for k in ("queries", "dispatches", "hits", "misses")
            } == {"queries": 1, "dispatches": 1, "hits": 0, "misses": 1}
    # the ExactHaus dispatch folded its SearchStats into the breakdown:
    # evaluated count and pruned fraction are recorded, not discarded
    assert per_h["exact_evaluations"] == hstats.exact_evaluations > 0
    assert per_h["candidates_after_bounds"] == hstats.candidates_after_bounds
    assert per_h["exact_evaluations"] <= per_h["candidates_after_bounds"]
    assert per_h["pruned_fraction"] == hstats.pruned_fraction
    assert 0.0 <= per_h["pruned_fraction"] < 1.0
    # engine totals count ANSWERED client queries only: build_queries is
    # internal (a query through build + op must not be double-counted)
    assert s.queries == 12 * N_QUERIES + 1
    # padded_queries books the bucket padding: 5 -> 8 per 5-query dispatch
    pad = engine.bucket_for(N_QUERIES) - N_QUERIES
    assert s.padded_queries == 12 * pad  # 6 ops x 2 passes; not build/exact


def test_server_micro_batching(env):
    """The serving front-end returns per-request results equal to the
    engine's and actually groups requests into shared device batches."""
    from repro.launch.serve_search import SearchServer
    datasets, repo, engine, lo, hi, *_ = env
    server = SearchServer(QueryEngine(repo), max_batch=8,
                          max_wait_ms=20.0).start()
    try:
        futures = [
            server.submit("topk_ia", q_lo=lo[i], q_hi=hi[i], k=K)
            for i in range(N_QUERIES)
        ]
        got = [f.result(timeout=600) for f in futures]
        vals, ids = engine.topk_ia(lo, hi, K)
        for i, (v, j) in enumerate(got):
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(vals[i]))
            np.testing.assert_array_equal(np.asarray(j),
                                          np.asarray(ids[i]))
        assert server.stats.batches < N_QUERIES   # grouping happened
    finally:
        server.stop()
