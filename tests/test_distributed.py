"""Multi-device tests.  jax pins the device count at first init, so these
run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the spec forbids setting it globally for the tier-1 test session; the
shared harness lives in conftest.run_py)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from conftest import REPO, run_py


def test_ring_hausdorff_and_sharded_search():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as dist
        from repro.kernels import ref
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(2)
        q = rng.normal(size=(64, 2)).astype(np.float32)
        d = rng.normal(loc=1.0, size=(128, 2)).astype(np.float32)
        qv = np.ones(64, bool); dv = np.ones(128, bool); dv[120:] = False
        h = dist.ring_hausdorff(mesh, "model", jnp.asarray(q),
                                jnp.asarray(qv), jnp.asarray(d),
                                jnp.asarray(dv))
        h_ref = ref.directed_hausdorff(jnp.asarray(q), jnp.asarray(d),
                                       jnp.asarray(qv), jnp.asarray(dv))
        assert np.allclose(h, h_ref, atol=1e-5), (float(h), float(h_ref))
        dd, ii = dist.ring_nn_distance(mesh, "model", jnp.asarray(q),
                                       jnp.asarray(qv), jnp.asarray(d),
                                       jnp.asarray(dv))
        dr, ir = ref.nn_distance(jnp.asarray(q), jnp.asarray(d),
                                 jnp.asarray(qv), jnp.asarray(dv))
        assert np.allclose(dd, dr, atol=1e-5)
        assert (np.asarray(ii) == np.asarray(ir)).all()
        # sharded GBO
        B = 64
        dvv = np.ones(B, bool); dvv[60:] = False
        sg = rng.integers(0, 2**32, size=(B, 32), dtype=np.uint32)
        qs = rng.integers(0, 2**32, size=(32,), dtype=np.uint32)
        tv, ti = dist.sharded_topk_gbo(mesh, ("data", "model"),
                                       jnp.asarray(qs), jnp.asarray(sg),
                                       jnp.asarray(dvv), 5)
        cref = np.array([np.unpackbits((qs & s).view(np.uint8)).sum()
                         for s in sg]).astype(np.int64)
        cref = np.where(dvv, cref, -1)
        assert (np.asarray(tv) == np.sort(cref)[::-1][:5]).all()
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.launch import sharding as sh, mesh as mesh_lib
        from repro.train import optimizer as opt_lib, train_step as ts
        cfg = configs.get_reduced("llama3_8b")
        opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=1)
        key = jax.random.PRNGKey(0)
        state = ts.init_train_state(key, cfg, opt_cfg)
        batch = {
            "tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
        }
        step = ts.make_train_step(cfg, opt_cfg)
        # single device
        s1, m1 = jax.jit(step)(state, batch)
        # sharded on a (4, 2) mesh
        mesh = mesh_lib.make_test_mesh()
        p_shard = sh.param_shardings(jax.eval_shape(lambda: state.params),
                                     mesh)
        with mesh:
            s2, m2 = jax.jit(step)(state, batch)
        assert np.allclose(float(m1["loss"]), float(m2["loss"]),
                           rtol=1e-4), (float(m1["loss"]), float(m2["loss"]))
        w1 = np.asarray(jax.tree.leaves(s1.params)[0])
        w2 = np.asarray(jax.tree.leaves(s2.params)[0])
        assert np.allclose(w1, w2, atol=1e-4)
        print("SHARD_OK", float(m1["loss"]))
    """)
    assert "SHARD_OK" in out


def test_elastic_restore_onto_different_mesh(tmp_path):
    tmp_path = str(tmp_path)
    out = run_py(f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.checkpoint import ckpt as ckpt_lib
        from repro.launch import sharding as sh
        from repro.runtime import elastic
        from repro.train import optimizer as opt_lib, train_step as ts
        cfg = configs.get_reduced("llama3_8b")
        opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=1)
        state = ts.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
        ckpt_lib.save({tmp_path!r}, 1, state.params, extra={{"step": 1}})
        # "failure": restore onto a SHRUNKEN mesh (8 -> 4 devices)
        plan = elastic.plan_remesh({{"data": 4, "model": 2}}, failed=4)
        assert plan.new_shape["model"] == 2
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        shards = sh.param_shardings(jax.eval_shape(lambda: state.params),
                                    mesh2)
        restored, extra = ckpt_lib.restore({tmp_path!r}, state.params,
                                           shardings=shards)
        w0 = np.asarray(jax.tree.leaves(state.params)[0])
        w1 = np.asarray(jax.tree.leaves(restored)[0])
        assert np.array_equal(w0, w1)
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_dryrun_cell_reduced():
    """The dry-run pipeline itself (lower+compile+cost+collectives) on a
    reduced cell and 8-device test mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src:{REPO}"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "yi_9b",
         "--shape", "train_4k", "--mesh", "multi", "--test",
         "--out", "/tmp/dryrun_pytest"],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "all cells ok" in r.stdout
    import json
    rec = json.loads(
        Path("/tmp/dryrun_pytest/yi_9b__train_4k__multi.json").read_text())
    assert rec["status"] == "ok"
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_total"] > 0   # grads cross the pod axis
