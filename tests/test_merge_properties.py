"""Property-based tests for the O(k) sharded top-k merge helpers.

The exactness contract of `repro.engine.merge`: merging per-shard top-k
lists (each produced by `jax.lax.top_k` over a contiguous ascending
global-id slot range, concatenated in shard order) is BIT-IDENTICAL to one
global `jax.lax.top_k` over the concatenated scores — including duplicate
distances (tie order) and `-1` id-sentinel padded slots (sentinel
application commutes with the merge).

Runs under hypothesis when installed (the CI path — hypothesis is in
requirements.txt); without it, the same properties are exercised by a
seeded random sweep so the suite never silently skips the contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import merge

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# few distinct values on purpose: ties (duplicate distances) everywhere
VALUE_POOL = np.array([-1.0, -1.0, 0.0, 0.5, 0.5, 2.0, 3.25, 3.25, 9.0],
                      np.float32)


def _case_from_seed(seed: int):
    rng = np.random.default_rng(seed)
    n_shards = int(rng.integers(1, 7))
    shard_slots = int(rng.integers(1, 9))
    k = int(rng.integers(1, n_shards * shard_slots + 1))
    scores = rng.choice(VALUE_POOL, size=(n_shards, shard_slots))
    return n_shards, shard_slots, k, scores.astype(np.float32)


def _run_merge_case(n_shards: int, shard_slots: int, k: int,
                    scores: np.ndarray):
    """scores: (n_shards, shard_slots); global slots = concatenation."""
    flat = jnp.asarray(scores.reshape(-1))
    want_v, want_i = jax.lax.top_k(flat, k)

    # per-shard lists exactly as the sharded engine builds them
    lv, li = [], []
    for s in range(n_shards):
        v, i = merge.local_topk(jnp.asarray(scores[s]), k,
                                base=s * shard_slots)
        lv.append(v)
        li.append(i)
    cat_v = jnp.concatenate(lv)
    cat_i = jnp.concatenate(li)
    got_v, got_i = merge.merge_topk(cat_v, cat_i, k)

    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))

    # -1 sentinel (padded/invalid slots score < 0): applying it to the
    # per-shard lists before merging == applying it to the merged list
    pre_v, pre_i = merge.merge_topk(cat_v, merge.sentinel_ids(cat_v, cat_i),
                                    k)
    np.testing.assert_array_equal(
        np.asarray(pre_i), np.asarray(merge.sentinel_ids(got_v, got_i)))
    np.testing.assert_array_equal(np.asarray(pre_v), np.asarray(got_v))

    # batched (leading query axis) form used inside the engine
    got_bv, got_bi = merge.merge_topk(cat_v[None], cat_i[None], k)
    np.testing.assert_array_equal(np.asarray(got_bv[0]), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_bi[0]), np.asarray(want_i))


def _run_int_case(seed: int):
    """GBO-shaped: int32 intersection counts with -1 invalid slots."""
    rng = np.random.default_rng(seed)
    n_shards = int(rng.integers(1, 7))
    shard_slots = int(rng.integers(1, 9))
    k = int(rng.integers(1, n_shards * shard_slots + 1))
    counts = rng.integers(-1, 4, size=(n_shards, shard_slots),
                          dtype=np.int32)
    flat = jnp.asarray(counts.reshape(-1))
    want_v, want_i = jax.lax.top_k(flat, k)
    lv, li = zip(*(merge.local_topk(jnp.asarray(counts[s]), k,
                                    base=s * shard_slots)
                   for s in range(n_shards)))
    got_v, got_i = merge.merge_topk(jnp.concatenate(lv),
                                    jnp.concatenate(li), k)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


if HAVE_HYPOTHESIS:
    SET = dict(max_examples=100, deadline=None)

    @st.composite
    def merge_case(draw):
        n_shards = draw(st.integers(1, 6))
        shard_slots = draw(st.integers(1, 8))
        k = draw(st.integers(1, n_shards * shard_slots))
        scores = draw(st.lists(
            st.sampled_from(list(float(v) for v in VALUE_POOL)),
            min_size=n_shards * shard_slots,
            max_size=n_shards * shard_slots,
        ))
        arr = np.asarray(scores, np.float32).reshape(n_shards, shard_slots)
        return n_shards, shard_slots, k, arr

    @given(merge_case())
    @settings(**SET)
    def test_merge_topk_matches_global_topk(case):
        _run_merge_case(*case)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SET)
    def test_merge_topk_int_counts(seed):
        _run_int_case(seed)

else:
    @pytest.mark.parametrize("seed", range(60))
    def test_merge_topk_matches_global_topk(seed):
        _run_merge_case(*_case_from_seed(seed))

    @pytest.mark.parametrize("seed", range(30))
    def test_merge_topk_int_counts(seed):
        _run_int_case(seed)


def test_merge_topk_all_sentinel():
    """Every slot padded: ids all -1, values all the fill score."""
    scores = np.full((4, 3), -1.0, np.float32)
    lv, li = zip(*(merge.local_topk(jnp.asarray(scores[s]), 5, base=3 * s)
                   for s in range(4)))
    v, i = merge.merge_topk(jnp.concatenate(lv), jnp.concatenate(li), 5)
    i = merge.sentinel_ids(v, i)
    assert (np.asarray(i) == -1).all()
    assert (np.asarray(v) == -1.0).all()
