"""Joinable dataset search: the grid-overlap / coverage op family.

Pins the tentpole contract of `core/join_search` + its engine wiring:

  * `topk_overlap` / `topk_coverage` through `QueryEngine.search` are
    BIT-IDENTICAL to the brute-force host oracle `topk_join_host`
    (integer scores — equality, no tolerance), across mixed query sizes,
    duplicate query rows, cloned-dataset score ties, and top-k overrun
    past the valid dataset count (`-1` sentinels);
  * the bound phase is SOUND: pruning changes no answer, only the
    `evaluated` counter (asserted via a full-evaluation reference run at
    chunk = n_slots), and the surfaced `SearchStats` are consistent
    (`candidates_after_bounds <= evaluated <= n_valid`);
  * the dataset→dataset Pipeline (stage-1 winners re-ranked by
    joinability) equals the two-call host baseline, keeps stage-1 rank
    on score ties, and degrades to ALL-SENTINEL output when zero
    stage-1 winners survive (the clamp+mask path, point stage too);
  * sharded (uneven 3-shard) and replicated (2x4) dispatch reproduce
    local results bit-for-bit (`dispatch_device_check` harness);
  * live mutations: joinable answers at every epoch match a cold engine
    over the frozen equivalent, and result-cache entries never leak
    across epochs (the epoch-carrying cache keys).

Property sweeps run under hypothesis when installed; without it — or
with ``REPRO_SEEDED_PROPS=1`` — the same properties run over a seeded
sweep (pattern from tests/test_mutation_properties.py).
"""
import os

import numpy as np
import pytest

from conftest import dispatch_device_check, make_clustered_datasets
from repro.core import join_search
from repro.core.build import build_repository
from repro.engine import Pipeline, Query, QueryEngine

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

USE_SEEDED = (not HAVE_HYPOTHESIS
              or bool(os.environ.get("REPRO_SEEDED_PROPS")))

THETA = 5
K = 6
N_DS = 26          # -> 32 slots; 3 shards pad to 33 (uneven remainder)


def _build(n_datasets=N_DS, seed=4):
    datasets = make_clustered_datasets(n_datasets, seed=seed,
                                       n_points=(30, 120))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    return datasets, repo


@pytest.fixture(scope="module")
def env():
    datasets, repo = _build()
    return datasets, repo, QueryEngine(repo, result_cache_size=0)


def _query_sets(datasets, rng):
    """Mixed-size query sets: dataset subsets (real overlap), a whole
    dataset, and off-support uniform noise (zero overlap everywhere)."""
    return [
        np.asarray(datasets[3][:40]),
        np.asarray(datasets[11]),
        np.asarray(datasets[7][:96]),
        rng.uniform(200, 300, (25, 2)).astype(np.float32),
    ]


@pytest.mark.parametrize("op,mode", [("topk_overlap", "overlap"),
                                     ("topk_coverage", "coverage")])
def test_matches_host_oracle(env, op, mode):
    datasets, repo, eng = env
    rng = np.random.default_rng(1)
    qsets = _query_sets(datasets, rng)
    for k in (K, N_DS, repo.n_slots):        # normal, = n_valid, overrun
        res = eng.search([Query(op=op, q=q, k=k) for q in qsets])
        want_v, want_i = join_search.topk_join_host(repo, qsets, k, mode)
        for i, r in enumerate(res):
            np.testing.assert_array_equal(np.asarray(r.vals), want_v[i])
            np.testing.assert_array_equal(np.asarray(r.ids), want_i[i])
    # overrun rows carry -1 sentinels (k = n_slots > n_valid)
    v = np.asarray(res[0].vals)
    i = np.asarray(res[0].ids)
    assert (v < 0).any() and (i[v < 0] == -1).all()


def test_duplicate_rows_and_ties(env):
    """Duplicate query rows in one grouped dispatch answer identically,
    and cloned datasets (exact score ties) rank by slot id — same rule
    as the oracle's stable sort."""
    datasets, repo, eng = env
    q = np.asarray(datasets[5][:64])
    res = eng.search([Query(op="topk_overlap", q=q, k=K)] * 3)
    for r in res[1:]:
        np.testing.assert_array_equal(np.asarray(res[0].vals),
                                      np.asarray(r.vals))
        np.testing.assert_array_equal(np.asarray(res[0].ids),
                                      np.asarray(r.ids))

    clones = [datasets[0], datasets[0], datasets[0], datasets[1]]
    repo2, _ = build_repository(clones, leaf_capacity=16, theta=THETA,
                                remove_outliers=False)
    eng2 = QueryEngine(repo2, result_cache_size=0)
    for op, mode in (("topk_overlap", "overlap"),
                     ("topk_coverage", "coverage")):
        r = eng2.search([Query(op=op, q=np.asarray(datasets[0]), k=4)])[0]
        wv, wi = join_search.topk_join_host(
            repo2, [np.asarray(datasets[0])], 4, mode)
        np.testing.assert_array_equal(np.asarray(r.vals), wv[0])
        np.testing.assert_array_equal(np.asarray(r.ids), wi[0])
        # the three clones tie at the top; ids come back in slot order
        assert np.asarray(r.vals)[0] == np.asarray(r.vals)[1] \
            == np.asarray(r.vals)[2]
        np.testing.assert_array_equal(np.asarray(r.ids)[:3], [0, 1, 2])


def test_pruning_is_sound_and_stats_consistent(env):
    """A small-chunk run (pruning active) returns the same answers as a
    one-chunk full evaluation; stats stay within their bounds and the
    executable-cache invariant holds."""
    datasets, repo, eng = env
    q = np.asarray(datasets[9])
    small = QueryEngine(repo, result_cache_size=0, default_chunk=8)
    full = QueryEngine(repo, result_cache_size=0,
                       default_chunk=repo.n_slots)
    for op in ("topk_overlap", "topk_coverage"):
        r_s = small.search([Query(op=op, q=q, k=3)])[0]
        r_f = full.search([Query(op=op, q=q, k=3)])[0]
        np.testing.assert_array_equal(np.asarray(r_s.vals),
                                      np.asarray(r_f.vals))
        np.testing.assert_array_equal(np.asarray(r_s.ids),
                                      np.asarray(r_f.ids))
        n_valid = int(np.asarray(repo.ds_valid).sum())
        for r in (r_s, r_f):
            s = r.stats
            # the refine evaluates whole chunks while τ is still loose, so
            # it covers (at least) every slot whose UB survives τ_final
            assert 0 < s.exact_evaluations <= n_valid
            assert s.candidates_after_bounds <= s.exact_evaluations
            assert 0.0 <= s.pruned_fraction <= 1.0
            assert s.nodes_evaluated > 0
        # the small-chunk run prunes tail chunks the full run evaluates
        assert (r_s.stats.exact_evaluations
                <= r_f.stats.exact_evaluations)
    for e in (small, full):
        assert e.stats.cache_hits + e.stats.cache_misses \
            == e.stats.dispatches


def test_off_support_query_prunes(env):
    """A query far off every dataset's support scores 0 everywhere; with
    clustered data a clustered query's refine stops early (genuinely
    nonzero pruned fraction at small chunk)."""
    datasets, repo, eng = env
    small = QueryEngine(repo, result_cache_size=0, default_chunk=4)
    q = np.asarray(datasets[2][:80])
    r = small.search([Query(op="topk_overlap", q=q, k=2)])[0]
    wv, wi = join_search.topk_join_host(repo, [q], 2, "overlap")
    np.testing.assert_array_equal(np.asarray(r.vals), wv[0])
    np.testing.assert_array_equal(np.asarray(r.ids), wi[0])
    assert r.stats.pruned_fraction > 0.0


def _rerank_baseline(repo, eng, q, k1, k2, mode, lo, hi):
    """Two-call host baseline: stage-1 top-k ia ids, full-oracle join
    scores, stable descending re-rank to k2."""
    r1 = eng.search([Query(op="topk_ia", r_lo=lo, r_hi=hi, k=k1)])[0]
    ids1 = np.asarray(r1.ids, np.int32)
    wv, wi = join_search.topk_join_host(repo, [q], repo.n_slots, mode)
    full = {int(i): int(v) for v, i in zip(wv[0], wi[0]) if i >= 0}
    sc = np.array([full.get(int(d), 0) if d >= 0 else -1 for d in ids1],
                  np.int32)
    order = np.argsort(-sc, kind="stable")[:k2]
    vals = np.where(sc[order] < 0, -1, sc[order]).astype(np.int32)
    ids = np.where(vals < 0, -1, ids1[order]).astype(np.int32)
    return vals, ids


@pytest.mark.parametrize("op,mode", [("topk_overlap", "overlap"),
                                     ("topk_coverage", "coverage")])
def test_pipeline_rerank_matches_baseline(env, op, mode):
    datasets, repo, eng = env
    q = np.asarray(datasets[3][:50])
    lo = q.min(axis=0) - 5.0
    hi = q.max(axis=0) + 5.0
    res = eng.search([Pipeline(
        Query(op="topk_ia", r_lo=lo, r_hi=hi, k=8),
        Query(op=op, q=q, k=3))])[0]
    want_v, want_i = _rerank_baseline(repo, eng, q, 8, 3, mode, lo, hi)
    np.testing.assert_array_equal(np.asarray(res.vals), want_v)
    np.testing.assert_array_equal(np.asarray(res.ids), want_i)
    np.testing.assert_array_equal(np.asarray(res.mask),
                                  np.asarray(res.vals) >= 0)
    # a joinable op can drive stage 1 as well (dataset→dataset both ways)
    res2 = eng.search([Pipeline(
        Query(op="topk_overlap", q=q, k=5),
        Query(op="topk_coverage", q=q, k=2))])[0]
    assert np.asarray(res2.vals).shape == (2,)
    assert (np.asarray(res2.ids) >= -1).all()


def test_two_pipelines_share_rerank_dispatch(env):
    """Compatible joinable stage-2 rows (same op/k/capacity) group into
    ONE re-rank dispatch across pipelines — ragged stage-1 ks included."""
    datasets, repo, eng = env
    engine = QueryEngine(repo, result_cache_size=0)
    q = np.asarray(datasets[3][:50])
    lo, hi = q.min(axis=0) - 5.0, q.max(axis=0) + 5.0

    def pipes():
        return [
            Pipeline(Query(op="topk_ia", r_lo=lo, r_hi=hi, k=3),
                     Query(op="topk_overlap", q=q, k=2)),
            Pipeline(Query(op="topk_ia", r_lo=lo - 2, r_hi=hi + 2, k=5),
                     Query(op="topk_overlap", q=q, k=2)),
        ]

    engine.search(pipes())                   # warm the executables
    g0 = engine.stats.plan_groups
    engine.search(pipes())
    # stage 1: topk_ia k=3 and k=5 groups; stage 2: ONE shared re-rank
    assert engine.stats.plan_groups == g0 + 3


def test_zero_surviving_winners_all_sentinel():
    """Satellite: a pipeline whose stage 1 yields NO winners (every
    dataset deleted) must degrade to all-sentinel output on BOTH stage-2
    flavors — the clamp+mask path never ranks slot 0 by accident."""
    from repro.engine import LiveRepository

    rng = np.random.default_rng(0)
    init = [(rng.uniform(-20, 20, 2)
             + rng.normal(0, 2, (24, 2))).astype(np.float32)
            for _ in range(4)]
    live = LiveRepository(init, leaf_capacity=16, point_capacity=32,
                          result_cache_size=16)
    for j in sorted(live.live_ids):
        live.delete(j)
    assert not live.live_ids
    q = init[0][:16]
    lo, hi = q.min(axis=0) - 50.0, q.max(axis=0) + 50.0

    # standalone joinable query on an empty repository: all sentinels
    r0 = live.search([Query(op="topk_overlap", q=q, k=3)])[0]
    np.testing.assert_array_equal(np.asarray(r0.vals), [-1, -1, -1])
    np.testing.assert_array_equal(np.asarray(r0.ids), [-1, -1, -1])

    # dataset→dataset stage 2 over zero survivors
    rj = live.search([Pipeline(
        Query(op="topk_ia", r_lo=lo, r_hi=hi, k=3),
        Query(op="topk_coverage", q=q, k=2))])[0]
    np.testing.assert_array_equal(np.asarray(rj.extras["ds_ids"]),
                                  [-1, -1, -1])
    assert not np.asarray(rj.extras["valid"]).any()
    np.testing.assert_array_equal(np.asarray(rj.vals), [-1, -1])
    np.testing.assert_array_equal(np.asarray(rj.ids), [-1, -1])
    assert not np.asarray(rj.mask).any()

    # point stage 2 over zero survivors: fully-masked rows
    rp = live.search([Pipeline(
        Query(op="topk_ia", r_lo=lo, r_hi=hi, k=3),
        Query(op="range_points", r_lo=lo, r_hi=hi))])[0]
    assert not np.asarray(rp.mask).any()
    assert not np.asarray(rp.extras["valid"]).any()

    s = live.stats
    assert s.cache_hits + s.cache_misses == s.dispatches


def test_result_cache_and_epoch_keys():
    """Identical joinable repeats hit the result cache; a mutation bumps
    the epoch, retires the entries, and the re-dispatch matches a cold
    engine over the frozen equivalent."""
    from repro.engine import LiveRepository

    datasets, _ = _build(10)
    live = LiveRepository(datasets, leaf_capacity=16, theta=THETA,
                          remove_outliers=False, result_cache_size=64)
    q = np.asarray(datasets[3][:50])
    batch = [Query(op="topk_overlap", q=q, k=4),
             Query(op="topk_coverage", q=q, k=4)]
    r0 = live.search(batch)
    h0 = live.stats.result_cache_hits
    r1 = live.search(batch)
    assert live.stats.result_cache_hits == h0 + len(batch)
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(np.asarray(a.vals),
                                      np.asarray(b.vals))
        np.testing.assert_array_equal(np.asarray(a.ids),
                                      np.asarray(b.ids))

    live.delete(3)
    inv0 = live.stats.epoch_invalidations
    r2 = live.search(batch)
    assert live.stats.epoch_invalidations >= inv0
    cold = QueryEngine(live.frozen_repository(), leaf_capacity=16,
                       result_cache_size=0)
    want = cold.search(batch)
    for a, b in zip(r2, want):
        np.testing.assert_array_equal(np.asarray(a.vals),
                                      np.asarray(b.vals))
        np.testing.assert_array_equal(np.asarray(a.ids),
                                      np.asarray(b.ids))
    s = live.stats
    assert s.cache_hits + s.cache_misses == s.dispatches


# ---------------------------------------------------------------------------
# mesh equivalence (uneven 3-shard and 2x4 replica meshes)
# ---------------------------------------------------------------------------


def _check_mesh(mesh_builder):
    datasets, repo = _build()
    eng = QueryEngine(repo, result_cache_size=0)
    sng = mesh_builder(repo)
    rng = np.random.default_rng(2)
    qsets = _query_sets(datasets, rng)
    eq = np.testing.assert_array_equal
    for op in ("topk_overlap", "topk_coverage"):
        for k in (K, repo.n_slots):          # normal and overrun
            qs = [Query(op=op, q=q, k=k) for q in qsets]
            r0, r1 = eng.search(qs), sng.search(qs)
            for a, b in zip(r0, r1):
                eq(np.asarray(a.vals), np.asarray(b.vals))
                eq(np.asarray(a.ids), np.asarray(b.ids))
    q = qsets[0]
    lo, hi = q.min(axis=0) - 5.0, q.max(axis=0) + 5.0
    p = [Pipeline(Query(op="topk_ia", r_lo=lo, r_hi=hi, k=8),
                  Query(op="topk_overlap", q=q, k=3))]
    a, b = eng.search(p)[0], sng.search(p)[0]
    eq(np.asarray(a.vals), np.asarray(b.vals))
    eq(np.asarray(a.ids), np.asarray(b.ids))
    s = sng.stats
    assert s.cache_hits + s.cache_misses == s.dispatches


def check_join_sharded_uneven():
    from repro.engine import ShardedQueryEngine, data_mesh
    _check_mesh(lambda repo: ShardedQueryEngine(repo, mesh=data_mesh(3)))


def check_join_replicated():
    from repro.engine import ReplicatedQueryEngine
    _check_mesh(lambda repo: ReplicatedQueryEngine(repo, n_replicas=2,
                                                   n_data=4))


def test_join_sharded_uneven():
    dispatch_device_check("test_join_search", "check_join_sharded_uneven",
                          devices=3)


def test_join_replicated():
    dispatch_device_check("test_join_search", "check_join_replicated",
                          devices=8)


# ---------------------------------------------------------------------------
# property sweeps
# ---------------------------------------------------------------------------

_PROP_DATASETS, _PROP_REPO = None, None


def _prop_env():
    """Build once per process: every example reuses the same repository
    and engine executables (geometry pinned, like the mutation props)."""
    global _PROP_DATASETS, _PROP_REPO
    if _PROP_REPO is None:
        _PROP_DATASETS, _PROP_REPO = _build(14, seed=9)
    return _PROP_DATASETS, _PROP_REPO, QueryEngine(_PROP_REPO,
                                                   result_cache_size=0)


def _join_property(seed: int):
    datasets, repo, eng = _prop_env()
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 4))
    qsets = []
    for _ in range(B):
        base = datasets[int(rng.integers(len(datasets)))]
        n = int(rng.integers(5, len(base) + 1))
        pts = base[rng.permutation(len(base))[:n]]
        if rng.random() < 0.3:               # jitter off the exact cells
            pts = pts + rng.normal(0, 1.0, pts.shape).astype(np.float32)
        qsets.append(np.asarray(pts, np.float32))
    k = int(rng.integers(1, repo.n_slots + 1))
    op, mode = (("topk_overlap", "overlap") if rng.random() < 0.5
                else ("topk_coverage", "coverage"))
    res = eng.search([Query(op=op, q=q, k=k) for q in qsets])
    want_v, want_i = join_search.topk_join_host(repo, qsets, k, mode)
    for i, r in enumerate(res):
        np.testing.assert_array_equal(np.asarray(r.vals), want_v[i])
        np.testing.assert_array_equal(np.asarray(r.ids), want_i[i])
    s = eng.stats
    assert s.cache_hits + s.cache_misses == s.dispatches


if not USE_SEEDED:
    @given(seed=st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_join_property_matches_oracle(seed):
        _join_property(seed)

else:
    @pytest.mark.parametrize("seed", range(12))
    def test_join_property_matches_oracle(seed):
        _join_property(seed)


def _live_join_property(seed: int, steps: int = 8):
    """Joinable queries interleaved with live ingest/delete/replace: at
    every epoch the answers match a cold engine over the frozen build."""
    from repro.engine import LiveRepository

    rng = np.random.default_rng(seed)

    def mk():
        n = int(rng.integers(8, 28))
        c = rng.uniform(-40, 40, 2)
        return (c + rng.normal(0, rng.uniform(1, 4), (n, 2))
                ).astype(np.float32)

    init = [mk() for _ in range(6)]
    live = LiveRepository(init, leaf_capacity=8, point_capacity=32,
                          result_cache_size=64)
    model = {j: init[j] for j in range(6)}

    def check():
        q = mk()[:12]
        batch = [Query(op="topk_overlap", q=q, k=3),
                 Query(op="topk_coverage", q=q, k=3)]
        got = live.search(batch)
        cold = QueryEngine(live.frozen_repository(), leaf_capacity=8,
                           result_cache_size=0)
        want = cold.search(batch)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a.vals),
                                          np.asarray(b.vals))
            np.testing.assert_array_equal(np.asarray(a.ids),
                                          np.asarray(b.ids))

    check()
    for _ in range(steps):
        kind = int(rng.integers(0, 3))
        if kind == 0:
            sid = live.ingest(mk())
            model[sid] = True
        elif kind == 1 and len(model) > 1:
            sid = int(rng.choice(sorted(model)))
            live.delete(sid)
            del model[sid]
        else:
            sid = int(rng.choice(sorted(model)))
            live.replace(sid, mk())
        check()
    s = live.stats
    assert s.cache_hits + s.cache_misses == s.dispatches


if not USE_SEEDED:
    @given(seed=st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=4, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_live_join_matches_frozen_every_epoch(seed):
        _live_join_property(seed)

else:
    @pytest.mark.parametrize("seed", range(4))
    def test_live_join_matches_frozen_every_epoch(seed):
        _live_join_property(seed)
