"""Property tests for replica-parallel dispatch: random mixed-op batches.

For random declarative `search()` batches — random op mix (all seven ops
plus dataset->point pipelines), random batch sizes (1..12, above and
below the replica count, with duplicate rows), random query parameters —
the ReplicatedQueryEngine on a random (replica, data) factorization of
the available devices returns results BIT-IDENTICAL to the single-device
QueryEngine (values, ids, masks, pipeline extras), and its EngineStats
keep the replica accounting invariants:

  * every device dispatch books exactly one executable-cache hit or miss;
  * the planner books the same compiled groups as the local engine
    (`plan_groups` equal), and the replica row-block accounting satisfies
    `plan_groups <= replica_subgroups <= plan_groups * R` with
    `sum(group_counts.values()) == replica_subgroups`.

The mesh pool adapts to the session: a single-device tier-1 session
exercises the degenerate 1x1 replicated engine (same dispatch code
path), while the multi-device CI job (REPRO_HOST_DEVICES=8) draws from
{1x8, 2x4, 4x2, 2x3} — including the uneven-shard 2x3 split.

Runs under hypothesis when installed (the CI path); without it the same
property runs over a seeded random sweep so the suite never silently
skips the contract (pattern from tests/test_exacthaus_properties.py).
Engines are cached per (repo, mesh) so executables are reused across
examples instead of recompiling per draw.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from test_engine_sharded import K, _build

_ENVS: dict = {}
REPO_SEEDS = (2, 7)
N_DATASETS = 17


def _mesh_pool():
    n = jax.device_count()
    if n >= 8:
        return ((1, 8), (2, 4), (4, 2), (2, 3))
    if n >= 6:
        return ((2, 3), (1, 2))
    if n >= 2:
        return ((2, 1), (1, 2))
    return ((1, 1),)


def _env(repo_seed: int, mesh: tuple[int, int]):
    from repro.engine import ReplicatedQueryEngine

    if repo_seed not in _ENVS:
        datasets, repo, eng, q_sets, sigs, eps = _build(N_DATASETS,
                                                        seed=repo_seed)
        _ENVS[repo_seed] = (datasets, repo, eng, q_sets, sigs, eps, {})
    datasets, repo, eng, q_sets, sigs, eps, rengs = _ENVS[repo_seed]
    if mesh not in rengs:
        rengs[mesh] = ReplicatedQueryEngine(repo, n_replicas=mesh[0],
                                            n_data=mesh[1])
    return datasets, repo, eng, q_sets, sigs, eps, rengs[mesh]


def _random_batch(rng, repo, q_sets, sigs, eps, size: int):
    """A random mixed search() batch: every op reachable, random params,
    k values that straddle the valid dataset count, ragged rects."""
    from repro.engine import Pipeline, Query

    lo = rng.uniform(-60, 40, (size, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (size, 2)).astype(np.float32)
    ks = (1, K, repo.n_slots)           # n_slots: top-k overrun

    def make(i):
        op = int(rng.integers(9))
        k = ks[int(rng.integers(len(ks)))]
        q = q_sets[int(rng.integers(len(q_sets)))]
        sig = sigs[int(rng.integers(len(sigs)))]
        ds = int(rng.integers(N_DATASETS))
        if op == 0:
            return Query(op="topk_ia", r_lo=lo[i], r_hi=hi[i], k=k)
        if op == 1:
            return Query(op="range_search", r_lo=lo[i], r_hi=hi[i])
        if op == 2:
            return Query(op="range_points", ds_id=ds, r_lo=lo[i],
                         r_hi=hi[i])
        if op == 3:
            return Query(op="nnp", ds_id=ds, q=q)
        if op == 4:
            return Query(op="topk_hausdorff", q=q, k=k)
        if op == 5:
            return Query(op="topk_gbo", q_sig=sig, k=k)
        if op == 6:
            return Query(op="topk_hausdorff_approx", q=q, k=k, eps=eps)
        if op == 7:
            return Pipeline(Query(op="topk_ia", r_lo=lo[i], r_hi=hi[i],
                                  k=k),
                            Query(op="range_points", r_lo=lo[i],
                                  r_hi=hi[i]))
        return Pipeline(Query(op="topk_gbo", q_sig=sig, k=min(k, 4)),
                        Query(op="nnp", q=q))

    batch = [make(i) for i in range(size)]
    if size >= 2 and rng.integers(2):
        batch[-1] = batch[0]            # duplicate row
    return batch


def _run_property(repo_seed: int, mesh_i: int, q_seed: int, size: int):
    pool = _mesh_pool()
    n_rep, n_data = pool[mesh_i % len(pool)]
    datasets, repo, eng, q_sets, sigs, eps, reng = _env(repo_seed,
                                                        (n_rep, n_data))
    rng = np.random.default_rng(q_seed)
    batch = _random_batch(rng, repo, q_sets, sigs, eps, size)

    l_before = eng.stats.plan_groups
    want = eng.search(batch)
    g_before = reng.stats.plan_groups
    got = reng.search(batch)

    assert len(got) == len(want) == size
    for a, b in zip(got, want):
        assert a.op == b.op
        for field in ("vals", "ids", "mask"):
            x, y = getattr(a, field), getattr(b, field)
            assert (x is None) == (y is None), (a.op, field)
            if x is not None:
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                              err_msg=a.op)
        if a.op == "pipeline":
            np.testing.assert_array_equal(np.asarray(a.extras["ds_ids"]),
                                          np.asarray(b.extras["ds_ids"]))

    s = reng.stats
    assert s.cache_hits + s.cache_misses == s.dispatches
    # identical planner: same batch -> same compiled groups as local
    assert s.plan_groups - g_before == eng.stats.plan_groups - l_before
    assert s.plan_groups <= s.replica_subgroups <= s.plan_groups * n_rep
    assert sum(s.group_counts.values()) == s.replica_subgroups


def _case_from_seed(seed: int):
    rng = np.random.default_rng(seed)
    return (
        REPO_SEEDS[int(rng.integers(len(REPO_SEEDS)))],
        int(rng.integers(8)),
        int(rng.integers(2**31 - 1)),
        int(rng.integers(1, 13)),
    )


if HAVE_HYPOTHESIS:
    @given(
        repo_seed=st.sampled_from(REPO_SEEDS),
        mesh_i=st.integers(0, 7),
        q_seed=st.integers(0, 2**31 - 1),
        size=st.integers(1, 12),
    )
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_replicated_search_matches_local(repo_seed, mesh_i, q_seed,
                                             size):
        _run_property(repo_seed, mesh_i, q_seed, size)

else:
    @pytest.mark.parametrize("seed", range(6))
    def test_replicated_search_matches_local(seed):
        _run_property(*_case_from_seed(seed))
