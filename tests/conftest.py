import numpy as np
import pytest

# NOTE (spec): do NOT set xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device.  Multi-device tests run subprocesses.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_clustered_datasets(n, seed=0, n_points=(40, 300), d=2):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 4))
        centers = rng.uniform(-50, 50, (k, d))
        npts = int(rng.integers(*n_points))
        idx = rng.integers(0, k, npts)
        pts = centers[idx] + rng.normal(size=(npts, d)) * rng.uniform(0.5, 2)
        out.append(pts.astype(np.float32))
    return out
