"""Shared test fixtures + the multi-device harness.

Spec note: XLA's host-platform device count is pinned at first jax init, so
the tier-1 session must NOT force it globally — smoke tests and benches see
exactly 1 device, and multi-device tests run in subprocesses via `run_py`.

The multi-device CI job opts in instead: it sets ``REPRO_HOST_DEVICES=N``
in the environment, and `repro.hostdev.apply()` below (which runs before
any test module imports jax) forces N host-platform devices for the whole
session.  Tests that need a mesh (tests/test_engine_sharded.py) then run
in-process; with the variable unset they transparently fall back to the
subprocess path.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro import hostdev        # requires PYTHONPATH=src (tier-1 command)

hostdev.apply()

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8, timeout: int = 560):
    """Run a python snippet in a subprocess with N forced host devices.

    PYTHONPATH includes src/, the repo root, and tests/ so snippets can
    import both the package and test helpers (e.g. the equivalence bodies
    in test_engine_sharded.py)."""
    env = dict(os.environ)
    env.pop("REPRO_HOST_DEVICES", None)   # the subprocess sets XLA_FLAGS
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{REPO}/src:{REPO}:{REPO}/tests"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def dispatch_device_check(module: str, fn_name: str, devices: int = 8,
                          timeout: int = 560):
    """Run check function `module.fn_name` in-process when the session
    already has >= `devices` devices, else in a forced-`devices`
    subprocess.

    The mesh-shaped tests (1-D data meshes AND 2-D replica x data meshes —
    any factorization whose device product is <= `devices`) share this so
    single-device tier-1 sessions still exercise every suite: the check
    body only sees jax.devices(), so an 8-device session serves a 4x2
    replica mesh and an 8-shard data mesh alike."""
    import importlib

    import jax
    if jax.device_count() >= devices:
        getattr(importlib.import_module(module), fn_name)()
    else:
        run_py(f"from {module} import {fn_name}\n{fn_name}()\n",
               devices=devices, timeout=timeout)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_clustered_datasets(n, seed=0, n_points=(40, 300), d=2):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 4))
        centers = rng.uniform(-50, 50, (k, d))
        npts = int(rng.integers(*n_points))
        idx = rng.integers(0, k, npts)
        pts = centers[idx] + rng.normal(size=(npts, d)) * rng.uniform(0.5, 2)
        out.append(pts.astype(np.float32))
    return out
