"""Search-layer equivalence vs brute force for every paper operation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_clustered_datasets
from repro.core import point_search, search, zorder
from repro.core.build import build_query_index, build_repository
from repro.kernels import ref


@pytest.fixture(scope="module")
def repo_env():
    datasets = make_clustered_datasets(50, seed=1)
    repo, info = build_repository(datasets, leaf_capacity=16, theta=5,
                                  remove_outliers=False)
    Q = datasets[7]
    q_idx, q_sig = build_query_index(Q, space_lo=repo.space_lo,
                                     space_hi=repo.space_hi, theta=5)
    return datasets, repo, Q, q_idx, q_sig


def brute_h(q, d):
    dd = np.sqrt(((q[:, None] - d[None]) ** 2).sum(-1))
    return dd.min(axis=1).max()


def test_topk_hausdorff_exact(repo_env):
    datasets, repo, Q, q_idx, _ = repo_env
    k = 8
    truth = np.array([brute_h(Q, d) for d in datasets])
    vals, ids, stats = search.topk_hausdorff(repo, q_idx, k)
    want = set(np.argsort(truth)[:k].tolist())
    assert set(np.asarray(ids).tolist()) == want
    np.testing.assert_allclose(
        np.sort(np.asarray(vals)), np.sort(truth)[:k], atol=1e-4)
    # pruning must actually prune
    assert stats.exact_evaluations < len(datasets)


def test_topk_hausdorff_approx_bound(repo_env):
    datasets, repo, Q, q_idx, _ = repo_env
    truth = np.array([brute_h(Q, d) for d in datasets])
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, 5))
    vals, ids, (lq, ld, eps_eff) = search.topk_hausdorff_approx(
        repo, q_idx, 8, eps)
    ids = np.asarray(ids)
    err = np.abs(np.asarray(vals) - truth[ids])
    assert (err <= 2 * eps_eff + 1e-4).all()


def test_topk_gbo_matches_set_semantics(repo_env):
    datasets, repo, Q, _, q_sig = repo_env
    vals, ids = search.topk_gbo(repo, q_sig, 5)
    q_cells = set(np.asarray(zorder.cell_ids(
        jnp.asarray(Q), repo.space_lo, repo.space_hi, 5)).tolist())
    brute = []
    for d in datasets:
        c = set(np.asarray(zorder.cell_ids(
            jnp.asarray(d), repo.space_lo, repo.space_hi, 5)).tolist())
        brute.append(len(q_cells & c))
    brute = np.array(brute)
    got_vals = np.asarray(vals)
    np.testing.assert_array_equal(got_vals, np.sort(brute)[::-1][:5])


def test_topk_ia_matches_brute(repo_env):
    datasets, repo, Q, _, _ = repo_env
    qlo, qhi = Q.min(0), Q.max(0)
    vals, ids = search.topk_ia(repo, jnp.asarray(qlo), jnp.asarray(qhi), 5)
    brute = []
    for d in datasets:
        l = np.maximum(
            np.minimum(qhi, d.max(0)) - np.maximum(qlo, d.min(0)), 0)
        brute.append(l[0] * l[1])
    brute = np.sort(np.array(brute))[::-1][:5]
    np.testing.assert_allclose(np.asarray(vals), brute, rtol=1e-5)


def test_range_search_matches_brute(repo_env):
    datasets, repo, Q, _, _ = repo_env
    qlo, qhi = Q.min(0), Q.max(0)
    mask, stats = search.range_search(repo, jnp.asarray(qlo),
                                      jnp.asarray(qhi))
    want = np.array([((d.min(0) <= qhi).all() and (qlo <= d.max(0)).all())
                     for d in datasets])
    np.testing.assert_array_equal(np.asarray(mask)[: len(datasets)], want)


def test_range_points_matches_brute(repo_env):
    datasets, repo, Q, _, _ = repo_env
    d_idx = jax.tree.map(lambda x: x[3], repo.ds_index)
    lo, hi = Q.min(0), Q.max(0)
    take, _ = point_search.range_points(d_idx, jnp.asarray(lo),
                                        jnp.asarray(hi))
    pts = np.asarray(d_idx.points)
    val = np.asarray(d_idx.valid)
    want = (pts >= lo).all(1) & (pts <= hi).all(1) & val
    np.testing.assert_array_equal(np.asarray(take), want)


def test_nnp_exact_and_pruned(repo_env):
    datasets, repo, Q, q_idx, _ = repo_env
    d_idx = jax.tree.map(lambda x: x[3], repo.ds_index)
    wd, wi = ref.nn_distance(q_idx.points, d_idx.points, q_idx.valid,
                             d_idx.valid)
    gd, gi = point_search.nnp(q_idx, d_idx)
    np.testing.assert_allclose(gd, wd, atol=1e-4)
    pd, pi, stats = point_search.nnp_pruned(q_idx, d_idx)
    np.testing.assert_allclose(pd, wd, atol=1e-4)
    assert (np.asarray(pi) == np.asarray(wi)).all()
    assert stats.pruned_fraction > 0.2   # pruning does real work


def test_pairwise_exact_hausdorff(repo_env):
    datasets, repo, Q, q_idx, _ = repo_env
    for j in (0, 11, 23):
        d_idx = jax.tree.map(lambda x: x[j], repo.ds_index)
        h, pruned = search.hausdorff_pair_exact(q_idx, d_idx)
        np.testing.assert_allclose(float(h), brute_h(Q, datasets[j]),
                                   atol=1e-4)


def test_outlier_removal_improves_hausdorff_ranking():
    """Paper Fig. 18: with GPS-failure outliers injected, removal restores
    the clean ranking."""
    datasets = make_clustered_datasets(30, seed=5)
    Q = datasets[0]
    clean_truth = np.array([brute_h(Q, d) for d in datasets])
    polluted = []
    rng = np.random.default_rng(0)
    for d in datasets:
        bad = rng.uniform(500, 800, (max(1, len(d) // 50), 2)).astype(
            np.float32)
        polluted.append(np.concatenate([d, bad]))
    repo_p, _ = build_repository(polluted, leaf_capacity=16,
                                 remove_outliers=True)
    q_idx, _ = build_query_index(Q, space_lo=repo_p.space_lo,
                                 space_hi=repo_p.space_hi)
    k = 5
    vals, ids, _ = search.topk_hausdorff(repo_p, q_idx, k)
    want = set(np.argsort(clean_truth)[:k].tolist())
    got = set(np.asarray(ids).tolist())
    assert len(got & want) >= k - 1   # >=80% accuracy, paper reports ~90%
