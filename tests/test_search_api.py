"""The unified declarative query API: `QueryEngine.search`.

Covers the tentpole contract of the redesign:

  * a mixed batch covering ALL SEVEN ops plus a pipeline query in ONE
    `search()` call, every row bit-identical to the legacy per-op batch
    methods;
  * input-order preservation under arbitrary interleaving (the planner
    regroups rows per (op, statics) but must scatter results back);
  * grouping: one dispatch per (op, statics) group, counted in the new
    `EngineStats.plan_groups` / `group_counts` counters, with the
    executable-cache invariant untouched;
  * result-cache hits short-circuiting per row ACROSS ops inside one
    mixed batch;
  * pipeline dataset->point equivalence against the two-call host
    baseline (both point ops, -1 sentinel winners masked);
  * the NNP dispatch routing through `core/point_search.nnp_pruned`
    (bit-identity + a genuinely nonzero pruned fraction surfaced in
    PointStats);
  * Query/Pipeline construction-time validation.

Sharded-dispatcher equivalence for the same API lives in
tests/test_engine_sharded.py (8-device and uneven 3-shard meshes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_clustered_datasets
from repro.core import point_search, search, zorder
from repro.core.build import build_repository
from repro.engine import Pipeline, Query, QueryEngine

THETA = 5
K = 6


@pytest.fixture(scope="module")
def env():
    datasets = make_clustered_datasets(33, seed=2, n_points=(30, 120))
    repo, _ = build_repository(datasets, leaf_capacity=16, theta=THETA,
                               remove_outliers=False)
    rng = np.random.default_rng(0)
    lo = rng.uniform(-60, 40, (5, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (5, 2)).astype(np.float32)
    q_sets = [datasets[i] for i in (0, 3, 9, 11, 20)]
    sigs = np.stack([
        np.asarray(zorder.signature(jnp.asarray(q),
                                    jnp.ones(len(q), bool),
                                    repo.space_lo, repo.space_hi, THETA))
        for q in q_sets
    ])
    eps = float(zorder.default_epsilon(repo.space_lo, repo.space_hi, THETA))
    return datasets, repo, lo, hi, q_sets, sigs, eps


def _mixed_batch(lo, hi, q_sets, sigs, eps):
    """All seven ops + a pipeline, deliberately interleaved."""
    return [
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=K),
        Query(op="range_search", r_lo=lo[1], r_hi=hi[1]),
        Query(op="nnp", ds_id=4, q=q_sets[1]),
        Query(op="topk_hausdorff", q=q_sets[0], k=K),
        Query(op="topk_gbo", q_sig=sigs[0], k=K),
        Query(op="topk_ia", r_lo=lo[2], r_hi=hi[2], k=K),
        Query(op="range_points", ds_id=7, r_lo=lo[3], r_hi=hi[3]),
        Query(op="topk_hausdorff_approx", q=q_sets[2], k=K, eps=eps),
        Pipeline(Query(op="topk_ia", r_lo=lo[4], r_hi=hi[4], k=3),
                 Query(op="range_points", r_lo=lo[3], r_hi=hi[3])),
        Query(op="topk_hausdorff", q=q_sets[3], k=K),
    ]


def test_mixed_batch_all_ops_one_call(env):
    """One search() call answers a batch covering every op + a pipeline,
    each row bit-identical to the legacy per-op method."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    res = engine.search(_mixed_batch(lo, hi, q_sets, sigs, eps))
    eq = np.testing.assert_array_equal

    # per-op references (legacy methods of a separate engine so the group
    # compositions differ from the mixed call's)
    ref = QueryEngine(repo, result_cache_size=0)
    v_ia, i_ia = ref.topk_ia(np.stack([lo[0], lo[2]]),
                             np.stack([hi[0], hi[2]]), K)
    eq(np.asarray(res[0].vals), np.asarray(v_ia[0]))
    eq(np.asarray(res[0].ids), np.asarray(i_ia[0]))
    eq(np.asarray(res[5].vals), np.asarray(v_ia[1]))
    eq(np.asarray(res[5].ids), np.asarray(i_ia[1]))

    eq(np.asarray(res[1].mask),
       np.asarray(ref.range_search(lo[1][None], hi[1][None])[0]))

    qb_nnp = ref.build_queries([q_sets[1]])
    d_ref, x_ref = ref.nnp(np.array([4], np.int32), qb_nnp)
    eq(np.asarray(res[2].vals), np.asarray(d_ref[0]))
    eq(np.asarray(res[2].ids), np.asarray(x_ref[0]))
    eq(np.asarray(res[2].mask), np.asarray(qb_nnp.valid[0]))

    # the two ExactHaus rows ride ONE dispatch group in the mixed call;
    # both must equal their solo legacy runs (and the host oracle, which
    # test_engine already pins the legacy path to)
    qb_h = ref.build_queries([q_sets[0], q_sets[3]])
    v_h, i_h, s_h = ref.topk_hausdorff(qb_h, K)
    for row, j in ((3, 0), (9, 1)):
        eq(np.asarray(res[row].vals), np.asarray(v_h[j]))
        eq(np.asarray(res[row].ids), np.asarray(i_h[j]))
        assert res[row].stats.exact_evaluations == s_h[j].exact_evaluations

    v_g, i_g = ref.topk_gbo(sigs[0][None], K)
    eq(np.asarray(res[4].vals), np.asarray(v_g[0]))
    eq(np.asarray(res[4].ids), np.asarray(i_g[0]))

    eq(np.asarray(res[6].mask),
       np.asarray(ref.range_points(np.array([7], np.int32),
                                   lo[3][None], hi[3][None])[0]))

    qb_a = ref.build_queries([q_sets[2]])
    v_a, i_a, e_a = ref.topk_hausdorff_approx(qb_a, K, eps)
    eq(np.asarray(res[7].vals), np.asarray(v_a[0]))
    eq(np.asarray(res[7].ids), np.asarray(i_a[0]))
    eq(np.asarray(res[7].extras["eps_eff"]), np.asarray(e_a[0]))

    # the pipeline row: stage 1 == legacy top-k, stage 2 == host handoff
    p = res[8]
    v_p, i_p = ref.topk_ia(lo[4][None], hi[4][None], 3)
    eq(np.asarray(p.extras["stage1"].vals), np.asarray(v_p[0]))
    eq(np.asarray(p.extras["ds_ids"]), np.asarray(i_p[0]))
    wids = np.asarray(i_p[0])
    valid = wids >= 0
    want = ref.range_points(np.where(valid, wids, 0),
                            np.broadcast_to(lo[3], (3, 2)),
                            np.broadcast_to(hi[3], (3, 2)))
    got = np.asarray(p.mask)
    eq(got[valid], np.asarray(want)[valid])
    assert not got[~valid].any()


def test_input_order_preserved(env):
    """Shuffling the batch permutes the results identically — the planner
    regroups internally but scatters back to input positions."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    batch = _mixed_batch(lo, hi, q_sets, sigs, eps)
    res = engine.search(batch)
    perm = [7, 2, 9, 0, 5, 8, 1, 3, 6, 4]
    res_p = engine.search([batch[i] for i in perm])
    for out_pos, in_pos in enumerate(perm):
        a, b = res_p[out_pos], res[in_pos]
        assert a.op == b.op
        for field in ("vals", "ids", "mask"):
            x, y = getattr(a, field), getattr(b, field)
            assert (x is None) == (y is None)
            if x is not None:
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_grouping_and_counters(env):
    """A mixed batch compiles to one dispatch group per (op, statics):
    group counters and pipeline stage counters are booked, and the
    executable-cache invariant holds for every dispatch the groups ran."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    batch = _mixed_batch(lo, hi, q_sets, sigs, eps)
    engine.search(batch)
    s = engine.stats
    # stage 1: 8 groups (topk_ia twice — k=6 rows and the pipeline's k=3
    # stage in its own statics group — plus range_search / gbo / approx /
    # exact / plain range_points / nnp); stage 2: 1 range_points group
    assert s.group_counts["topk_ia"] == 2
    for op in ("range_search", "topk_gbo", "topk_hausdorff_approx",
               "topk_hausdorff", "nnp"):
        assert s.group_counts[op] == 1, op
    assert s.group_counts["range_points"] == 2    # plain + pipeline stage 2
    assert s.plan_groups == sum(s.group_counts.values()) == 9
    assert s.pipeline_stage1 == s.pipeline_stage2 == 1
    assert s.cache_hits + s.cache_misses == s.dispatches
    # the two ExactHaus rows shared one dispatch
    assert s.per_op["topk_hausdorff"]["dispatches"] == 1
    assert s.per_op["topk_hausdorff"]["queries"] == 2
    # re-running the identical batch re-plans the same groups and hits
    # the executable cache on every dispatch
    h0, g0 = s.cache_hits, s.plan_groups
    engine.search(batch)
    assert s.plan_groups == 2 * g0
    assert s.cache_hits > h0
    assert s.cache_hits + s.cache_misses == s.dispatches


def test_result_cache_across_ops_in_one_batch(env):
    """Rows repeated across ops inside ONE mixed batch short-circuit from
    the result LRU: only the genuinely new rows dispatch."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo)            # result cache ON
    warm = engine.search([
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=K),
        Query(op="topk_gbo", q_sig=sigs[0], k=K),
    ])
    d0 = engine.stats.dispatches
    hits0 = engine.stats.result_cache_hits
    # one mixed batch: a repeated IA row, a repeated GBO row, one new
    # range_search row -> exactly ONE new dispatch (the range_search)
    res = engine.search([
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=K),
        Query(op="range_search", r_lo=lo[1], r_hi=hi[1]),
        Query(op="topk_gbo", q_sig=sigs[0], k=K),
    ])
    assert engine.stats.dispatches == d0 + 1
    assert engine.stats.result_cache_hits == hits0 + 2
    np.testing.assert_array_equal(np.asarray(res[0].vals),
                                  np.asarray(warm[0].vals))
    np.testing.assert_array_equal(np.asarray(res[2].vals),
                                  np.asarray(warm[1].vals))
    # in-batch duplicates across a mixed batch dedupe per op group too
    d1 = engine.stats.dispatches
    res2 = engine.search([
        Query(op="topk_ia", r_lo=lo[2], r_hi=hi[2], k=K),
        Query(op="topk_ia", r_lo=lo[2], r_hi=hi[2], k=K),
    ])
    assert engine.stats.dispatches == d1 + 1
    np.testing.assert_array_equal(np.asarray(res2[0].vals),
                                  np.asarray(res2[1].vals))


@pytest.mark.parametrize("point_op", ["range_points", "nnp"])
def test_pipeline_matches_two_call_baseline(env, point_op):
    """Pipeline(dataset top-k -> point op in the winners) must equal the
    host two-call baseline: run the dataset op, pull the ids, run the
    point op — for both point ops and several dataset ops."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    k = 4
    stage1s = [
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=k),
        Query(op="topk_gbo", q_sig=sigs[1], k=k),
        Query(op="topk_hausdorff_approx", q=q_sets[2], k=k, eps=eps),
    ]
    if point_op == "range_points":
        stage2 = Query(op="range_points", r_lo=lo[1], r_hi=hi[1])
    else:
        stage2 = Query(op="nnp", q=q_sets[4])
    res = engine.search([Pipeline(s1, stage2) for s1 in stage1s])

    baseline = QueryEngine(repo, result_cache_size=0)
    for s1, r in zip(stage1s, res):
        if s1.op == "topk_ia":
            _, ids = baseline.topk_ia(s1.r_lo[None], s1.r_hi[None], k)
        elif s1.op == "topk_gbo":
            _, ids = baseline.topk_gbo(s1.q_sig[None], k)
        else:
            qb = baseline.build_queries([s1.q])
            _, ids, _ = baseline.topk_hausdorff_approx(qb, k, eps)
        ids = np.asarray(ids[0])
        np.testing.assert_array_equal(np.asarray(r.extras["ds_ids"]), ids)
        valid = ids >= 0
        safe = np.where(valid, ids, 0)
        if point_op == "range_points":
            want = baseline.range_points(
                safe, np.broadcast_to(stage2.r_lo, (k, 2)),
                np.broadcast_to(stage2.r_hi, (k, 2)))
            got = np.asarray(r.mask)
            np.testing.assert_array_equal(got[valid],
                                          np.asarray(want)[valid])
            assert not got[~valid].any()
        else:
            qb2 = baseline.build_queries([stage2.q] * k)
            wd, wi = baseline.nnp(safe, qb2)
            np.testing.assert_array_equal(
                np.asarray(r.vals)[valid], np.asarray(wd)[valid])
            np.testing.assert_array_equal(
                np.asarray(r.ids)[valid], np.asarray(wi)[valid])


def test_pipeline_sentinel_winners_masked(env):
    """k past the valid dataset count: the -1 sentinel winners' stage-2
    rows are masked out, never gathered as real datasets."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    k = repo.n_slots                      # > n_valid by construction
    assert k > int(np.asarray(repo.ds_valid).sum())
    res = engine.search([Pipeline(
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=k),
        Query(op="range_points", r_lo=lo[0], r_hi=hi[0]))])[0]
    ids = np.asarray(res.extras["ds_ids"])
    assert (ids == -1).any()
    np.testing.assert_array_equal(np.asarray(res.extras["valid"]),
                                  ids >= 0)
    assert not np.asarray(res.mask)[ids < 0].any()


def test_two_pipelines_share_stage2_dispatch(env):
    """Compatible pipelines group their stage-2 point queries into ONE
    dispatch (ragged ks concatenated)."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    engine.search([Pipeline(
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=3),
        Query(op="range_points", r_lo=lo[1], r_hi=hi[1]))])  # warm groups
    d0 = engine.stats.dispatches
    engine.search([
        Pipeline(Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=3),
                 Query(op="range_points", r_lo=lo[1], r_hi=hi[1])),
        Pipeline(Query(op="topk_ia", r_lo=lo[2], r_hi=hi[2], k=5),
                 Query(op="range_points", r_lo=lo[3], r_hi=hi[3])),
    ])
    # stage 1: one topk_ia group per k (2 dispatches); stage 2: ONE
    # range_points dispatch of 3 + 5 = 8 rows
    assert engine.stats.dispatches == d0 + 3
    assert engine.stats.per_op["range_points"]["queries"] >= 8
    assert engine.stats.pipeline_stage2 >= 3


def test_nnp_routes_through_pruned(env):
    """The engine's NNP dispatch is the Eq. 4 tree-pruned path: results
    bit-identical to `point_search.nnp_pruned` on the same trees, the
    same NN set as the unpruned `point_search.nnp` oracle, and the
    pruned fraction is surfaced (nonzero for clustered data) instead of
    discarded."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    res = engine.search([Query(op="nnp", ds_id=9, q=q_sets[1])])[0]
    qb = engine.build_queries([q_sets[1]])
    q_idx = jax.tree.map(lambda x: x[0], qb)
    d_idx = jax.tree.map(lambda x: x[9], repo.ds_index)

    wd, wi, ws = point_search.nnp_pruned(q_idx, d_idx)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(res.vals), np.asarray(wd),
                               rtol=1e-6, atol=1e-6)
    # the pruning actually bit: stats surfaced per query, fraction > 0
    assert res.stats.leaves_scanned == ws.leaves_scanned
    assert res.stats.pruned_fraction == pytest.approx(ws.pruned_fraction)
    assert res.stats.pruned_fraction > 0.0
    assert engine.stats.per_op["nnp"]["pruned_fraction"] > 0.0

    # unpruned oracle agreement on the valid points (the prune is lossless)
    ud, ui = point_search.nnp(q_idx, d_idx)
    qv = np.asarray(q_idx.valid)
    np.testing.assert_allclose(np.asarray(res.vals)[qv],
                               np.asarray(ud)[qv], atol=1e-4)


def test_query_validation():
    with pytest.raises(ValueError):
        Query(op="nope")
    with pytest.raises(ValueError):
        Query(op="topk_ia", r_lo=np.zeros(2), r_hi=np.ones(2))  # no k
    with pytest.raises(ValueError):
        Query(op="topk_hausdorff", k=3)                 # no q / q_index
    with pytest.raises(ValueError):
        Pipeline(Query(op="range_search", r_lo=np.zeros(2),
                       r_hi=np.ones(2)),
                 Query(op="range_points", r_lo=np.zeros(2),
                       r_hi=np.ones(2)))                # not a top-k stage
    with pytest.raises(ValueError):
        Pipeline(Query(op="topk_ia", r_lo=np.zeros(2), r_hi=np.ones(2),
                       k=2),
                 Query(op="topk_gbo", q_sig=np.zeros(8, np.uint32), k=2))
    with pytest.raises(ValueError):
        Pipeline(Query(op="topk_ia", r_lo=np.zeros(2), r_hi=np.ones(2),
                       k=2),
                 Query(op="range_points", ds_id=3, r_lo=np.zeros(2),
                       r_hi=np.ones(2)))                # ds_id must be None
    with pytest.raises(ValueError):
        Query(op="topk_hausdorff", k=3, q=np.zeros((4, 2)),
              q_index=np.zeros((4, 2)))                 # q XOR q_index
    with pytest.raises(ValueError):
        Query(op="nnp", ds_id=1, q_index=np.zeros((8, 2)))  # not an index


def test_standalone_point_query_requires_ds_id(env):
    """A standalone RangeP/NNP query without ds_id fails with a clear
    error at search() — only a Pipeline point stage may omit it."""
    datasets, repo, lo, hi, q_sets, sigs, eps = env
    engine = QueryEngine(repo, result_cache_size=0)
    with pytest.raises(ValueError, match="ds_id"):
        engine.search([Query(op="range_points", r_lo=lo[0], r_hi=hi[0])])
    with pytest.raises(ValueError, match="ds_id"):
        engine.search([Query(op="nnp", q=q_sets[0])])


def test_search_rejects_non_queries(env):
    datasets, repo, *_ = env
    engine = QueryEngine(repo, result_cache_size=0)
    with pytest.raises(TypeError):
        engine.search([{"op": "range_search"}])
    assert engine.search([]) == []
