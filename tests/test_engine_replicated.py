"""ReplicatedQueryEngine vs QueryEngine: bit-identical equivalence.

The replica-parallel engine serves from an R x D (replica x data) mesh:
the repository is sharded over ``data`` and replicated across ``replica``
groups, and each dispatch's query rows are split over the groups — every
group runs the 1-D sharded pipeline (data-scoped collectives only) on its
own row slice.  Every op must reproduce the single-device engine
bit-for-bit (values AND ids AND masks — np.testing.assert_array_equal, no
tolerance) regardless of the replica count or how the rows land, covering

  * all seven serving ops on the {1x8, 2x4, 4x2} mesh shapes, including
    the genuinely sharded ExactHaus (per-group while_loops retire
    independently: the continue flag is psum-reduced over ``data`` only),
  * batches SMALLER than the replica count (row padding: a 1-row batch on
    a 4-group mesh runs 3 groups on copies of row 0),
  * the uneven 2x3 mesh — replica row split on top of the 64 -> 66 slot
    padding path — and top-k overrun past the valid dataset count,
  * the declarative mixed `search()` batch (pipelines riding the same
    dispatch groups), bit-identical to the local engine's search(),
  * EngineStats invariants under replica dispatch: every dispatch books an
    executable-cache hit or miss, the planner's `group_counts` /
    `replica_subgroups` account for replica sub-groups, and the result
    cache short-circuits BEFORE rows are split over groups,
  * memory placement: per-device resident bytes of the dataset-axis
    arrays are total/D on EVERY one of the R x D devices (replicas share
    the shard layout; no device holds a full copy).

Same harness as tests/test_engine_sharded.py: in-process when the session
has >= 8 devices (the multi-device CI job), else each test re-runs its
body in a subprocess with XLA_FLAGS forcing 8 host devices
(conftest.dispatch_device_check).
"""
import numpy as np

from conftest import dispatch_device_check
from test_engine_sharded import K, _assert_all_ops_equal, _build

MESHES = ((1, 8), (2, 4), (4, 2))


def _dispatch(fn_name: str):
    dispatch_device_check("test_engine_replicated", fn_name)


def _replicated(repo, n_replicas, n_data, **kw):
    from repro.engine import ReplicatedQueryEngine
    return ReplicatedQueryEngine(repo, n_replicas=n_replicas, n_data=n_data,
                                 **kw)


def check_replicated_equivalence_meshes():
    """All seven ops on every {R x D} shape of 8 devices, ragged batches
    (including B < R: the row pad path), k overrun."""
    import jax

    datasets, repo, eng, q_sets, sigs, eps = _build(33)
    rng = np.random.default_rng(0)
    q_batch = eng.build_queries(q_sets)
    for n_rep, n_data in MESHES:
        reng = _replicated(repo, n_rep, n_data)
        assert reng.dispatch.name == "replicated"
        assert reng.dispatch.n_replicas == n_rep
        assert reng.dispatch.n_shards == n_data
        for B in (1, 5):              # B=1 pads rows on every R>1 mesh
            lo = rng.uniform(-60, 40, (B, 2)).astype(np.float32)
            hi = lo + rng.uniform(5, 40, (B, 2)).astype(np.float32)
            ds_ids = rng.integers(0, 33, B).astype(np.int32)
            qb = jax.tree.map(lambda x, n=B: x[:n], q_batch)
            _assert_all_ops_equal(eng, reng, repo, qb, sigs, eps, lo, hi,
                                  ds_ids, ks=(K, repo.n_slots))
        # batched ExactHaus: groups retire their while_loops independently
        vb, ib, sb = reng.topk_hausdorff(q_batch, K)
        vw, iw, sw = eng.topk_hausdorff(q_batch, K)
        np.testing.assert_array_equal(np.asarray(vb), np.asarray(vw))
        np.testing.assert_array_equal(np.asarray(ib), np.asarray(iw))
        for a, b in zip(sb, sw):
            assert a.candidates_after_bounds == b.candidates_after_bounds
        s = reng.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
    print("REPLICATED_MESHES_OK")


def check_replicated_uneven_data():
    """2x3 mesh: replica row split stacked on the uneven-shard slot
    padding (64 -> 66 slots), several buckets, k past the shard size."""
    datasets, repo, eng, q_sets, sigs, eps = _build(33)
    reng = _replicated(repo, 2, 3)
    assert reng.dispatch.n_slots_sharded == 66
    assert reng.dispatch.shard_slots == 22

    rng = np.random.default_rng(1)
    q_batch = eng.build_queries(q_sets)
    for B in (1, 5, 12):
        lo = rng.uniform(-60, 40, (B, 2)).astype(np.float32)
        hi = lo + rng.uniform(5, 40, (B, 2)).astype(np.float32)
        np.testing.assert_array_equal(
            np.asarray(reng.range_search(lo, hi)),
            np.asarray(eng.range_search(lo, hi)))
        for k in (K, repo.n_slots):
            v1, i1 = eng.topk_ia(lo, hi, k)
            v2, i2 = reng.topk_ia(lo, hi, k)
            np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))
            np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))
        ds_ids = rng.integers(0, 33, B).astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(reng.range_points(ds_ids, lo, hi)),
            np.asarray(eng.range_points(ds_ids, lo, hi)))
    lo = rng.uniform(-60, 40, (5, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (5, 2)).astype(np.float32)
    _assert_all_ops_equal(eng, reng, repo, q_batch, sigs, eps, lo, hi,
                          np.arange(5, dtype=np.int32), ks=(K, 33))
    print("REPLICATED_UNEVEN_OK")


def check_replicated_search_mixed():
    """One declarative mixed search() batch — all seven ops, three
    pipelines (one with k overrun), a duplicate row — bit-identical to
    the local engine on 2x4, 4x2, and the uneven 2x3 mesh, with the
    planner's sub-group accounting consistent."""
    from repro.engine import Pipeline, Query

    datasets, repo, eng, q_sets, sigs, eps = _build(33)
    rng = np.random.default_rng(5)
    lo = rng.uniform(-60, 40, (5, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (5, 2)).astype(np.float32)
    batch = [
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=K),
        Query(op="range_search", r_lo=lo[1], r_hi=hi[1]),
        Query(op="nnp", ds_id=4, q=q_sets[1]),
        Query(op="topk_hausdorff", q=q_sets[0], k=K),
        Query(op="topk_gbo", q_sig=sigs[0], k=K),
        Query(op="range_points", ds_id=7, r_lo=lo[3], r_hi=hi[3]),
        Query(op="topk_hausdorff_approx", q=q_sets[2], k=K, eps=eps),
        Pipeline(Query(op="topk_ia", r_lo=lo[4], r_hi=hi[4], k=3),
                 Query(op="range_points", r_lo=lo[3], r_hi=hi[3])),
        Pipeline(Query(op="topk_gbo", q_sig=sigs[1], k=3),
                 Query(op="nnp", q=q_sets[3])),
        Pipeline(Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0],
                       k=repo.n_slots),
                 Query(op="range_points", r_lo=lo[1], r_hi=hi[1])),
        Query(op="topk_ia", r_lo=lo[0], r_hi=hi[0], k=K),   # duplicate row
    ]
    want = eng.search(batch)
    for n_rep, n_data in ((2, 4), (4, 2), (2, 3)):
        reng = _replicated(repo, n_rep, n_data)
        got = reng.search(batch)
        assert len(got) == len(want)
        for a, b in zip(got, want):
            assert a.op == b.op
            for field in ("vals", "ids", "mask"):
                x, y = getattr(a, field), getattr(b, field)
                assert (x is None) == (y is None), (a.op, field)
                if x is not None:
                    np.testing.assert_array_equal(
                        np.asarray(x), np.asarray(y), err_msg=a.op)
            if a.op == "pipeline":
                np.testing.assert_array_equal(
                    np.asarray(a.extras["ds_ids"]),
                    np.asarray(b.extras["ds_ids"]))
        s = reng.stats
        assert s.cache_hits + s.cache_misses == s.dispatches
        assert s.pipeline_stage1 == s.pipeline_stage2 == 3
        # identical planner -> identical compiled groups; the replicated
        # dispatcher additionally books the replica row-blocks each group
        # spanned (bounded by R, and by the group's row count)
        assert s.plan_groups == eng.stats.plan_groups
        assert s.plan_groups <= s.replica_subgroups <= s.plan_groups * n_rep
        assert sum(s.group_counts.values()) == s.replica_subgroups
        assert set(s.group_counts) == set(eng.stats.group_counts)
    # the local engine books exactly one sub-group per compiled group
    assert eng.stats.replica_subgroups == eng.stats.plan_groups
    assert sum(eng.stats.group_counts.values()) == eng.stats.plan_groups
    print("REPLICATED_MIXED_OK")


def check_replicated_result_cache_short_circuit():
    """The result LRU answers repeat rows BEFORE replica splitting: an
    identical second batch books result-cache hits and adds zero device
    dispatches and zero compiled groups — on a multi-replica mesh."""
    datasets, repo, eng, q_sets, sigs, eps = _build(33)
    from repro.engine import Query

    reng = _replicated(repo, 2, 4)
    rng = np.random.default_rng(9)
    lo = rng.uniform(-60, 40, (6, 2)).astype(np.float32)
    hi = lo + rng.uniform(5, 40, (6, 2)).astype(np.float32)
    batch = [Query(op="topk_ia", r_lo=lo[i], r_hi=hi[i], k=K)
             for i in range(6)]
    want = [np.asarray(r.vals) for r in reng.search(batch)]
    s = reng.stats
    d0, g0 = s.dispatches, s.plan_groups
    assert s.result_cache_misses == 6 and s.result_cache_hits == 0
    assert d0 > 0
    got = [np.asarray(r.vals) for r in reng.search(batch)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert s.result_cache_hits == 6
    # the planner still BOOKS the group (count_group is a planning-level
    # metric), but every row was served from the LRU before bucketing, so
    # no dispatch — and therefore no replica split — ever happened
    assert s.dispatches == d0
    assert s.plan_groups == g0 + 1
    assert s.cache_hits + s.cache_misses == s.dispatches
    print("REPLICATED_CACHE_OK")


def check_replicated_repo_placement():
    """Every one of the R x D devices holds exactly 1/D of the
    dataset-axis arrays plus the (small) replicated upper tree — replicas
    reuse the shard layout, no device carries a full repository copy."""
    import jax
    from repro.engine.sharded import repo_device_bytes

    datasets, repo, eng, *_ = _build(33)
    for n_rep, n_data in ((2, 4), (4, 2)):
        reng = _replicated(repo, n_rep, n_data)
        d = reng.dispatch
        assert reng.repo is d.repo
        ds_arrays = (d.repo.ds_index, d.repo.ds_sigs, d.repo.ds_valid)
        ds_total = sum(x.nbytes for x in jax.tree.leaves(ds_arrays))
        per_dev = repo_device_bytes(ds_arrays)
        assert len(per_dev) == n_rep * n_data       # all 8 devices resident
        assert max(per_dev.values()) == ds_total // n_data
        rep_total = sum(x.nbytes for x in jax.tree.leaves(
            (d.repo.repo, d.repo.space_lo, d.repo.space_hi)))
        full = repo_device_bytes(d.repo)
        assert len(full) == n_rep * n_data
        assert max(full.values()) == ds_total // n_data + rep_total
    print("REPLICATED_PLACEMENT_OK")


def test_replicated_equivalence_meshes():
    _dispatch("check_replicated_equivalence_meshes")


def test_replicated_uneven_data():
    _dispatch("check_replicated_uneven_data")


def test_replicated_search_mixed():
    _dispatch("check_replicated_search_mixed")


def test_replicated_result_cache_short_circuit():
    _dispatch("check_replicated_result_cache_short_circuit")


def test_replicated_repo_placement():
    _dispatch("check_replicated_repo_placement")
